//! Hot-path microbenchmarks — the L3 performance budget.
//!
//! Measures every operation on the per-task path (SCRT nearest-neighbour,
//! insert/evict, top-τ, native SSIM/LSH, PJRT artifact dispatch) plus the
//! end-to-end scenario throughput. Results feed EXPERIMENTS.md §Perf.

use std::time::Duration;

use ccrsat::compute::{native::ssim_global, ComputeBackend, NativeBackend, Preprocessed};
use ccrsat::config::SimConfig;
use ccrsat::coordinator::scrt::{Record, Scrt};
use ccrsat::coordinator::Scenario;
use ccrsat::harness::bench::{black_box, Bencher};
use ccrsat::simulator::{prepare, Simulation};
use ccrsat::util::rng::Rng;
use ccrsat::workload::build_workload;

fn fake_pre(rng: &mut Rng) -> Preprocessed {
    let pd: Vec<f32> = (0..3072).map(|_| rng.f32()).collect();
    let gray: Vec<f32> = (0..1024).map(|_| rng.f32()).collect();
    Preprocessed {
        h: 32,
        w: 32,
        pd,
        gray,
    }
}

fn fake_record(id: usize, rng: &mut Rng) -> Record {
    Record {
        id,
        pre: fake_pre(rng),
        task_type: 0,
        result: (id % 21) as u32,
        reuse_count: (id % 7) as u32,
        last_used: id as f64,
        origin: id % 25,
    }
}

fn main() {
    let mut b = Bencher::new("hotpath").with_budget(
        Duration::from_millis(150),
        Duration::from_millis(700),
    );
    let mut rng = Rng::new(42);

    // ---- SCRT operations -------------------------------------------------
    let mut scrt = Scrt::new(4, 32);
    for i in 0..31 {
        scrt.insert((i % 4) as u32, fake_record(i, &mut rng));
    }
    let probe = fake_pre(&mut rng);
    b.bench("scrt::nearest (31 records, 3072-dim)", || {
        black_box(scrt.nearest(1, 0, &probe));
    });
    b.bench("scrt::top_tau(11)", || {
        black_box(scrt.top_tau(11));
    });
    let mut i = 1000;
    b.bench("scrt::insert+evict (full table)", || {
        i += 1;
        scrt.insert((i % 4) as u32, fake_record(i, &mut rng));
    });

    // ---- native kernels ----------------------------------------------------
    let a = fake_pre(&mut rng);
    let c = fake_pre(&mut rng);
    b.bench("native ssim_global (1024 px)", || {
        black_box(ssim_global(&a.gray, &c.gray));
    });
    let cfg = SimConfig::paper_default(5);
    let native = NativeBackend::new(&cfg);
    b.bench("native lsh_bucket (p_k=2, 3072-dim)", || {
        black_box(native.lsh_bucket(&a).unwrap());
    });
    b.bench("native classify (21 classes)", || {
        black_box(native.classify(&a).unwrap());
    });

    // ---- PJRT dispatch (only when artifacts exist) -------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let pjrt =
            ccrsat::compute::PjrtBackend::from_dir("artifacts").expect("engine");
        pjrt.engine().warmup().expect("warmup");
        b.bench("pjrt ssim dispatch", || {
            black_box(pjrt.ssim(&a, &c).unwrap());
        });
        b.bench("pjrt lsh_hash dispatch", || {
            black_box(pjrt.lsh_bucket(&a).unwrap());
        });
        b.bench("pjrt classify dispatch", || {
            black_box(pjrt.classify(&a).unwrap());
        });
    }

    // ---- end-to-end scenario (native backend, 3×3/45 tasks) ----------------
    let mut small = SimConfig::paper_default(3);
    small.workload.total_tasks = 45;
    let backend = NativeBackend::new(&small);
    let wl = build_workload(&small);
    let prep = prepare(&backend, &wl).expect("prepare");
    b.bench("simulate SLCR 3x3/45 (native, prepared)", || {
        let r = Simulation::new(&small, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        black_box(r.reused_tasks);
    });
    b.bench("simulate SCCR 3x3/45 (native, prepared)", || {
        let r = Simulation::new(&small, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        black_box(r.reused_tasks);
    });

    b.report();
}
