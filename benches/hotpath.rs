//! Hot-path microbenchmarks — the L3 performance budget.
//!
//! Thin wrapper over the shared suite in `ccrsat::harness::hotpath`
//! (also behind `ccrsat bench` and the CI perf job): measures every
//! operation on the per-task path (SCRT nearest-neighbour, identity
//! probe, insert/evict, top-τ, native SSIM/LSH, PJRT artifact dispatch)
//! plus end-to-end scenario throughput, and emits the machine-readable
//! `BENCH_hotpath.json` artifact. Pass `--scale` for the
//! production-scale SCRT tables and the 11×11 / 15×15 grids.

use ccrsat::harness::hotpath::{run_suite, HotpathOpts, DEFAULT_OUT};

fn main() {
    let opts = HotpathOpts {
        scale: std::env::args().any(|a| a == "--scale"),
        ..HotpathOpts::default()
    };
    let b = run_suite(&opts).expect("hotpath suite");
    b.report();
    b.write_json(DEFAULT_OUT).expect("write bench artifact");
    eprintln!("wrote {DEFAULT_OUT} ({} measurements)", b.results().len());
}
