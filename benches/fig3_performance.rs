//! Fig. 3 reproduction: task completion time (3a), reuse rate (3b) and CPU
//! occupancy (3c) for all five scenarios at every network scale.
//!
//! Paper headline shapes:
//!   * SCCR cuts completion time by up to 62.1% vs w/o CR (5×5) and CPU
//!     occupancy by up to 28.8%;
//!   * SLCR reuse rates fall with scale (0.544 / 0.39 / 0.27);
//!   * SCCR ≥ SLCR in reuse rate at every scale;
//!   * SRS Priority is the worst reuse scenario on completion time and
//!     can exceed w/o CR at larger scales.

use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::bench::Bencher;
use ccrsat::harness::experiments as exp;

fn main() {
    let cfg = SimConfig::paper_default(5);
    let backend = exp::default_backend(&cfg).expect("backend");
    let mut b = Bencher::new("fig3_performance");

    let mut reports = Vec::new();
    b.bench_once("suite: 5 scenarios x {5,7,9} scales", || {
        reports = exp::run_scale_suite(
            &cfg,
            backend.as_ref(),
            &exp::PAPER_SCALES,
            &Scenario::ALL,
        )
        .expect("suite");
    });

    println!("\n{}", exp::fig3_markdown(&reports));
    b.report();

    let get = |n: usize, s: Scenario| {
        reports.iter().find(|r| r.n == n && r.scenario == s).unwrap()
    };
    let mut ok = true;
    for n in exp::PAPER_SCALES {
        let scratch = get(n, Scenario::WithoutCr);
        let slcr = get(n, Scenario::Slcr);
        let sccr = get(n, Scenario::Sccr);
        if slcr.completion_time >= scratch.completion_time {
            eprintln!("SHAPE VIOLATION: SLCR not faster than w/o CR at {n}x{n}");
            ok = false;
        }
        if sccr.completion_time >= scratch.completion_time {
            eprintln!("SHAPE VIOLATION: SCCR not faster than w/o CR at {n}x{n}");
            ok = false;
        }
        if sccr.reuse_rate < slcr.reuse_rate {
            eprintln!("SHAPE VIOLATION: SCCR reuse rate below SLCR at {n}x{n}");
            ok = false;
        }
        if scratch.cpu_occupancy <= sccr.cpu_occupancy {
            eprintln!("SHAPE VIOLATION: w/o CR CPU not the highest at {n}x{n}");
            ok = false;
        }
    }
    // SLCR reuse rate decreases with scale (paper: 0.544 → 0.39 → 0.27)
    let rr5 = get(5, Scenario::Slcr).reuse_rate;
    let rr9 = get(9, Scenario::Slcr).reuse_rate;
    if rr9 >= rr5 {
        eprintln!("SHAPE VIOLATION: SLCR reuse rate must fall with scale ({rr5:.3} → {rr9:.3})");
        ok = false;
    }
    std::process::exit(if ok { 0 } else { 1 });
}
