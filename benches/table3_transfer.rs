//! Table III reproduction: data transfer volume (MB) for all scenarios at
//! every network scale.
//!
//! Paper reference rows:
//!   5×5: 0 / 8114.67 / 0 / 889.98 / 1054.09
//!   7×7: 0 / 44070.41 / 0 / 1732.42 / 1743.56
//!   9×9: 0 / 184587.78 / 0 / 3125.06 / 3369.23
//!
//! Expected shape: w/o CR = SLCR = 0; SCCR slightly above SCCR-INIT; SRS
//! Priority an order of magnitude above both and exploding with scale.

use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::bench::Bencher;
use ccrsat::harness::experiments as exp;

fn main() {
    let cfg = SimConfig::paper_default(5);
    let backend = exp::default_backend(&cfg).expect("backend");
    let mut b = Bencher::new("table3_transfer");

    let mut reports = Vec::new();
    b.bench_once("suite: 5 scenarios x {5,7,9} scales", || {
        reports = exp::run_scale_suite(
            &cfg,
            backend.as_ref(),
            &exp::PAPER_SCALES,
            &Scenario::ALL,
        )
        .expect("suite");
    });

    println!("\n{}", exp::table3_markdown(&reports));
    b.report();

    let mb = |n: usize, s: Scenario| {
        reports
            .iter()
            .find(|r| r.n == n && r.scenario == s)
            .map(|r| r.data_transfer_mb)
            .unwrap()
    };
    let mut ok = true;
    for n in exp::PAPER_SCALES {
        if mb(n, Scenario::WithoutCr) != 0.0 || mb(n, Scenario::Slcr) != 0.0 {
            eprintln!("SHAPE VIOLATION: non-collaborative scenario transferred data at {n}x{n}");
            ok = false;
        }
        if mb(n, Scenario::SrsPriority) <= mb(n, Scenario::Sccr) {
            eprintln!(
                "SHAPE VIOLATION: SRS Priority ({:.1} MB) must transfer far more than SCCR ({:.1} MB) at {n}x{n}",
                mb(n, Scenario::SrsPriority),
                mb(n, Scenario::Sccr)
            );
            ok = false;
        }
    }
    std::process::exit(if ok { 0 } else { 1 });
}
