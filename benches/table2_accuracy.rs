//! Table II reproduction: reuse accuracy for all five scenarios at every
//! network scale (5×5, 7×7, 9×9).
//!
//! Paper reference rows (UC Merced, their testbed):
//!   5×5: 1 / 0.9692 / 1 / 0.9980 / 0.9970
//!   7×7: 1 / 0.9756 / 1 / 0.9974 / 0.9954
//!   9×9: 1 / 0.9190 / 1 / 0.9757 / 0.9750
//!
//! Expected shape: w/o CR = 1 exactly (nothing reused); SLCR ≈ 1; the
//! collaborative scenarios slightly below and degrading with scale.

use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::bench::Bencher;
use ccrsat::harness::experiments as exp;

fn main() {
    let cfg = SimConfig::paper_default(5);
    let backend = exp::default_backend(&cfg).expect("backend");
    let mut b = Bencher::new("table2_accuracy");

    let mut reports = Vec::new();
    b.bench_once("suite: 5 scenarios x {5,7,9} scales", || {
        reports = exp::run_scale_suite(
            &cfg,
            backend.as_ref(),
            &exp::PAPER_SCALES,
            &Scenario::ALL,
        )
        .expect("suite");
    });

    println!("\n{}", exp::table2_markdown(&reports));
    b.report();

    // Shape assertions: warn and exit non-zero on violations.
    let acc = |n: usize, s: Scenario| {
        reports
            .iter()
            .find(|r| r.n == n && r.scenario == s)
            .map(|r| r.reuse_accuracy)
            .unwrap()
    };
    let mut ok = true;
    for n in exp::PAPER_SCALES {
        if acc(n, Scenario::WithoutCr) != 1.0 {
            eprintln!("SHAPE VIOLATION: w/o CR accuracy != 1 at {n}x{n}");
            ok = false;
        }
        if acc(n, Scenario::Slcr) < 0.95 {
            eprintln!("SHAPE VIOLATION: SLCR accuracy {} at {n}x{n}", acc(n, Scenario::Slcr));
            ok = false;
        }
    }
    std::process::exit(if ok { 0 } else { 1 });
}
