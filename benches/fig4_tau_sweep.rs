//! Fig. 4 reproduction: impact of τ (records broadcast per collaboration)
//! on task completion time, 5×5 network, SCCR-INIT and SCCR.
//!
//! Paper shape: completion time falls as τ grows and flattens around
//! τ = 11, where the SCRT storage limit stops further records from adding
//! value; SCCR tracks at-or-below SCCR-INIT.

use ccrsat::config::SimConfig;
use ccrsat::harness::bench::Bencher;
use ccrsat::harness::experiments as exp;

fn main() {
    let cfg = SimConfig::paper_default(5);
    let backend = exp::default_backend(&cfg).expect("backend");
    let mut b = Bencher::new("fig4_tau_sweep");

    let mut rows = Vec::new();
    b.bench_once("tau sweep x 8 values x 2 scenarios (5x5)", || {
        rows = exp::tau_sweep(&cfg, backend.as_ref(), 5, &exp::TAU_SWEEP)
            .expect("sweep");
    });

    println!("\n{}", exp::fig4_markdown(&rows));
    b.report();

    // Shape: the curve must not blow up with τ — the late-τ region should
    // be no worse than ~15% above the best point (the paper's plateau).
    let mut ok = true;
    for series in 0..2 {
        let best = rows
            .iter()
            .map(|(_, ys)| ys[series])
            .fold(f64::INFINITY, f64::min);
        let last = rows.last().unwrap().1[series];
        if last > best * 1.25 {
            eprintln!(
                "SHAPE VIOLATION: series {series} rises after the plateau (best {best:.1}, τ=15 {last:.1})"
            );
            ok = false;
        }
    }
    std::process::exit(if ok { 0 } else { 1 });
}
