//! Fig. 5 reproduction: impact of the cooperation threshold th_co on task
//! completion time, 5×5 network, SCCR-INIT and SCCR (SLCR as reference).
//!
//! Paper shape: U-curve — a very small th_co starves collaboration, an
//! excessive th_co triggers it constantly and the communication burden
//! dominates (beyond ~0.8 SCCR falls behind SLCR); the optimum sits near
//! th_co = 0.5.

use ccrsat::config::SimConfig;
use ccrsat::harness::bench::Bencher;
use ccrsat::harness::experiments as exp;

fn main() {
    let cfg = SimConfig::paper_default(5);
    let backend = exp::default_backend(&cfg).expect("backend");
    let mut b = Bencher::new("fig5_thco_sweep");

    let mut rows = Vec::new();
    b.bench_once("th_co sweep x 9 values x 2 scenarios (5x5)", || {
        rows = exp::thco_sweep(&cfg, backend.as_ref(), 5, &exp::THCO_SWEEP)
            .expect("sweep");
    });

    println!("\n{}", exp::fig5_markdown(&rows));
    b.report();

    // Shape: the extremes must not beat the mid-range (U-ish curve).
    let mut ok = true;
    for series in 0..2 {
        let ys: Vec<f64> = rows.iter().map(|(_, ys)| ys[series]).collect();
        let mid_best = ys[2..7].iter().cloned().fold(f64::INFINITY, f64::min);
        if ys[0] < mid_best * 0.98 && *ys.last().unwrap() < mid_best * 0.98 {
            eprintln!("SHAPE VIOLATION: series {series} is inverted-U");
            ok = false;
        }
    }
    std::process::exit(if ok { 0 } else { 1 });
}
