//! Compile-time stand-in for the real `xla` crate (xla-rs).
//!
//! The offline build image cannot vendor the real XLA bindings, but the
//! PJRT engine code in `ccrsat::runtime` must keep type-checking — CI
//! runs `cargo check --features pjrt` against this crate so the real
//! execution path cannot rot unseen. The API surface mirrors exactly
//! what the engine consumes; every runtime entry point returns an error
//! explaining that this is the stub.
//!
//! To run the real three-layer path, point the `xla` path dependency in
//! `rust/Cargo.toml` at a vendored xla-rs build instead of this crate.

#![forbid(unsafe_code)]

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (the engine only needs `Display`).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this is the xla API stub (vendor/xla-stub); vendor a real \
         xla-rs build and point rust/Cargo.toml's `xla` path dependency at \
         it for PJRT execution"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_the_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla-stub"), "{err}");
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("vendor"), "{err}");
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
