//! End-to-end driver: the paper's full 5×5 evaluation on the real
//! three-layer stack.
//!
//! Loads the AOT artifacts (Pallas kernels + MicroGoogLeNet inside JAX
//! graphs, lowered to HLO and executed via PJRT — Python is never invoked),
//! generates the 625-image synthetic UC Merced stand-in, runs all five
//! scenarios of Sec. V on the identical task stream, and prints the
//! Table II / Table III / Fig. 3 rows. This is the run recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example constellation_e2e
//! ```

use std::time::Instant;

use ccrsat::compute::PjrtBackend;
use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::experiments as exp;

fn main() -> ccrsat::Result<()> {
    let wall = Instant::now();
    let cfg = SimConfig::paper_default(5);
    let backend = PjrtBackend::from_dir("artifacts")?;
    println!(
        "engine: platform={}, {} artifacts",
        backend.engine().platform_name(),
        backend.engine().manifest().entries.len()
    );

    println!("\npreparing 5×5 workload (625 images) + oracle labels...");
    let ps = exp::prepare_scale(&cfg, &backend, 5)?;
    println!(
        "workload: {} tasks, {} distinct scenes",
        ps.workload.tasks.len(),
        ps.workload.num_scenes
    );

    let mut reports = Vec::new();
    for scenario in Scenario::ALL {
        let r = exp::run_scenario(&ps, &backend, scenario)?;
        println!("{}", r.summary());
        reports.push(r);
    }

    println!("\n{}", exp::table2_markdown(&reports));
    println!("{}", exp::table3_markdown(&reports));
    println!("{}", exp::fig3_markdown(&reports));

    // Headline claims, paper vs us.
    let t = |s: Scenario| {
        reports
            .iter()
            .find(|r| r.scenario == s)
            .map(|r| r.completion_time)
            .unwrap()
    };
    let cpu = |s: Scenario| {
        reports
            .iter()
            .find(|r| r.scenario == s)
            .map(|r| r.cpu_occupancy)
            .unwrap()
    };
    let rr = |s: Scenario| {
        reports
            .iter()
            .find(|r| r.scenario == s)
            .map(|r| r.reuse_rate)
            .unwrap()
    };
    println!("headline checks (paper → measured):");
    println!(
        "  SCCR completion-time reduction vs w/o CR : 62.1% → {:.1}%",
        100.0 * (1.0 - t(Scenario::Sccr) / t(Scenario::WithoutCr))
    );
    println!(
        "  SCCR CPU-occupancy reduction vs w/o CR   : 28.8% → {:.1}%",
        100.0 * (1.0 - cpu(Scenario::Sccr) / cpu(Scenario::WithoutCr))
    );
    println!(
        "  SCCR reuse-rate gain vs SLCR             : +37.3% → {:+.1}%",
        100.0 * (rr(Scenario::Sccr) / rr(Scenario::Slcr) - 1.0)
    );
    let stats = backend.engine().stats();
    println!(
        "\nPJRT: {} compilations, {} executions, wallclock {:.1}s",
        stats.compiles,
        stats.executions,
        wall.elapsed().as_secs_f64()
    );
    Ok(())
}
