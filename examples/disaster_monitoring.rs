//! Domain scenario: disaster monitoring with a hot region.
//!
//! The intro of the paper motivates computation reuse with real-time
//! applications such as disaster warning: during an event, many satellites
//! repeatedly image the *same* affected area, so the task stream becomes
//! extremely redundant. This example models that by raising the dwell
//! probability and the spatial-correlation knobs and compares SLCR vs SCCR
//! under increasing redundancy — showing where collaborative reuse starts
//! to pay for its communication.
//!
//! ```sh
//! make artifacts && cargo run --release --example disaster_monitoring
//! ```

use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::experiments as exp;
use ccrsat::simulator::Simulation;

fn main() -> ccrsat::Result<()> {
    let base = SimConfig::paper_default(5);
    let backend = exp::default_backend(&base)?;

    println!("disaster-monitoring sweep: redundancy ramps up as the event");
    println!("unfolds (dwell probability ↑, scene diversity ↓)\n");
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>10} {:>12}",
        "dwell", "T_slcr (s)", "T_sccr (s)", "rr_slcr", "rr_sccr", "xfer (MB)"
    );

    for dwell in [0.3, 0.5, 0.7, 0.85] {
        let mut cfg = base.clone();
        cfg.workload.scene_repeat_prob = dwell;
        cfg.workload.repeat_prob_spread = 0.2;
        cfg.workload.scenes_per_satellite = 4; // few scenes: the hot area
        cfg.validate()?;

        let slcr = Simulation::new(&cfg, backend.as_ref(), Scenario::Slcr).run()?;
        let sccr = Simulation::new(&cfg, backend.as_ref(), Scenario::Sccr).run()?;
        println!(
            "{:<10.2} {:>12.1} {:>12.1} {:>10.3} {:>10.3} {:>12.1}",
            dwell,
            slcr.completion_time,
            sccr.completion_time,
            slcr.reuse_rate,
            sccr.reuse_rate,
            sccr.data_transfer_mb
        );
    }

    println!("\nhigher redundancy → higher reuse rates and faster completion;");
    println!("the redundant-event regime is where CCRSat pays off most.");
    Ok(())
}
