//! Quickstart: run local computation reuse (SLCR) on a small constellation
//! and print the paper's five criteria.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT backend (the real Pallas/JAX artifacts) when
//! `artifacts/manifest.json` exists, else the pure-Rust reference backend.

use ccrsat::compute::{ComputeBackend, NativeBackend, PjrtBackend};
use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::simulator::Simulation;

fn main() -> ccrsat::Result<()> {
    // A 3×3 constellation with 90 tasks — small enough to finish in
    // seconds, big enough to exercise queueing, hashing and the SSIM gate.
    let mut cfg = SimConfig::paper_default(3);
    cfg.workload.total_tasks = 90;
    cfg.validate()?;

    let backend: Box<dyn ComputeBackend> =
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Box::new(PjrtBackend::from_dir("artifacts")?)
        } else {
            eprintln!("note: no artifacts found, using the native backend");
            Box::new(NativeBackend::new(&cfg))
        };
    println!("backend: {}", backend.name());

    for scenario in [Scenario::WithoutCr, Scenario::Slcr] {
        let report = Simulation::new(&cfg, backend.as_ref(), scenario).run()?;
        println!("{}", report.summary());
    }

    println!("\nSLCR reuses previously computed results whenever the SSIM");
    println!("similarity gate (eq. 12) exceeds th_sim = {}.", cfg.reuse.th_sim);
    Ok(())
}
