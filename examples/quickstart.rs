//! Quickstart: run local computation reuse (SLCR) on a small constellation
//! and print the paper's five criteria.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT backend (the real Pallas/JAX artifacts) when
//! `artifacts/manifest.json` exists, else the pure-Rust reference backend.

use ccrsat::compute::ComputeBackend;
use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::experiments as exp;
use ccrsat::simulator::Simulation;

fn main() -> ccrsat::Result<()> {
    // A 3×3 constellation with 90 tasks — small enough to finish in
    // seconds, big enough to exercise queueing, hashing and the SSIM gate.
    let mut cfg = SimConfig::paper_default(3);
    cfg.workload.total_tasks = 90;
    cfg.validate()?;

    let backend = exp::default_backend(&cfg)?;
    println!("backend: {}", backend.name());

    for scenario in [Scenario::WithoutCr, Scenario::Slcr] {
        let report = Simulation::new(&cfg, backend.as_ref(), scenario).run()?;
        println!("{}", report.summary());
    }

    println!("\nSLCR reuses previously computed results whenever the SSIM");
    println!("similarity gate (eq. 12) exceeds th_sim = {}.", cfg.reuse.th_sim);
    Ok(())
}
