//! Scenario-level integration tests (native backend — fast, deterministic).
//!
//! These check the cross-module behaviours the paper's evaluation relies
//! on: scenario orderings, conservation laws, failure injection on the
//! config boundary, and determinism of whole runs.

use ccrsat::compute::NativeBackend;
use ccrsat::config::SimConfig;
use ccrsat::coordinator::Scenario;
use ccrsat::harness::experiments as exp;
use ccrsat::simulator::{prepare, Simulation};
use ccrsat::workload::build_workload;

fn cfg(n: usize, tasks: usize) -> SimConfig {
    let mut c = SimConfig::paper_default(n);
    c.workload.total_tasks = tasks;
    c
}

#[test]
fn all_scenarios_process_every_task() {
    let c = cfg(3, 54);
    let backend = NativeBackend::new(&c);
    for s in Scenario::ALL {
        let r = Simulation::new(&c, &backend, s).run().unwrap();
        assert_eq!(r.total_tasks, 54, "{s} lost tasks");
        assert_eq!(r.tasks.len(), 54);
        // conservation: reused + computed = total
        let computed = r.tasks.iter().filter(|t| !t.reused).count();
        assert_eq!(computed + r.reused_tasks, 54);
    }
}

#[test]
fn reuse_scenarios_beat_scratch_on_sigma() {
    let c = cfg(3, 54);
    let backend = NativeBackend::new(&c);
    let scratch = Simulation::new(&c, &backend, Scenario::WithoutCr)
        .run()
        .unwrap();
    for s in [Scenario::Slcr, Scenario::SccrInit, Scenario::Sccr] {
        let r = Simulation::new(&c, &backend, s).run().unwrap();
        assert!(
            r.completion_time < scratch.completion_time,
            "{s}: {} !< {}",
            r.completion_time,
            scratch.completion_time
        );
        assert!(r.cpu_occupancy < scratch.cpu_occupancy);
    }
}

#[test]
fn sigma_decomposes_into_compute_plus_comm() {
    let c = cfg(3, 54);
    let backend = NativeBackend::new(&c);
    for s in Scenario::ALL {
        let r = Simulation::new(&c, &backend, s).run().unwrap();
        let sigma = c.alpha * r.comm_seconds + r.compute_seconds;
        assert!(
            (r.completion_time - sigma).abs() < 1e-6,
            "{s}: eq. 9 decomposition broken"
        );
        if !s.collaborates() {
            assert_eq!(r.comm_seconds, 0.0, "{s} must not communicate");
        }
    }
}

#[test]
fn full_determinism_across_runs_and_sharing() {
    let c = cfg(3, 45);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    for s in Scenario::ALL {
        let a = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let b = Simulation::new(&c, &backend, s).run().unwrap();
        assert_eq!(a.completion_time, b.completion_time, "{s}");
        assert_eq!(a.reused_tasks, b.reused_tasks, "{s}");
        assert_eq!(a.data_transfer_mb, b.data_transfer_mb, "{s}");
        assert_eq!(a.reuse_accuracy, b.reuse_accuracy, "{s}");
    }
}

#[test]
fn th_sim_above_one_degenerates_to_scratch_plus_lookup() {
    let mut c = cfg(3, 36);
    c.reuse.th_sim = 1.0; // SSIM can never exceed 1 strictly
    let backend = NativeBackend::new(&c);
    let r = Simulation::new(&c, &backend, Scenario::Slcr).run().unwrap();
    assert_eq!(r.reused_tasks, 0, "th_sim=1.0 must disable reuse");
}

#[test]
fn zero_th_co_never_collaborates_when_everyone_is_fine() {
    let mut c = cfg(3, 36);
    c.reuse.th_co = 0.0; // SRS can never be < 0
    let backend = NativeBackend::new(&c);
    let r = Simulation::new(&c, &backend, Scenario::Sccr).run().unwrap();
    assert_eq!(r.collab_events, 0);
    assert_eq!(r.data_transfer_mb, 0.0);
}

#[test]
fn tau_controls_broadcast_size() {
    let c = cfg(3, 54);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    let run_tau = |tau: usize| {
        let mut c2 = c.clone();
        c2.reuse.tau = tau;
        Simulation::new(&c2, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap()
    };
    let small = run_tau(1);
    let large = run_tau(12);
    // τ upper-bounds the per-event share size
    assert!(
        small.broadcast_records <= small.collab_events,
        "τ=1 must cap shares at one record per event"
    );
    if small.collab_events > 0 && large.collab_events > 0 {
        let per_small = small.broadcast_records as f64 / small.collab_events as f64;
        let per_large = large.broadcast_records as f64 / large.collab_events as f64;
        assert!(
            per_large >= per_small,
            "larger τ must not shrink shares ({per_large} < {per_small})"
        );
    }
}

#[test]
fn larger_networks_dilute_per_satellite_load() {
    // total tasks fixed (the paper's setup): a larger grid means fewer
    // tasks per satellite and a lower SLCR reuse rate.
    let backend3 = NativeBackend::new(&cfg(3, 108));
    let r3 = Simulation::new(&cfg(3, 108), &backend3, Scenario::Slcr)
        .run()
        .unwrap();
    let backend6 = NativeBackend::new(&cfg(6, 108));
    let r6 = Simulation::new(&cfg(6, 108), &backend6, Scenario::Slcr)
        .run()
        .unwrap();
    assert!(
        r6.reuse_rate < r3.reuse_rate,
        "rr must fall with scale: {} !< {}",
        r6.reuse_rate,
        r3.reuse_rate
    );
}

#[test]
fn experiment_suite_tables_render() {
    let base = cfg(3, 36);
    let backend = NativeBackend::new(&base);
    let reports =
        exp::run_scale_suite(&base, &backend, &[3], &Scenario::ALL).unwrap();
    assert_eq!(reports.len(), 5);
    for table in [
        exp::table2_markdown(&reports),
        exp::table3_markdown(&reports),
        exp::fig3_markdown(&reports),
    ] {
        assert!(table.contains("| 3x3 |"), "missing row:\n{table}");
        assert!(table.contains("SCCR"));
    }
    let csv = exp::suite_csv(&reports);
    assert_eq!(csv.lines().count(), 6);
}

#[test]
fn invalid_configs_rejected_at_run_boundary() {
    let mut c = cfg(3, 36);
    c.reuse.tau = 0;
    let backend = NativeBackend::new(&cfg(3, 36));
    assert!(Simulation::new(&c, &backend, Scenario::Sccr).run().is_err());
}

#[test]
fn parallel_harness_matches_sequential_runs() {
    // The tentpole invariant: fanning scenario runs out across threads
    // against one shared Prepared workload must be observationally
    // identical to running them one after another.
    let c = cfg(3, 45);
    let backend = NativeBackend::new(&c);
    let ps = exp::prepare_scale(&c, &backend, 3).unwrap();
    let par = exp::run_scenarios_parallel(&ps, &backend, &Scenario::ALL).unwrap();
    assert_eq!(par.len(), Scenario::ALL.len());
    for (report, &scenario) in par.iter().zip(Scenario::ALL.iter()) {
        assert_eq!(report.scenario, scenario, "order must be preserved");
        let seq = exp::run_scenario(&ps, &backend, scenario).unwrap();
        assert_eq!(report.completion_time, seq.completion_time, "{scenario}");
        assert_eq!(report.compute_seconds, seq.compute_seconds, "{scenario}");
        assert_eq!(report.comm_seconds, seq.comm_seconds, "{scenario}");
        assert_eq!(report.makespan, seq.makespan, "{scenario}");
        assert_eq!(report.reuse_rate, seq.reuse_rate, "{scenario}");
        assert_eq!(report.reuse_accuracy, seq.reuse_accuracy, "{scenario}");
        assert_eq!(report.data_transfer_mb, seq.data_transfer_mb, "{scenario}");
        assert_eq!(report.reused_tasks, seq.reused_tasks, "{scenario}");
        assert_eq!(report.total_tasks, seq.total_tasks, "{scenario}");
        assert_eq!(report.cpu_occupancy, seq.cpu_occupancy, "{scenario}");
        assert_eq!(report.mean_latency, seq.mean_latency, "{scenario}");
        assert_eq!(report.p95_latency, seq.p95_latency, "{scenario}");
        assert_eq!(report.collab_events, seq.collab_events, "{scenario}");
        assert_eq!(report.expanded_events, seq.expanded_events, "{scenario}");
        assert_eq!(report.aborted_collabs, seq.aborted_collabs, "{scenario}");
        assert_eq!(report.broadcast_records, seq.broadcast_records, "{scenario}");
    }
}

#[test]
fn timed_suite_reports_fanout_speedup_inputs() {
    let c = cfg(3, 36);
    let backend = NativeBackend::new(&c);
    let (reports, timing) =
        exp::run_scale_suite_timed(&c, &backend, &[3], &Scenario::ALL).unwrap();
    assert_eq!(reports.len(), Scenario::ALL.len());
    assert!(timing.parallel_s > 0.0);
    assert!(timing.sequential_s > 0.0);
    assert!(timing.speedup() > 0.0);
}

#[test]
fn srs_priority_transfers_most() {
    let c = cfg(4, 96);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    let sccr = Simulation::new(&c, &backend, Scenario::Sccr)
        .with_workload(&wl)
        .with_prepared(&prep)
        .run()
        .unwrap();
    let srs_p = Simulation::new(&c, &backend, Scenario::SrsPriority)
        .with_workload(&wl)
        .with_prepared(&prep)
        .run()
        .unwrap();
    if srs_p.collab_events > 0 {
        assert!(
            srs_p.data_transfer_mb > sccr.data_transfer_mb,
            "SRS Priority must flood more data"
        );
    }
}
