//! Golden pins for the engine rework: fixed-seed `RunReport` identity
//! between the kept pre-refactor monolithic loop
//! (`Simulation::run_reference`) and the layered engine
//! (`Simulation::run`), for every scenario — down to the per-task logs.
//!
//! The reference path IS the pre-refactor code (kept verbatim, the same
//! pattern as `prepare_sequential`), so these tests pin the engine to the
//! exact numbers the monolith produced at the paper seed. Any behavioural
//! drift in the rework — event ordering, damping, counter accounting,
//! float summation order — fails here first.

use ccrsat::compute::NativeBackend;
use ccrsat::config::{NodeOutageSpec, OutageSpec, SimConfig, TopologyMode};
use ccrsat::coordinator::Scenario;
use ccrsat::metrics::RunReport;
use ccrsat::simulator::{
    prepare, PreparedSource, Simulation, StreamConfig, StreamingSource,
};
use ccrsat::workload::build_workload;

fn cfg(n: usize, tasks: usize) -> SimConfig {
    let mut c = SimConfig::paper_default(n);
    c.workload.total_tasks = tasks;
    c
}

/// Every deterministic aggregate field (everything but wallclock_s).
fn assert_aggregates_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.scenario, b.scenario, "{label}");
    assert_eq!(a.n, b.n, "{label}");
    assert_eq!(a.completion_time, b.completion_time, "{label}");
    assert_eq!(a.compute_seconds, b.compute_seconds, "{label}");
    assert_eq!(a.comm_seconds, b.comm_seconds, "{label}");
    assert_eq!(a.makespan, b.makespan, "{label}");
    assert_eq!(a.reuse_rate, b.reuse_rate, "{label}");
    assert_eq!(a.cpu_occupancy, b.cpu_occupancy, "{label}");
    assert_eq!(a.reuse_accuracy, b.reuse_accuracy, "{label}");
    assert_eq!(a.data_transfer_mb, b.data_transfer_mb, "{label}");
    assert_eq!(a.total_tasks, b.total_tasks, "{label}");
    assert_eq!(a.reused_tasks, b.reused_tasks, "{label}");
    assert_eq!(a.cross_scene_reuses, b.cross_scene_reuses, "{label}");
    assert_eq!(a.foreign_reuses, b.foreign_reuses, "{label}");
    assert_eq!(a.errors_same_scene, b.errors_same_scene, "{label}");
    assert_eq!(a.errors_cross_scene, b.errors_cross_scene, "{label}");
    assert_eq!(a.collab_events, b.collab_events, "{label}");
    assert_eq!(a.expanded_events, b.expanded_events, "{label}");
    assert_eq!(a.aborted_collabs, b.aborted_collabs, "{label}");
    assert_eq!(a.broadcast_records, b.broadcast_records, "{label}");
    assert_eq!(a.retransmits, b.retransmits, "{label}");
    assert_eq!(a.dropped_chunks, b.dropped_chunks, "{label}");
    assert_eq!(a.dedup_saved_mb, b.dedup_saved_mb, "{label}");
    assert_eq!(a.handovers, b.handovers, "{label}");
    assert_eq!(a.stranded_chunks, b.stranded_chunks, "{label}");
    assert_eq!(a.contact_wait_s, b.contact_wait_s, "{label}");
    assert_eq!(a.contact_utilization, b.contact_utilization, "{label}");
    assert_eq!(a.crashes, b.crashes, "{label}");
    assert_eq!(a.lost_tasks, b.lost_tasks, "{label}");
    assert_eq!(a.failover_reselections, b.failover_reselections, "{label}");
    assert_eq!(a.timeout_fallbacks, b.timeout_fallbacks, "{label}");
    assert_eq!(a.cold_scrt_rebuilds, b.cold_scrt_rebuilds, "{label}");
    assert_eq!(a.crash_dropped_chunks, b.crash_dropped_chunks, "{label}");
    assert_eq!(a.mean_latency, b.mean_latency, "{label}");
    assert_eq!(a.p95_latency, b.p95_latency, "{label}");
}

/// Per-satellite summaries, slot for slot.
fn assert_satellites_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.per_satellite.len(), b.per_satellite.len(), "{label}");
    for (x, y) in a.per_satellite.iter().zip(&b.per_satellite) {
        assert_eq!(x.sat, y.sat, "{label}");
        assert_eq!(x.tasks, y.tasks, "{label} sat {}", x.sat);
        assert_eq!(x.reused, y.reused, "{label} sat {}", x.sat);
        assert_eq!(x.busy_s, y.busy_s, "{label} sat {}", x.sat);
        assert_eq!(x.cpu_occupancy, y.cpu_occupancy, "{label} sat {}", x.sat);
        assert_eq!(
            x.collab_requests, y.collab_requests,
            "{label} sat {}",
            x.sat
        );
        assert_eq!(x.times_source, y.times_source, "{label} sat {}", x.sat);
        assert_eq!(x.scrt_len, y.scrt_len, "{label} sat {}", x.sat);
        assert_eq!(x.evictions, y.evictions, "{label} sat {}", x.sat);
    }
}

/// Per-task logs, entry for entry (completion order).
fn assert_logs_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.tasks.len(), b.tasks.len(), "{label}");
    for (x, y) in a.tasks.iter().zip(&b.tasks) {
        assert_eq!(x.task_id, y.task_id, "{label}");
        assert_eq!(x.sat, y.sat, "{label} task {}", x.task_id);
        assert_eq!(x.arrival, y.arrival, "{label} task {}", x.task_id);
        assert_eq!(x.start, y.start, "{label} task {}", x.task_id);
        assert_eq!(x.completion, y.completion, "{label} task {}", x.task_id);
        assert_eq!(x.reused, y.reused, "{label} task {}", x.task_id);
        assert_eq!(x.correct, y.correct, "{label} task {}", x.task_id);
        assert_eq!(x.ssim, y.ssim, "{label} task {}", x.task_id);
        assert_eq!(x.scene, y.scene, "{label} task {}", x.task_id);
        assert_eq!(
            x.reused_from_scene, y.reused_from_scene,
            "{label} task {}",
            x.task_id
        );
        assert_eq!(
            x.reused_from_sat, y.reused_from_sat,
            "{label} task {}",
            x.task_id
        );
    }
}

#[test]
fn engine_matches_reference_for_every_scenario() {
    let c = cfg(3, 60);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    for s in Scenario::ALL {
        let engine = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        let label = format!("scenario {s}");
        assert_aggregates_identical(&engine, &reference, &label);
        assert_satellites_identical(&engine, &reference, &label);
        assert_logs_identical(&engine, &reference, &label);
    }
}

#[test]
fn engine_matches_reference_on_a_larger_collaborating_grid() {
    // 4×4 with more tasks per satellite: exercises queue buildup, the
    // cooldown window, area expansion and receiver suppression harder
    // than the 3×3 pin.
    let c = cfg(4, 96);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    for s in [Scenario::Sccr, Scenario::SrsPriority] {
        let engine = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        let label = format!("scenario {s} 4x4");
        assert_aggregates_identical(&engine, &reference, &label);
        assert_satellites_identical(&engine, &reference, &label);
        assert_logs_identical(&engine, &reference, &label);
    }
}

#[test]
fn sharded_engine_matches_reference_for_every_scenario() {
    // The sharded conservative engine must land on the exact numbers the
    // pre-refactor monolith produced — aggregates, per-satellite
    // summaries and per-task logs — for every scenario and shard count.
    let c = cfg(3, 60);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    for s in Scenario::ALL {
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        for threads in [2usize, 3] {
            let sharded = Simulation::new(&c, &backend, s)
                .with_workload(&wl)
                .with_prepared(&prep)
                .threads(threads)
                .run()
                .unwrap();
            let label = format!("sharded scenario {s} K={threads}");
            assert_aggregates_identical(&sharded, &reference, &label);
            assert_satellites_identical(&sharded, &reference, &label);
            assert_logs_identical(&sharded, &reference, &label);
        }
    }
}

#[test]
fn sharded_engine_matches_reference_on_a_larger_collaborating_grid() {
    // 4×4 with queue buildup, area expansion and (for SRS Priority)
    // frequent flooding requests — the pause/resolve path under load.
    let c = cfg(4, 96);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    for s in [Scenario::Sccr, Scenario::SrsPriority] {
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        let sharded = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .threads(4)
            .run()
            .unwrap();
        let label = format!("sharded scenario {s} 4x4 K=4");
        assert_aggregates_identical(&sharded, &reference, &label);
        assert_satellites_identical(&sharded, &reference, &label);
        assert_logs_identical(&sharded, &reference, &label);
    }
}

#[test]
fn sharded_streaming_matches_reference() {
    // Sharded engine over the streaming source: both axes of the engine
    // rework at once, still bit-identical to the monolith.
    let c = cfg(3, 45);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    let stream = StreamConfig {
        chunk_tasks: 8,
        window_chunks: 2,
    };
    for s in [Scenario::Sccr, Scenario::SrsPriority] {
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        let mut source = StreamingSource::new(&backend, &wl, stream).unwrap();
        let sharded = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .threads(4)
            .run_with_source(&mut source)
            .unwrap();
        let label = format!("sharded streaming scenario {s}");
        assert_aggregates_identical(&sharded, &reference, &label);
        assert_satellites_identical(&sharded, &reference, &label);
        assert_logs_identical(&sharded, &reference, &label);
    }
}

#[test]
fn sharded_engine_rejects_a_degenerate_lookahead() {
    // Zero-byte records collapse the per-hop latency to zero: the
    // conservative window could never advance past a broadcast, so the
    // sharded engine must reject the topology instead of deadlocking.
    let mut c = cfg(3, 12);
    c.comm.record_input_bytes = 0.0;
    c.comm.record_output_bytes = 0.0;
    let backend = NativeBackend::new(&c);
    let err = Simulation::new(&c, &backend, Scenario::Sccr).threads(2).run();
    match err {
        Err(ccrsat::Error::Simulation(msg)) => {
            assert!(msg.contains("lookahead"), "unexpected message: {msg}");
        }
        other => panic!("expected Error::Simulation, got {other:?}"),
    }
    // Non-collaborating scenarios never broadcast: no lookahead needed.
    let ok = Simulation::new(&c, &backend, Scenario::Slcr).threads(2).run();
    assert!(ok.is_ok(), "SLCR must not need a broadcast lookahead");
}

#[test]
fn engines_reject_degenerate_fault_configs_naming_the_value() {
    // A nonsensical fault model must be rejected up front by BOTH engines
    // with an `Error::Simulation` naming the offending value — never a
    // hang in an unwinnable retransmission loop or a mid-run panic.
    let mutations: Vec<(Box<dyn Fn(&mut SimConfig)>, &str)> = vec![
        (Box::new(|c| c.comm.loss_prob = 1.0), "loss_prob=1"),
        (Box::new(|c| c.comm.loss_prob = -0.25), "loss_prob=-0.25"),
        (
            Box::new(|c| c.comm.link_bandwidth_bps = 0.0),
            "link_bandwidth_bps=0",
        ),
        (
            Box::new(|c| c.comm.link_bandwidth_bps = -1000000.0),
            "link_bandwidth_bps=-1000000",
        ),
        (Box::new(|c| c.comm.chunk_bytes = 0.0), "chunk_bytes=0"),
        (
            Box::new(|c| {
                c.comm.chunk_bytes = 1e6;
                c.comm.max_retries = 65;
            }),
            "max_retries=65",
        ),
    ];
    for (mutate, needle) in &mutations {
        let mut c = cfg(3, 12);
        mutate(&mut c);
        let backend = NativeBackend::new(&c);
        for threads in [None, Some(2)] {
            let mut sim = Simulation::new(&c, &backend, Scenario::Sccr);
            if let Some(k) = threads {
                sim = sim.threads(k);
            }
            match sim.run() {
                Err(ccrsat::Error::Simulation(msg)) => {
                    assert!(
                        msg.contains(needle),
                        "threads {threads:?}: expected '{needle}' in: {msg}"
                    );
                }
                other => panic!(
                    "threads {threads:?} ({needle}): expected Error::Simulation, got {other:?}"
                ),
            }
        }
    }
}

#[test]
fn engines_reject_bad_topology_configs_naming_the_value() {
    // Same contract as the fault-model check above, for the contact-plan
    // layer: both engines must reject a nonsensical topology up front
    // with an `Error::Simulation` naming the offending value.
    let mutations: Vec<(Box<dyn Fn(&mut SimConfig)>, &str)> = vec![
        (
            Box::new(|c| {
                c.topology.mode = TopologyMode::Walker;
                c.topology.duty = 0.0;
            }),
            "duty=0",
        ),
        (
            Box::new(|c| {
                c.topology.mode = TopologyMode::Walker;
                c.topology.inter_rate_scale = 1.5;
            }),
            "inter_rate_scale=1.5",
        ),
        (
            // Inert Walker knobs on a static topology are a config bug,
            // not a silent no-op.
            Box::new(|c| c.topology.duty = 0.5),
            "static",
        ),
        (
            Box::new(|c| {
                c.topology.outages =
                    OutageSpec::parse_list("0-1@5..2").unwrap();
            }),
            "start < end",
        ),
        (
            // Satellites 0 and 2 are two hops apart on the 3×3 grid:
            // not an ISL, so no outage can name that pair.
            Box::new(|c| {
                c.topology.outages =
                    OutageSpec::parse_list("0-2@1..2").unwrap();
            }),
            "not a grid ISL",
        ),
        (
            Box::new(|c| {
                c.topology.mode = TopologyMode::Walker;
                c.topology.planes = Some(4);
            }),
            "planes",
        ),
    ];
    for (mutate, needle) in &mutations {
        let mut c = cfg(3, 12);
        mutate(&mut c);
        let backend = NativeBackend::new(&c);
        for threads in [None, Some(2)] {
            let mut sim = Simulation::new(&c, &backend, Scenario::Sccr);
            if let Some(k) = threads {
                sim = sim.threads(k);
            }
            match sim.run() {
                Err(ccrsat::Error::Simulation(msg)) => {
                    assert!(
                        msg.contains(needle),
                        "threads {threads:?}: expected '{needle}' in: {msg}"
                    );
                }
                other => panic!(
                    "threads {threads:?} ({needle}): expected Error::Simulation, got {other:?}"
                ),
            }
        }
    }
}

#[test]
fn engines_reject_degenerate_node_fault_configs_naming_the_value() {
    // Same contract as the link-fault and topology checks: both engines
    // must reject a nonsensical node-fault model up front with an
    // `Error::Simulation` naming the offending value.
    let mutations: Vec<(Box<dyn Fn(&mut SimConfig)>, &str)> = vec![
        (Box::new(|c| c.faults.mtbf_s = 0.0), "mtbf_s=0"),
        (Box::new(|c| c.faults.mtbf_s = f64::NAN), "mtbf_s=NaN"),
        (
            Box::new(|c| {
                c.faults.mtbf_s = 1000.0;
                c.faults.downtime_s = 0.0;
            }),
            "downtime_s=0",
        ),
        (
            Box::new(|c| {
                c.faults.mtbf_s = 1000.0;
                c.faults.collab_timeout_s = -1.0;
            }),
            "collab_timeout_s=-1",
        ),
        (
            Box::new(|c| {
                c.faults.mtbf_s = 1000.0;
                c.faults.max_failover_retries = 17;
            }),
            "max_failover_retries=17",
        ),
        (
            Box::new(|c| {
                c.faults.mtbf_s = 1000.0;
                c.faults.failover_backoff = 0.5;
            }),
            "failover_backoff=0.5",
        ),
        (
            // Satellite 99 does not exist on a 3×3 grid.
            Box::new(|c| {
                c.faults.node_outages =
                    NodeOutageSpec::parse_list("99@1..2").unwrap();
            }),
            "sat=99",
        ),
        (
            Box::new(|c| {
                c.faults.node_outages =
                    NodeOutageSpec::parse_list("5@9..3").unwrap();
            }),
            "start < end",
        ),
    ];
    for (mutate, needle) in &mutations {
        let mut c = cfg(3, 12);
        mutate(&mut c);
        let backend = NativeBackend::new(&c);
        for threads in [None, Some(2)] {
            let mut sim = Simulation::new(&c, &backend, Scenario::Sccr);
            if let Some(k) = threads {
                sim = sim.threads(k);
            }
            match sim.run() {
                Err(ccrsat::Error::Simulation(msg)) => {
                    assert!(
                        msg.contains(needle),
                        "threads {threads:?}: expected '{needle}' in: {msg}"
                    );
                }
                other => panic!(
                    "threads {threads:?} ({needle}): expected Error::Simulation, got {other:?}"
                ),
            }
        }
    }
}

#[test]
fn reference_monolith_refuses_node_fault_configs() {
    // The kept pre-refactor monolith predates the node-fault model: it
    // must refuse a crash-injecting config rather than silently report
    // fault-free numbers for it.
    let mut c = cfg(3, 12);
    c.faults.mtbf_s = 500.0;
    let backend = NativeBackend::new(&c);
    let refr = Simulation::new(&c, &backend, Scenario::Sccr).run_reference();
    match refr {
        Err(ccrsat::Error::Simulation(msg)) => {
            assert!(
                msg.contains("node faults"),
                "unexpected message: {msg}"
            );
        }
        other => panic!("expected Error::Simulation, got {other:?}"),
    }
}

#[test]
fn failover_exhaustion_terminates_and_counts_fallbacks() {
    // Every satellite except the center crashes briefly every 3 s
    // (staggered), so any source a surviving requester selects has a
    // crash inside every failover window: the bounded cascade must
    // exhaust its retries and degrade to local compute — terminating,
    // counting the fallbacks, and staying bit-identical when sharded.
    let mut c = cfg(3, 60);
    // SRS = β·rr + (1−β)(1−C) is exactly th_co = 0.5 on a fresh idle
    // satellite; raise the threshold so nearly every completion at the
    // surviving requester fires the Alg. 2 gate.
    c.reuse.th_co = 0.95;
    c.faults.collab_timeout_s = 5.0;
    c.faults.failover_backoff = 1.0;
    c.faults.downtime_s = 1.0; // inert for scripted spans; must be valid
    let center = 4usize; // 3×3 grid center never crashes
    let mut spec = String::new();
    for sat in (0..9).filter(|&s| s != center) {
        for k in 0..2000 {
            let start = k as f64 * 3.0 + sat as f64 * 0.1;
            spec.push_str(&format!("{sat}@{start}..{},", start + 0.5));
        }
    }
    c.faults.node_outages =
        NodeOutageSpec::parse_list(spec.trim_end_matches(',')).unwrap();
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    // SRS Priority floods and its global source search cannot come up
    // empty while any other satellite is alive, so the cascade (not the
    // selection) is what decides every one of its requests.
    let single = Simulation::new(&c, &backend, Scenario::SrsPriority)
        .with_workload(&wl)
        .with_prepared(&prep)
        .run()
        .unwrap();
    assert!(single.crashes > 0, "the outage script must crash satellites");
    assert!(
        single.timeout_fallbacks > 0,
        "every window holds a source crash: some cascade must exhaust \
         its retries ({} reselections, {} aborted)",
        single.failover_reselections,
        single.aborted_collabs
    );
    let sharded = Simulation::new(&c, &backend, Scenario::SrsPriority)
        .with_workload(&wl)
        .with_prepared(&prep)
        .threads(2)
        .run()
        .unwrap();
    assert_aggregates_identical(&sharded, &single, "failover exhaustion");
    assert_satellites_identical(&sharded, &single, "failover exhaustion");
    assert_logs_identical(&sharded, &single, "failover exhaustion");
}

#[test]
fn reference_monolith_refuses_dynamic_contact_plans() {
    // The kept pre-refactor monolith predates contact plans: it must
    // refuse a dynamic topology rather than silently report always-on
    // numbers for it.
    let mut c = cfg(3, 12);
    c.topology.mode = TopologyMode::Walker;
    c.topology.duty = 0.5;
    let backend = NativeBackend::new(&c);
    let refr = Simulation::new(&c, &backend, Scenario::Sccr).run_reference();
    match refr {
        Err(ccrsat::Error::Simulation(msg)) => {
            assert!(msg.contains("run_reference"), "unexpected message: {msg}");
        }
        other => panic!("expected Error::Simulation, got {other:?}"),
    }
}

#[test]
fn retry_exhaustion_terminates_and_counts_drops() {
    // Heavy loss against a tiny retry budget: the bounded attempt loop
    // must terminate (no livelock waiting for a chunk that never lands),
    // the report must count both retransmissions and abandoned chunks,
    // and the sharded engine must stay bit-identical through all of it.
    let mut c = cfg(3, 60);
    c.comm.loss_prob = 0.6;
    c.comm.chunk_bytes = 6e6;
    c.comm.max_retries = 1;
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    let single = Simulation::new(&c, &backend, Scenario::Sccr)
        .with_workload(&wl)
        .with_prepared(&prep)
        .run()
        .unwrap();
    assert!(single.collab_events > 0, "no broadcasts — nothing exercised");
    assert!(single.retransmits > 0, "loss 0.6 must force retransmissions");
    assert!(
        single.dropped_chunks > 0,
        "0.36 per-chunk drop odds over this many chunks must exhaust retries"
    );
    let sharded = Simulation::new(&c, &backend, Scenario::Sccr)
        .with_workload(&wl)
        .with_prepared(&prep)
        .threads(2)
        .run()
        .unwrap();
    assert_aggregates_identical(&sharded, &single, "retry exhaustion");
    assert_satellites_identical(&sharded, &single, "retry exhaustion");
    assert_logs_identical(&sharded, &single, "retry exhaustion");
    // The kept pre-fault monolith has no lossy path: it must refuse the
    // config rather than silently report ideal-link numbers.
    let refr = Simulation::new(&c, &backend, Scenario::Sccr)
        .with_workload(&wl)
        .with_prepared(&prep)
        .run_reference();
    match refr {
        Err(ccrsat::Error::Simulation(msg)) => {
            assert!(msg.contains("run_reference"), "unexpected message: {msg}");
        }
        other => panic!("expected Error::Simulation, got {other:?}"),
    }
}

#[test]
fn streaming_engine_matches_reference_for_every_scenario() {
    // The full chain: streaming preparation feeding the engine must land
    // on the exact numbers the pre-refactor monolith produced over the
    // fully-materialized table.
    let c = cfg(3, 45);
    let backend = NativeBackend::new(&c);
    let wl = build_workload(&c);
    let prep = prepare(&backend, &wl).unwrap();
    let stream = StreamConfig {
        chunk_tasks: 8,
        window_chunks: 2,
    };
    for s in Scenario::ALL {
        let mut source = StreamingSource::new(&backend, &wl, stream).unwrap();
        let streamed = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .run_with_source(&mut source)
            .unwrap();
        let reference = Simulation::new(&c, &backend, s)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_reference()
            .unwrap();
        let label = format!("streaming scenario {s}");
        assert_aggregates_identical(&streamed, &reference, &label);
        assert_satellites_identical(&streamed, &reference, &label);
        assert_logs_identical(&streamed, &reference, &label);
        if s.uses_reuse() {
            assert!(
                source.peak_resident() <= stream.window_tasks(),
                "{label}: residency {} over window {}",
                source.peak_resident(),
                stream.window_tasks()
            );
        }
    }
}
