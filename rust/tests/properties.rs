//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable in this offline image, so the harness is a
//! seeded random-sweep driver: each property runs across many generated
//! cases; failures print the seed for exact reproduction.

use ccrsat::compute::kernels::{dot, gemm_nt, gemv};
use ccrsat::compute::{ComputeBackend, NativeBackend, Preprocessed};
use ccrsat::coordinator::sccr::{select_source, AreaPolicy};
use ccrsat::coordinator::scrt::{Record, Scrt};
use ccrsat::coordinator::srs::srs;
use ccrsat::coordinator::Scenario;
use ccrsat::network::{CommModel, GridTopology};
use ccrsat::config::{NodeOutageSpec, OutageSpec, SimConfig, TopologyMode};
use ccrsat::simulator::{
    prepare, prepare_sequential, PreparedSource, ShardPartition, Simulation,
    StreamConfig, StreamingSource,
};
use ccrsat::util::rng::Rng;
use ccrsat::workload::build_workload;

const CASES: u64 = 200;

fn pre(rng: &mut Rng, dim: usize) -> Preprocessed {
    Preprocessed {
        h: 1,
        w: dim,
        pd: (0..dim * 3).map(|_| rng.f32()).collect(),
        gray: (0..dim).map(|_| rng.f32()).collect(),
    }
}

fn record(id: usize, rng: &mut Rng) -> Record {
    Record {
        id,
        pre: std::sync::Arc::new(pre(rng, 8)),
        task_type: (rng.below(3)) as u16,
        result: rng.below(21) as u32,
        reuse_count: rng.below(10) as u32,
        last_used: rng.f64() * 100.0,
        origin: rng.below(25),
    }
}

// ---------------------------------------------------------------------------
// Blocked GEMV / GEMM kernels ≡ naive per-row reference
// ---------------------------------------------------------------------------

/// Strict left-to-right f64 dot — the naive per-row reference the blocked
/// kernels are measured against.
fn naive_dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| f64::from(x) * f64::from(y))
        .sum()
}

/// Condition-aware tolerance scale: re-associating a float sum moves the
/// result by a multiple of machine epsilon *per magnitude of the summed
/// terms*, so relative error is measured against Σ|aᵢ·bᵢ| (+1 so
/// zero-length rows don't divide by zero).
fn dot_scale(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (f64::from(x) * f64::from(y)).abs())
        .sum::<f64>()
        + 1.0
}

#[test]
fn prop_blocked_gemv_matches_naive_reference() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x6E44);
        let rows = 1 + rng.below(24);
        // shapes straddle the 8-lane boundary and go up to kernel-sized
        let cols = 1 + rng.below(3100);
        let a: Vec<f32> = (0..rows * cols).map(|_| rng.f32() - 0.5).collect();
        let x: Vec<f32> = (0..cols).map(|_| rng.f32() - 0.5).collect();
        let mut out = vec![0f32; rows];
        gemv(&a, rows, cols, &x, &mut out);
        for (r, &got) in out.iter().enumerate() {
            let row = &a[r * cols..(r + 1) * cols];
            let want = naive_dot_f64(row, &x);
            let err = (f64::from(got) - want).abs();
            let tol = 1e-4 * dot_scale(row, &x);
            assert!(
                err <= tol,
                "seed {seed}: row {r} ({rows}x{cols}): |{got} - {want}| = {err} > {tol}"
            );
        }
    }
}

#[test]
fn prop_blocked_gemm_matches_naive_reference_and_gemv_bitwise() {
    for seed in 0..CASES / 4 {
        let mut rng = Rng::new(seed ^ 0x9E88);
        let n = 1 + rng.below(20);
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(800);
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let w: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let mut out = vec![0f32; n * m];
        gemm_nt(&x, n, &w, m, k, &mut out);
        for i in 0..n {
            let xrow = &x[i * k..(i + 1) * k];
            // bitwise identical to the per-row GEMV path ...
            let mut row_out = vec![0f32; m];
            gemv(&w, m, k, xrow, &mut row_out);
            for j in 0..m {
                assert_eq!(
                    out[i * m + j].to_bits(),
                    row_out[j].to_bits(),
                    "seed {seed}: ({i},{j}) of {n}x{m}x{k} diverges from gemv"
                );
                assert_eq!(
                    row_out[j].to_bits(),
                    dot(xrow, &w[j * k..(j + 1) * k]).to_bits(),
                    "seed {seed}: gemv vs dot"
                );
            }
            // ... and within 1e-4 relative of the naive reference.
            for j in 0..m {
                let wrow = &w[j * k..(j + 1) * k];
                let want = naive_dot_f64(xrow, wrow);
                let err = (f64::from(out[i * m + j]) - want).abs();
                let tol = 1e-4 * dot_scale(xrow, wrow);
                assert!(err <= tol, "seed {seed}: ({i},{j}): {err} > {tol}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batched backend paths ≡ single-task paths; prepare() ≡ sequential
// ---------------------------------------------------------------------------

#[test]
fn prop_native_batched_apis_match_single_task_paths() {
    let cfg = SimConfig::paper_default(3);
    let backend = NativeBackend::new(&cfg);
    let dim = cfg.workload.raw_h / 2;
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0xBA7C);
        let count = 1 + rng.below(90); // straddles the 64-task GEMM block
        let pres: Vec<Preprocessed> = (0..count)
            .map(|_| Preprocessed {
                h: dim,
                w: dim,
                pd: (0..dim * dim * 3).map(|_| rng.f32()).collect(),
                gray: (0..dim * dim).map(|_| rng.f32()).collect(),
            })
            .collect();
        let refs: Vec<&Preprocessed> = pres.iter().collect();
        let labels = backend.classify_many(&refs).unwrap();
        let buckets = backend.lsh_bucket_many(&refs).unwrap();
        assert_eq!(labels.len(), count);
        assert_eq!(buckets.len(), count);
        for (i, p) in pres.iter().enumerate() {
            assert_eq!(
                labels[i],
                backend.classify(p).unwrap(),
                "seed {seed}: label {i} of {count}"
            );
            assert_eq!(
                buckets[i],
                backend.lsh_bucket(p).unwrap(),
                "seed {seed}: bucket {i} of {count}"
            );
        }
    }
}

/// Fixed-seed end-to-end invariance: the parallel + batched `prepare` and
/// the sequential unbatched reference produce identical `Prepared` data,
/// and the fixed-seed `RunReport` reuse/accuracy metrics are identical
/// whichever path fed the simulation.
#[test]
fn prop_fixed_seed_reuse_metrics_invariant_across_prepare_paths() {
    let mut cfg = SimConfig::paper_default(3);
    cfg.workload.total_tasks = 45;
    let backend = NativeBackend::new(&cfg);
    let wl = build_workload(&cfg);
    let par = prepare(&backend, &wl).unwrap();
    let seq = prepare_sequential(&backend, &wl).unwrap();
    assert_eq!(par.pres, seq.pres, "preprocessed inputs diverged");
    assert_eq!(par.oracle, seq.oracle, "oracle labels diverged");
    for scenario in [Scenario::Slcr, Scenario::Sccr] {
        let a = Simulation::new(&cfg, &backend, scenario)
            .with_workload(&wl)
            .with_prepared(&par)
            .run()
            .unwrap();
        let b = Simulation::new(&cfg, &backend, scenario)
            .with_workload(&wl)
            .with_prepared(&seq)
            .run()
            .unwrap();
        assert_eq!(a.reuse_rate, b.reuse_rate, "{scenario}");
        assert_eq!(a.reuse_accuracy, b.reuse_accuracy, "{scenario}");
        assert_eq!(a.reused_tasks, b.reused_tasks, "{scenario}");
        assert_eq!(a.completion_time, b.completion_time, "{scenario}");
        assert_eq!(a.data_transfer_mb, b.data_transfer_mb, "{scenario}");
    }
}

/// Streaming preparation ≡ fully-materialized preparation: across random
/// seeds and window shapes (including degenerate single-chunk windows that
/// force recomputation), a streaming run's `RunReport` is bit-identical to
/// the materialized run's, while prepared-task residency stays bounded by
/// the window instead of the task count.
#[test]
fn prop_streaming_runs_bit_identical_to_materialized() {
    let mut case_rng = Rng::new(0xCC25A7);
    for case in 0..6u64 {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 36 + case_rng.below(25);
        cfg.workload.seed = 2025 + case;
        // Smaller tiles keep the debug-mode render cost sane; identity is
        // independent of tile size.
        cfg.workload.raw_h = 32;
        cfg.workload.raw_w = 32;
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let full = prepare(&backend, &wl).unwrap();
        let stream = StreamConfig {
            chunk_tasks: 1 + case_rng.below(12),
            window_chunks: 1 + case_rng.below(3),
        };
        for scenario in [Scenario::Slcr, Scenario::Sccr] {
            let materialized = Simulation::new(&cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&full)
                .run()
                .unwrap();
            let mut source =
                StreamingSource::new(&backend, &wl, stream).unwrap();
            let streamed = Simulation::new(&cfg, &backend, scenario)
                .with_workload(&wl)
                .run_with_source(&mut source)
                .unwrap();
            let label = format!(
                "case {case} {scenario} chunk={} window={}",
                stream.chunk_tasks, stream.window_chunks
            );
            assert_eq!(
                streamed.completion_time, materialized.completion_time,
                "{label}"
            );
            assert_eq!(
                streamed.compute_seconds, materialized.compute_seconds,
                "{label}"
            );
            assert_eq!(streamed.makespan, materialized.makespan, "{label}");
            assert_eq!(streamed.reuse_rate, materialized.reuse_rate, "{label}");
            assert_eq!(
                streamed.reuse_accuracy, materialized.reuse_accuracy,
                "{label}"
            );
            assert_eq!(
                streamed.data_transfer_mb, materialized.data_transfer_mb,
                "{label}"
            );
            assert_eq!(
                streamed.collab_events, materialized.collab_events,
                "{label}"
            );
            assert_eq!(
                streamed.reused_tasks, materialized.reused_tasks,
                "{label}"
            );
            assert_eq!(streamed.tasks.len(), materialized.tasks.len(), "{label}");
            assert!(
                source.peak_resident() <= stream.window_tasks(),
                "{label}: residency {} over window {}",
                source.peak_resident(),
                stream.window_tasks()
            );
        }
    }
}

/// Sharded conservative engine ≡ single-threaded engine, bit for bit:
/// across random workload seeds, shard counts K ∈ {1, 2, 3, 8}, every
/// scenario, and both prepared sources (materialized + streaming). The
/// comparison covers the aggregates, the per-satellite summaries and the
/// per-task logs in completion order — the full deterministic surface of
/// a `RunReport`.
#[test]
fn prop_sharded_runs_bit_identical_across_shard_counts() {
    let mut case_rng = Rng::new(0x5A4D);
    for case in 0..4u64 {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 36 + case_rng.below(25);
        cfg.workload.seed = 7_000 + case;
        // Smaller tiles keep the debug-mode render cost sane; identity is
        // independent of tile size.
        cfg.workload.raw_h = 32;
        cfg.workload.raw_w = 32;
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let threads = [1usize, 2, 3, 8][case as usize % 4];
        let stream = StreamConfig {
            chunk_tasks: 1 + case_rng.below(10),
            window_chunks: 1 + case_rng.below(3),
        };
        for scenario in Scenario::ALL {
            let single = Simulation::new(&cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run()
                .unwrap();
            let sharded = Simulation::new(&cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .threads(threads)
                .run()
                .unwrap();
            let label = format!("case {case} {scenario} K={threads}");
            assert_reports_bit_identical(&single, &sharded, &label);

            let mut source = StreamingSource::new(&backend, &wl, stream).unwrap();
            let sharded_streamed = Simulation::new(&cfg, &backend, scenario)
                .with_workload(&wl)
                .threads(threads)
                .run_with_source(&mut source)
                .unwrap();
            assert_reports_bit_identical(
                &single,
                &sharded_streamed,
                &format!("{label} streaming"),
            );
        }
    }
}

/// Every deterministic field of two `RunReport`s (wallclock excluded),
/// including per-satellite summaries and per-task logs.
fn assert_reports_bit_identical(
    a: &ccrsat::metrics::RunReport,
    b: &ccrsat::metrics::RunReport,
    label: &str,
) {
    assert_eq!(a.completion_time, b.completion_time, "{label}");
    assert_eq!(a.compute_seconds, b.compute_seconds, "{label}");
    assert_eq!(a.comm_seconds, b.comm_seconds, "{label}");
    assert_eq!(a.makespan, b.makespan, "{label}");
    assert_eq!(a.reuse_rate, b.reuse_rate, "{label}");
    assert_eq!(a.cpu_occupancy, b.cpu_occupancy, "{label}");
    assert_eq!(a.reuse_accuracy, b.reuse_accuracy, "{label}");
    assert_eq!(a.data_transfer_mb, b.data_transfer_mb, "{label}");
    assert_eq!(a.total_tasks, b.total_tasks, "{label}");
    assert_eq!(a.reused_tasks, b.reused_tasks, "{label}");
    assert_eq!(a.cross_scene_reuses, b.cross_scene_reuses, "{label}");
    assert_eq!(a.foreign_reuses, b.foreign_reuses, "{label}");
    assert_eq!(a.collab_events, b.collab_events, "{label}");
    assert_eq!(a.expanded_events, b.expanded_events, "{label}");
    assert_eq!(a.aborted_collabs, b.aborted_collabs, "{label}");
    assert_eq!(a.broadcast_records, b.broadcast_records, "{label}");
    assert_eq!(a.retransmits, b.retransmits, "{label}");
    assert_eq!(a.dropped_chunks, b.dropped_chunks, "{label}");
    assert_eq!(a.crashes, b.crashes, "{label}");
    assert_eq!(a.lost_tasks, b.lost_tasks, "{label}");
    assert_eq!(a.failover_reselections, b.failover_reselections, "{label}");
    assert_eq!(a.timeout_fallbacks, b.timeout_fallbacks, "{label}");
    assert_eq!(a.cold_scrt_rebuilds, b.cold_scrt_rebuilds, "{label}");
    assert_eq!(a.crash_dropped_chunks, b.crash_dropped_chunks, "{label}");
    assert_eq!(a.dedup_saved_mb, b.dedup_saved_mb, "{label}");
    assert_eq!(a.handovers, b.handovers, "{label}");
    assert_eq!(a.stranded_chunks, b.stranded_chunks, "{label}");
    assert_eq!(a.contact_wait_s, b.contact_wait_s, "{label}");
    assert_eq!(a.contact_utilization, b.contact_utilization, "{label}");
    assert_eq!(a.mean_latency, b.mean_latency, "{label}");
    assert_eq!(a.p95_latency, b.p95_latency, "{label}");
    assert_eq!(a.per_satellite.len(), b.per_satellite.len(), "{label}");
    for (x, y) in a.per_satellite.iter().zip(&b.per_satellite) {
        assert_eq!(x.sat, y.sat, "{label}");
        assert_eq!(x.tasks, y.tasks, "{label} sat {}", x.sat);
        assert_eq!(x.reused, y.reused, "{label} sat {}", x.sat);
        assert_eq!(x.busy_s, y.busy_s, "{label} sat {}", x.sat);
        assert_eq!(x.cpu_occupancy, y.cpu_occupancy, "{label} sat {}", x.sat);
        assert_eq!(x.collab_requests, y.collab_requests, "{label} sat {}", x.sat);
        assert_eq!(x.times_source, y.times_source, "{label} sat {}", x.sat);
        assert_eq!(x.scrt_len, y.scrt_len, "{label} sat {}", x.sat);
        assert_eq!(x.evictions, y.evictions, "{label} sat {}", x.sat);
    }
    assert_eq!(a.tasks.len(), b.tasks.len(), "{label}");
    for (x, y) in a.tasks.iter().zip(&b.tasks) {
        assert_eq!(x.task_id, y.task_id, "{label}");
        assert_eq!(x.sat, y.sat, "{label} task {}", x.task_id);
        assert_eq!(x.start, y.start, "{label} task {}", x.task_id);
        assert_eq!(x.completion, y.completion, "{label} task {}", x.task_id);
        assert_eq!(x.reused, y.reused, "{label} task {}", x.task_id);
        assert_eq!(x.correct, y.correct, "{label} task {}", x.task_id);
        assert_eq!(x.ssim, y.ssim, "{label} task {}", x.task_id);
        assert_eq!(
            x.reused_from_sat, y.reused_from_sat,
            "{label} task {}",
            x.task_id
        );
    }
}

/// Fault-injection sweep: across workload seeds, loss rates {0.0, 0.05,
/// 0.3}, shard counts K ∈ {1, 2, 4} and every scenario, the sharded
/// engine's full `RunReport` — aggregates, fault counters, per-satellite
/// summaries, per-task logs — is bit-identical to the single-threaded
/// engine's. At loss 0.0 the fault model is dormant (`faults_active()` is
/// false) and the run must additionally land on the kept pre-fault
/// monolith's exact numbers: the golden baseline is NOT re-seeded by this
/// feature.
#[test]
fn prop_lossy_sweep_bit_identical_and_loss_zero_reproduces_goldens() {
    let mut case_rng = Rng::new(0x1055);
    for case in 0..2u64 {
        let mut base = SimConfig::paper_default(3);
        base.workload.total_tasks = 36 + case_rng.below(17);
        base.workload.seed = 11_000 + case;
        // Smaller tiles keep the debug-mode render cost sane; identity is
        // independent of tile size.
        base.workload.raw_h = 32;
        base.workload.raw_w = 32;
        let backend = NativeBackend::new(&base);
        let wl = build_workload(&base);
        let prep = prepare(&backend, &wl).unwrap();
        for loss in [0.0f64, 0.05, 0.3] {
            let mut cfg = base.clone();
            cfg.comm.loss_prob = loss;
            if loss > 0.0 {
                // Chunk the ~20.5 MB records so loss, retransmission and
                // reassembly all trigger mid-record.
                cfg.comm.chunk_bytes = 6e6;
            }
            for scenario in Scenario::ALL {
                let single = Simulation::new(&cfg, &backend, scenario)
                    .with_workload(&wl)
                    .with_prepared(&prep)
                    .run()
                    .unwrap();
                if loss == 0.0 {
                    let golden = Simulation::new(&cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .run_reference()
                        .unwrap();
                    assert_reports_bit_identical(
                        &golden,
                        &single,
                        &format!("case {case} {scenario} loss=0 vs reference"),
                    );
                }
                for threads in [1usize, 2, 4] {
                    let sharded = Simulation::new(&cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .threads(threads)
                        .run()
                        .unwrap();
                    assert_reports_bit_identical(
                        &single,
                        &sharded,
                        &format!("case {case} {scenario} loss={loss} K={threads}"),
                    );
                }
            }
        }
    }
}

/// Degenerate contact plans are invisible. A Walker-mode topology at full
/// duty with no rate/latency modifiers is semantically static
/// (`TopologyConfig::is_dynamic()` is false), so it must land on the
/// static-grid goldens bit-for-bit — through the reference monolith, the
/// single-threaded engine and the sharded engine alike. The static grid
/// IS the always-on degenerate plan, not a parallel code path.
#[test]
fn prop_degenerate_walker_plan_reproduces_the_static_goldens() {
    for seed in [21_000u64, 21_001] {
        let mut base = SimConfig::paper_default(3);
        base.workload.total_tasks = 40;
        base.workload.seed = seed;
        base.workload.raw_h = 32;
        base.workload.raw_w = 32;
        let mut walker = base.clone();
        walker.topology.mode = TopologyMode::Walker;
        // duty stays 1.0 and no rate/latency modifiers: degenerate.
        let backend = NativeBackend::new(&base);
        let wl = build_workload(&base);
        let prep = prepare(&backend, &wl).unwrap();
        for scenario in Scenario::ALL {
            let golden = Simulation::new(&base, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run()
                .unwrap();
            assert_eq!(golden.handovers, 0, "static grid never hands over");
            assert_eq!(golden.stranded_chunks, 0, "static grid never strands");
            assert_eq!(golden.contact_utilization, 1.0, "{seed} {scenario}");
            let reference = Simulation::new(&walker, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run_reference()
                .unwrap();
            assert_reports_bit_identical(
                &golden,
                &reference,
                &format!("seed {seed} {scenario} degenerate walker reference"),
            );
            for threads in [1usize, 4] {
                let run = Simulation::new(&walker, &backend, scenario)
                    .with_workload(&wl)
                    .with_prepared(&prep)
                    .threads(threads)
                    .run()
                    .unwrap();
                assert_reports_bit_identical(
                    &golden,
                    &run,
                    &format!("seed {seed} {scenario} degenerate walker K={threads}"),
                );
            }
        }
    }
}

/// Time-varying contact plans keep the house invariant: across Walker
/// duty cycling, scripted mid-run outages, ground-station passes and
/// inter-plane rate/latency modifiers, the sharded engine's `RunReport`
/// is bit-identical to the single-threaded engine's for every scenario
/// and K ∈ {1, 2, 4}. The sweep also checks the dynamic machinery
/// actually engaged (some chunk waited for a window somewhere) so the
/// identity isn't vacuous.
#[test]
fn prop_dynamic_contact_plans_stay_bit_identical_across_shards() {
    let mut walker = SimConfig::paper_default(3);
    walker.workload.total_tasks = 36;
    walker.workload.seed = 31_000;
    walker.workload.raw_h = 32;
    walker.workload.raw_w = 32;
    walker.comm.chunk_bytes = 6e6;
    walker.topology.mode = TopologyMode::Walker;
    walker.topology.duty = 0.6;
    walker.topology.period_s = 30.0;

    // Second variant: outages that open and close mid-run, a ground
    // station stealing each satellite's radio periodically, and slowed
    // inter-plane links.
    let mut contested = walker.clone();
    contested.topology.outages =
        OutageSpec::parse_list("0-1@0..30,1-4@10..45").unwrap();
    contested.topology.ground_stations = 1;
    contested.topology.pass_period_s = 50.0;
    contested.topology.pass_duty = 0.1;
    contested.topology.inter_rate_scale = 0.8;
    contested.topology.inter_extra_latency_s = 0.01;

    let mut engaged = 0u64;
    for (variant, cfg) in [("walker", &walker), ("contested", &contested)] {
        let backend = NativeBackend::new(cfg);
        let wl = build_workload(cfg);
        let prep = prepare(&backend, &wl).unwrap();
        for scenario in Scenario::ALL {
            let single = Simulation::new(cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run()
                .unwrap();
            engaged += single.handovers + single.stranded_chunks;
            for threads in [1usize, 2, 4] {
                let sharded = Simulation::new(cfg, &backend, scenario)
                    .with_workload(&wl)
                    .with_prepared(&prep)
                    .threads(threads)
                    .run()
                    .unwrap();
                assert_reports_bit_identical(
                    &single,
                    &sharded,
                    &format!("{variant} {scenario} K={threads}"),
                );
            }
        }
    }
    assert!(
        engaged > 0,
        "no chunk ever waited for a contact window: the dynamic plan never \
         engaged and the sweep is vacuous"
    );
}

/// Shard partition is pure relabeling. Whether satellites map to shards
/// round-robin (`sat % K`) or as contiguous id blocks, the sharded
/// engine's `RunReport` — aggregates, per-satellite summaries, per-task
/// logs — is bit-identical to the single-threaded engine's, across every
/// scenario, K ∈ {1, 2, 4}, and both a static grid and a dynamic Walker
/// contact plan. The partition decides only which worker *executes* a
/// satellite; gate resolution and log folding run in global orders that
/// never observe shard ownership.
#[test]
fn prop_shard_partitions_are_pure_relabelings() {
    let mut grid = SimConfig::paper_default(3);
    grid.workload.total_tasks = 36;
    grid.workload.seed = 41_000;
    // Smaller tiles keep the debug-mode render cost sane; identity is
    // independent of tile size.
    grid.workload.raw_h = 32;
    grid.workload.raw_w = 32;

    let mut walker = grid.clone();
    walker.topology.mode = TopologyMode::Walker;
    walker.topology.duty = 0.6;
    walker.topology.period_s = 30.0;
    walker.comm.chunk_bytes = 6e6;

    for (variant, cfg) in [("grid", &grid), ("walker", &walker)] {
        let backend = NativeBackend::new(cfg);
        let wl = build_workload(cfg);
        let prep = prepare(&backend, &wl).unwrap();
        for scenario in Scenario::ALL {
            let single = Simulation::new(cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run()
                .unwrap();
            for part in [ShardPartition::RoundRobin, ShardPartition::Blocks] {
                for threads in [1usize, 2, 4] {
                    let sharded = Simulation::new(cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .threads(threads)
                        .partition(part)
                        .run()
                        .unwrap();
                    assert_reports_bit_identical(
                        &single,
                        &sharded,
                        &format!(
                            "{variant} {scenario} {} K={threads}",
                            part.name()
                        ),
                    );
                }
            }
        }
    }
}

/// Node-fault sweep: across workload seeds, crash intensities (off,
/// sparse, aggressive), both SCRT reboot policies (cold-start wipe and
/// persisted table), shard counts K ∈ {1, 2, 4} and every scenario, the
/// sharded engine's full `RunReport` — aggregates, fault counters,
/// per-satellite summaries, per-task logs — is bit-identical to the
/// single-threaded engine's. With faults off (`node_faults_active()` is
/// false) the run must additionally land on the reference monolith's
/// exact numbers: the fault machinery is invisible until switched on.
#[test]
fn prop_node_fault_sweep_bit_identical_and_fault_free_reproduces_goldens() {
    let mut case_rng = Rng::new(0xFA17);
    let mut crashes = 0u64;
    for case in 0..2u64 {
        let mut base = SimConfig::paper_default(3);
        base.workload.total_tasks = 36 + case_rng.below(17);
        base.workload.seed = 51_000 + case;
        // Smaller tiles keep the debug-mode render cost sane; identity is
        // independent of tile size.
        base.workload.raw_h = 32;
        base.workload.raw_w = 32;
        let backend = NativeBackend::new(&base);
        let wl = build_workload(&base);
        let prep = prepare(&backend, &wl).unwrap();
        // At 0.3 arrivals/s per satellite the ~40-task horizon is tens of
        // seconds, so per-satellite MTBFs of 40 s / 8 s yield a sparse and
        // an aggressive crash schedule inside the run.
        for mtbf in [f64::INFINITY, 40.0, 8.0] {
            let mut cfg = base.clone();
            cfg.faults.mtbf_s = mtbf;
            cfg.faults.downtime_s = 2.0;
            cfg.faults.collab_timeout_s = 1.5;
            // Alternate the reboot policy so both the cold-start wipe and
            // the persisted-SCRT paths are swept.
            cfg.faults.scrt_persist = case == 1;
            for scenario in Scenario::ALL {
                let single = Simulation::new(&cfg, &backend, scenario)
                    .with_workload(&wl)
                    .with_prepared(&prep)
                    .run()
                    .unwrap();
                crashes += single.crashes;
                if mtbf.is_infinite() {
                    assert_eq!(single.crashes, 0, "case {case} {scenario}");
                    let golden = Simulation::new(&cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .run_reference()
                        .unwrap();
                    assert_reports_bit_identical(
                        &golden,
                        &single,
                        &format!("case {case} {scenario} faults-off vs reference"),
                    );
                }
                for threads in [1usize, 2, 4] {
                    let sharded = Simulation::new(&cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .threads(threads)
                        .run()
                        .unwrap();
                    assert_reports_bit_identical(
                        &single,
                        &sharded,
                        &format!(
                            "case {case} {scenario} mtbf={mtbf} K={threads}"
                        ),
                    );
                }
            }
        }
    }
    assert!(
        crashes > 0,
        "no satellite ever crashed: the node-fault sweep is vacuous"
    );
}

/// Scripted crashes compose with everything else: a `--node-outages`
/// schedule that downs satellites mid-run stays bit-identical between
/// the single-threaded and sharded engines across every scenario,
/// K ∈ {1, 2, 4}, both shard partitions, and both a static grid and a
/// duty-cycled Walker contact plan (node faults stacked on top of link
/// windows). Every scripted span must actually fire — the crash counter
/// equals the schedule length, so the sweep can't silently go vacuous.
#[test]
fn prop_scripted_crashes_stay_bit_identical_across_shards_and_topologies() {
    let mut grid = SimConfig::paper_default(3);
    grid.workload.total_tasks = 40;
    grid.workload.seed = 61_000;
    // Smaller tiles keep the debug-mode render cost sane; identity is
    // independent of tile size.
    grid.workload.raw_h = 32;
    grid.workload.raw_w = 32;
    grid.faults.node_outages =
        NodeOutageSpec::parse_list("4@2..6,0@5..9,8@1..4").unwrap();
    grid.faults.collab_timeout_s = 1.5;

    let mut walker = grid.clone();
    walker.topology.mode = TopologyMode::Walker;
    walker.topology.duty = 0.6;
    walker.topology.period_s = 30.0;
    walker.comm.chunk_bytes = 6e6;
    // The Walker variant also wipes the SCRT on reboot so the cold-start
    // path is exercised under a dynamic contact plan.
    walker.faults.scrt_persist = false;
    grid.faults.scrt_persist = true;

    for (variant, cfg) in [("grid", &grid), ("walker", &walker)] {
        let backend = NativeBackend::new(cfg);
        let wl = build_workload(cfg);
        let prep = prepare(&backend, &wl).unwrap();
        for scenario in Scenario::ALL {
            let single = Simulation::new(cfg, &backend, scenario)
                .with_workload(&wl)
                .with_prepared(&prep)
                .run()
                .unwrap();
            assert_eq!(
                single.crashes, 3,
                "{variant} {scenario}: every scripted span fires once"
            );
            for part in [ShardPartition::RoundRobin, ShardPartition::Blocks] {
                for threads in [1usize, 2, 4] {
                    let sharded = Simulation::new(cfg, &backend, scenario)
                        .with_workload(&wl)
                        .with_prepared(&prep)
                        .threads(threads)
                        .partition(part)
                        .run()
                        .unwrap();
                    assert_reports_bit_identical(
                        &single,
                        &sharded,
                        &format!(
                            "{variant} {scenario} {} K={threads}",
                            part.name()
                        ),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SCRT invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_scrt_never_exceeds_capacity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let cap = 1 + rng.below(20);
        let buckets = 1 << (1 + rng.below(3));
        let mut scrt = Scrt::new(buckets, cap);
        for i in 0..cap * 3 {
            scrt.insert(rng.below(buckets) as u32, record(i, &mut rng));
            assert!(
                scrt.len() <= cap,
                "seed {seed}: len {} > cap {cap}",
                scrt.len()
            );
        }
        assert_eq!(scrt.len(), cap, "seed {seed}: table should be full");
    }
}

#[test]
fn prop_scrt_eviction_removes_minimum_value() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xE11C);
        let cap = 2 + rng.below(10);
        let mut scrt = Scrt::new(4, cap);
        for i in 0..cap {
            scrt.insert(rng.below(4) as u32, record(i, &mut rng));
        }
        // min (reuse_count, last_used) before the insert
        let min_key = scrt
            .iter()
            .map(|(_, r)| (r.reuse_count, r.last_used, r.id))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .unwrap();
        let evicted = scrt.insert(0, record(9999, &mut rng)).unwrap();
        assert_eq!(evicted, min_key.2, "seed {seed}: wrong victim");
    }
}

#[test]
fn prop_scrt_top_tau_sorted_and_bounded() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x70AA);
        let mut scrt = Scrt::new(4, 64);
        let count = rng.below(30);
        for i in 0..count {
            scrt.insert(rng.below(4) as u32, record(i, &mut rng));
        }
        let tau = 1 + rng.below(15);
        let top = scrt.top_tau(tau);
        assert!(top.len() <= tau.min(count));
        for w in top.windows(2) {
            assert!(
                w[0].1.reuse_count >= w[1].1.reuse_count,
                "seed {seed}: top_tau not sorted"
            );
        }
    }
}

#[test]
fn prop_scrt_nearest_is_exact_argmin() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x4EA2);
        let mut scrt = Scrt::new(2, 64);
        let count = 1 + rng.below(20);
        for i in 0..count {
            let mut r = record(i, &mut rng);
            r.task_type = 0;
            scrt.insert(0, r);
        }
        let probe = pre(&mut rng, 8);
        if let Some((slot, d)) = scrt.nearest(0, 0, &probe) {
            // brute force over the borrowed SoA views
            let best = scrt
                .iter()
                .filter(|(b, r)| *b == 0 && r.task_type == 0)
                .map(|(_, r)| {
                    r.pd
                        .iter()
                        .zip(&probe.pd)
                        .map(|(x, y)| (x - y) * (x - y))
                        .sum::<f32>()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(
                (d - best).abs() < 1e-5,
                "seed {seed}: nearest {d} != brute-force {best} (slot {slot})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Indexed SCRT ≡ naive reference model
// ---------------------------------------------------------------------------

/// Total-order value comparison `(reuse_count, last_used, id)` — the
/// ordering contract the indexed SCRT maintains (NaN-proof via
/// `f64::total_cmp`, deterministic id tie-break).
fn value_cmp(a: (u32, f64, usize), b: (u32, f64, usize)) -> std::cmp::Ordering {
    a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2))
}

/// Naive O(n) reference model of the SCRT: per-bucket `Vec<Record>` with
/// `swap_remove` eviction, whole-table victim scans and full sorts. The
/// indexed implementation must be behaviorally identical to this, slot
/// for slot.
struct NaiveScrt {
    buckets: Vec<Vec<Record>>,
    capacity: usize,
}

impl NaiveScrt {
    fn new(num_buckets: usize, capacity: usize) -> Self {
        NaiveScrt {
            buckets: vec![Vec::new(); num_buckets],
            capacity,
        }
    }

    fn len(&self) -> usize {
        self.buckets.iter().map(Vec::len).sum()
    }

    fn contains(&self, id: usize) -> bool {
        self.buckets.iter().any(|b| b.iter().any(|r| r.id == id))
    }

    fn nearest(
        &self,
        bucket: u32,
        task_type: u16,
        probe: &Preprocessed,
    ) -> Option<(usize, f32)> {
        let mut best: Option<(usize, f32)> = None;
        for (slot, r) in self.buckets[bucket as usize].iter().enumerate() {
            if r.task_type != task_type {
                continue;
            }
            let d: f32 = r
                .pre
                .pd
                .iter()
                .zip(&probe.pd)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((slot, d));
            }
        }
        best
    }

    fn insert(&mut self, bucket: u32, rec: Record) -> Option<usize> {
        let mut evicted = None;
        if self.len() >= self.capacity {
            let (bi, si, _) = self
                .buckets
                .iter()
                .enumerate()
                .flat_map(|(bi, b)| {
                    b.iter().enumerate().map(move |(si, r)| {
                        (bi, si, (r.reuse_count, r.last_used, r.id))
                    })
                })
                .min_by(|a, b| value_cmp(a.2, b.2))
                .expect("full table has a victim");
            let victim = self.buckets[bi].swap_remove(si);
            evicted = Some(victim.id);
        }
        self.buckets[bucket as usize].push(rec);
        evicted
    }

    fn mark_reused(&mut self, bucket: u32, slot: usize, now: f64) {
        let r = &mut self.buckets[bucket as usize][slot];
        r.reuse_count += 1;
        r.last_used = now;
    }

    fn merge_broadcast(&mut self, bucket: u32, mut rec: Record, now: f64) -> bool {
        if self.contains(rec.id) {
            return false;
        }
        rec.reuse_count = 0;
        rec.last_used = now;
        self.insert(bucket, rec);
        true
    }

    /// Top-τ record ids by descending `(reuse_count, last_used, id)`.
    fn top_tau(&self, tau: usize) -> Vec<(u32, usize)> {
        let mut all: Vec<(u32, (u32, f64, usize))> = self
            .buckets
            .iter()
            .enumerate()
            .flat_map(|(b, bucket)| {
                bucket
                    .iter()
                    .map(move |r| (b as u32, (r.reuse_count, r.last_used, r.id)))
            })
            .collect();
        all.sort_by(|a, b| value_cmp(b.1, a.1));
        all.truncate(tau);
        all.into_iter().map(|(b, key)| (b, key.2)).collect()
    }
}

/// Flatten both tables in (bucket, slot) order and compare every field,
/// including the SoA-stored feature vectors.
fn assert_tables_equal(seed: u64, step: usize, real: &Scrt, model: &NaiveScrt) {
    let real_flat: Vec<_> = real
        .iter()
        .map(|(b, v)| {
            (
                b,
                v.id,
                v.reuse_count,
                v.last_used,
                v.task_type,
                v.result,
                v.pd.to_vec(),
                v.gray.to_vec(),
            )
        })
        .collect();
    let model_flat: Vec<_> = model
        .buckets
        .iter()
        .enumerate()
        .flat_map(|(b, bucket)| {
            bucket.iter().map(move |r| {
                (
                    b as u32,
                    r.id,
                    r.reuse_count,
                    r.last_used,
                    r.task_type,
                    r.result,
                    r.pre.pd.clone(),
                    r.pre.gray.clone(),
                )
            })
        })
        .collect();
    assert_eq!(
        real_flat, model_flat,
        "seed {seed} step {step}: tables diverged"
    );
}

#[test]
fn prop_indexed_scrt_matches_naive_reference() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x1DE7);
        let cap = 2 + rng.below(12);
        let num_buckets = 1 << (1 + rng.below(3));
        let mut real = Scrt::new(num_buckets, cap);
        let mut model = NaiveScrt::new(num_buckets, cap);
        let mut next_id = 0usize;
        for step in 0..60 {
            match rng.below(6) {
                0 | 1 => {
                    // plain insert (Alg. 1 lines 5/14)
                    let r = record(next_id, &mut rng);
                    next_id += 1;
                    let b = rng.below(num_buckets) as u32;
                    let ev_real = real.insert(b, r.clone());
                    let ev_model = model.insert(b, r);
                    assert_eq!(
                        ev_real, ev_model,
                        "seed {seed} step {step}: eviction victims diverge"
                    );
                }
                2 => {
                    // NN probe, sometimes followed by a reuse hit
                    let b = rng.below(num_buckets) as u32;
                    let tt = rng.below(3) as u16;
                    let probe = pre(&mut rng, 8);
                    let got = real.nearest(b, tt, &probe);
                    let want = model.nearest(b, tt, &probe);
                    assert_eq!(got, want, "seed {seed} step {step}: nearest");
                    if let Some((slot, _)) = got {
                        let now = rng.f64() * 1e3;
                        real.mark_reused(b, slot, now);
                        model.mark_reused(b, slot, now);
                    }
                }
                3 => {
                    // broadcast merge, half the time a duplicate id
                    let dup = next_id > 0 && rng.below(2) == 0;
                    let id = if dup { rng.below(next_id) } else { next_id };
                    if !dup {
                        next_id += 1;
                    }
                    let r = record(id, &mut rng);
                    let b = rng.below(num_buckets) as u32;
                    let now = rng.f64() * 1e3;
                    assert_eq!(
                        real.merge_broadcast(b, &r, now),
                        model.merge_broadcast(b, r, now),
                        "seed {seed} step {step}: merge"
                    );
                }
                4 => {
                    // broadcast selection order
                    let tau = 1 + rng.below(8);
                    let got: Vec<(u32, usize)> = real
                        .top_tau(tau)
                        .iter()
                        .map(|(b, r)| (*b, r.id))
                        .collect();
                    assert_eq!(
                        got,
                        model.top_tau(tau),
                        "seed {seed} step {step}: top_tau"
                    );
                }
                _ => {
                    // identity probe
                    let id = rng.below(next_id.max(1));
                    assert_eq!(
                        real.contains(id),
                        model.contains(id),
                        "seed {seed} step {step}: contains"
                    );
                }
            }
            assert_tables_equal(seed, step, &real, &model);
        }
    }
}

/// Slot + distance-bit comparison for `nearest` results. Plain
/// `assert_eq!` on the `f32` would accept `-0.0 == 0.0`; the quantized
/// coarse path promises *bit* identity with the full scan, so that is
/// what gets checked.
fn assert_nearest_bits(
    got: Option<(usize, f32)>,
    want: Option<(usize, f32)>,
    label: &str,
) {
    match (got, want) {
        (None, None) => {}
        (Some((gs, gd)), Some((ws, wd))) => {
            assert_eq!(gs, ws, "{label}: slot diverged");
            assert_eq!(
                gd.to_bits(),
                wd.to_bits(),
                "{label}: distance bits diverged ({gd} vs {wd})"
            );
        }
        _ => panic!("{label}: presence diverged: {got:?} vs {want:?}"),
    }
}

/// The quantized coarse scan inside `Scrt::nearest` is bit-identical to
/// the naive full scan. Unlike `prop_indexed_scrt_matches_naive_reference`
/// (tiny buckets, so the ≥16-slot coarse gate never opens), this sweep
/// builds populous buckets at several feature dims and drives
/// insert/evict/merge/reuse churn plus probes that are hard on the error
/// bound: near-duplicate records 1e-6 apart, probes near those clusters,
/// and rows carrying non-finite values (which must force the exact-scan
/// fallback). Every probe must return the same slot and the same f32
/// *bits* as the naive reference, and the table contents must stay
/// field-identical after every mutation so quant-mirror bookkeeping can
/// never silently desynchronize record storage.
#[test]
fn prop_quantized_nearest_matches_naive_reference_bitwise() {
    let mut coarse_cases = 0usize;
    let mut hits = 0u64;
    let sweeps = CASES / 2;
    for seed in 0..sweeps {
        let mut rng = Rng::new(seed ^ 0x0A57);
        // `pre(dim)` stores a pd of 3×dim f32s — up to 360-wide rows.
        let dim = [8usize, 16, 40, 120][rng.below(4)];
        let num_buckets = 1 + rng.below(2);
        let cap = 24 + rng.below(40);
        let mut real = Scrt::new(num_buckets, cap);
        let mut model = NaiveScrt::new(num_buckets, cap);
        // Cluster center for near-duplicate records and probes.
        let base = pre(&mut rng, dim);
        let mut next_id = 0usize;

        let make_rec = |id: usize, rng: &mut Rng| -> Record {
            let mut p = match rng.below(3) {
                // near-duplicate of the cluster center, 1e-6 apart
                0 => {
                    let mut p = base.clone();
                    for v in p.pd.iter_mut() {
                        *v += (rng.f32() - 0.5) * 1e-6;
                    }
                    p
                }
                _ => pre(rng, dim),
            };
            if rng.below(24) == 0 {
                // non-finite row: quantization must flag it and the
                // whole lookup must fall back to the exact scan
                p.pd[0] = f32::INFINITY;
            }
            Record {
                id,
                pre: std::sync::Arc::new(p),
                task_type: rng.below(3) as u16,
                result: rng.below(21) as u32,
                reuse_count: rng.below(10) as u32,
                last_used: rng.f64() * 100.0,
                origin: rng.below(25),
            }
        };

        // Fill to capacity so the coarse gate opens, then churn.
        for _ in 0..cap {
            let r = make_rec(next_id, &mut rng);
            next_id += 1;
            let b = rng.below(num_buckets) as u32;
            let ev_real = real.insert(b, r.clone());
            let ev_model = model.insert(b, r);
            assert_eq!(ev_real, ev_model, "seed {seed} prefill: eviction");
        }
        let mut per_bucket = vec![0usize; num_buckets];
        for (b, _) in real.iter() {
            per_bucket[b as usize] += 1;
        }
        if per_bucket.iter().any(|&n| n >= 16) {
            coarse_cases += 1;
        }

        for step in 0..60 {
            match rng.below(5) {
                0 => {
                    // evicting insert
                    let r = make_rec(next_id, &mut rng);
                    next_id += 1;
                    let b = rng.below(num_buckets) as u32;
                    let ev_real = real.insert(b, r.clone());
                    let ev_model = model.insert(b, r);
                    assert_eq!(
                        ev_real, ev_model,
                        "seed {seed} step {step}: eviction"
                    );
                }
                1 => {
                    // broadcast merge, half the time a duplicate id
                    let dup = rng.below(2) == 0;
                    let id = if dup { rng.below(next_id) } else { next_id };
                    if !dup {
                        next_id += 1;
                    }
                    let r = make_rec(id, &mut rng);
                    let b = rng.below(num_buckets) as u32;
                    let now = rng.f64() * 1e3;
                    assert_eq!(
                        real.merge_broadcast(b, &r, now),
                        model.merge_broadcast(b, r, now),
                        "seed {seed} step {step}: merge"
                    );
                }
                _ => {
                    // probe: random, or aimed at the near-duplicate
                    // cluster where coarse bounds are tightest
                    let probe = if rng.below(2) == 0 {
                        let mut p = base.clone();
                        for v in p.pd.iter_mut() {
                            *v += (rng.f32() - 0.5) * 2e-6;
                        }
                        p
                    } else {
                        pre(&mut rng, dim)
                    };
                    let b = rng.below(num_buckets) as u32;
                    let tt = rng.below(3) as u16;
                    let got = real.nearest(b, tt, &probe);
                    let want = model.nearest(b, tt, &probe);
                    assert_nearest_bits(
                        got,
                        want,
                        &format!("seed {seed} step {step}"),
                    );
                    if let Some((slot, _)) = got {
                        hits += 1;
                        let now = rng.f64() * 1e3;
                        real.mark_reused(b, slot, now);
                        model.mark_reused(b, slot, now);
                    }
                }
            }
            assert_tables_equal(seed, step, &real, &model);
        }
    }
    // Non-vacuity: most cases must actually open the ≥16-slot coarse
    // gate, and plenty of probes must land on real records.
    assert!(
        coarse_cases * 2 >= sweeps as usize,
        "coarse gate opened in only {coarse_cases}/{sweeps} cases"
    );
    assert!(hits > sweeps * 10, "only {hits} probe hits: sweep is vacuous");
}

// ---------------------------------------------------------------------------
// SRS / Alg. 2 invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_srs_bounded_and_monotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x55AA);
        let beta = rng.f64();
        let rr = rng.f64();
        let cpu = rng.f64();
        let v = srs(beta, rr, cpu);
        assert!((0.0..=1.0).contains(&v), "seed {seed}: srs {v}");
        // raising rr never lowers SRS; raising cpu never raises it
        assert!(srs(beta, (rr + 0.1).min(1.0), cpu) >= v - 1e-12);
        assert!(srs(beta, rr, (cpu + 0.1).min(1.0)) <= v + 1e-12);
    }
}

#[test]
fn prop_select_source_respects_threshold_and_membership() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let n = 3 + rng.below(6);
        let topo = GridTopology::new(n);
        let srs_values: Vec<f64> = (0..topo.len()).map(|_| rng.f64()).collect();
        let req = rng.below(topo.len());
        let th = rng.f64();
        for policy in [
            AreaPolicy::InitialOnly,
            AreaPolicy::WithExpansion,
            AreaPolicy::GlobalSrsPriority,
        ] {
            if let Some(d) = select_source(&topo, req, &srs_values, th, policy) {
                assert_ne!(d.source, req, "seed {seed}: self-serve");
                assert!(d.area.contains(&d.source), "seed {seed}: source outside area");
                assert!(d.area.contains(&req), "seed {seed}: requester outside area");
                if policy != AreaPolicy::GlobalSrsPriority {
                    assert!(
                        srs_values[d.source] > th,
                        "seed {seed}: source below threshold"
                    );
                    // source is the max over its area (minus requester)
                    let max = d
                        .area
                        .iter()
                        .filter(|&&s| s != req)
                        .map(|&s| srs_values[s])
                        .fold(f64::NEG_INFINITY, f64::max);
                    assert!(srs_values[d.source] >= max - 1e-12);
                }
            } else if policy == AreaPolicy::WithExpansion {
                // termination implies nobody in the expanded area clears th
                let expanded = topo.expand_area(&topo.area(req, 1));
                for &s in &expanded {
                    if s != req {
                        assert!(
                            srs_values[s] <= th,
                            "seed {seed}: viable source missed"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_expanded_area_contains_initial() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xA5EA);
        let n = 2 + rng.below(8);
        let topo = GridTopology::new(n);
        let center = rng.below(topo.len());
        let initial = topo.area(center, 1);
        let expanded = topo.expand_area(&initial);
        for s in &initial {
            assert!(expanded.contains(s), "seed {seed}: expansion lost a member");
        }
        assert!(expanded.len() >= initial.len());
        assert!(expanded.len() <= topo.len());
    }
}

// ---------------------------------------------------------------------------
// Communication-model invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_broadcast_plan_consistent() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xB10C);
        let n = 3 + rng.below(6);
        let cfg = SimConfig::paper_default(n);
        let topo = GridTopology::new(n);
        let comm = CommModel::new(&cfg.network, &cfg.comm);
        let src = rng.below(topo.len());
        let radius = 1 + rng.below(2);
        let area = topo.area(src, radius);
        let records = 1 + rng.below(15);
        let plan = comm.plan_broadcast(&topo, src, &area, records);
        // bytes = records × (|area|-1) × record size
        let want = records as f64 * comm.record_bytes() * (area.len() - 1) as f64;
        assert!(
            (plan.bytes - want).abs() < 1.0,
            "seed {seed}: plan bytes {} != {want}",
            plan.bytes
        );
        assert!(plan.airtime_s > 0.0);
        assert_eq!(plan.arrivals.len(), area.len() - 1);
        // arrivals are monotone in k and depth
        for &(m, depth) in &plan.arrivals {
            assert!(depth >= 1 && m != src);
            assert!(plan.arrival_offset(1, depth) > plan.arrival_offset(0, depth));
        }
        // completion covers every arrival
        let done = plan.completion_offset(records);
        for &(_, depth) in &plan.arrivals {
            assert!(plan.arrival_offset(records - 1, depth) <= done + 1e-9);
        }
    }
}

#[test]
fn prop_delivery_time_increases_with_distance_same_plane() {
    // Monotonicity only holds along paths of one hop type (intra- and
    // inter-plane links run at different rates), so compare within a row.
    let cfg = SimConfig::paper_default(7);
    let topo = GridTopology::new(7);
    let comm = CommModel::new(&cfg.network, &cfg.comm);
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD157);
        let orbit = rng.below(7);
        let src = topo.sat_at(orbit, rng.below(7));
        let mut slots: Vec<usize> = (0..7).collect();
        slots.sort_by_key(|&s| topo.hops(src, topo.sat_at(orbit, s)));
        let mut prev = 0.0;
        for &s in &slots {
            let d = comm.delivery_seconds(&topo, src, topo.sat_at(orbit, s), 3);
            assert!(d + 1e-9 >= prev, "seed {seed}: not monotone in-plane");
            prev = d;
        }
    }
}
