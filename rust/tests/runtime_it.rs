//! Integration tests over the real PJRT runtime + artifacts.
//!
//! These need `make artifacts`; every test skips cleanly (with a note)
//! when the artifacts directory is absent so `cargo test` stays green on a
//! fresh checkout.

use ccrsat::compute::{ComputeBackend, NativeBackend, PjrtBackend};
use ccrsat::config::SimConfig;
use ccrsat::runtime::{Engine, Tensor};
use ccrsat::util::rng::Rng;
use ccrsat::workload::texture::{SceneSpec, TextureSynth};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    // tests run from the crate root
    let p = std::path::PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(p) => p,
            None => return,
        }
    };
}

#[test]
fn engine_loads_and_warms_all_artifacts() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    engine.warmup().unwrap();
    let stats = engine.stats();
    assert_eq!(stats.compiles as usize, engine.manifest().entries.len());
    assert!(engine.platform_name().to_lowercase().contains("cpu"));
}

#[test]
fn preprocess_artifact_matches_native() {
    let dir = require_artifacts!();
    let cfg = SimConfig::paper_default(5);
    let pjrt = PjrtBackend::from_dir(&dir).unwrap();
    let native = NativeBackend::new(&cfg);
    let synth = TextureSynth::new(64, 64, 0.02);
    for seed in 0..4 {
        let scene = SceneSpec::sample(seed, (seed % 21) as u16, &mut Rng::new(seed as u64));
        let img = synth.render(&scene, &mut Rng::new(100 + seed as u64));
        let a = pjrt.preprocess(&img).unwrap();
        let b = native.preprocess(&img).unwrap();
        assert_eq!(a.pd.len(), b.pd.len());
        for (x, y) in a.pd.iter().zip(&b.pd) {
            assert!((x - y).abs() < 1e-4, "pd mismatch {x} vs {y}");
        }
        for (x, y) in a.gray.iter().zip(&b.gray) {
            assert!((x - y).abs() < 1e-4, "gray mismatch {x} vs {y}");
        }
    }
}

#[test]
fn ssim_artifact_matches_native_formula() {
    let dir = require_artifacts!();
    let cfg = SimConfig::paper_default(5);
    let pjrt = PjrtBackend::from_dir(&dir).unwrap();
    let native = NativeBackend::new(&cfg);
    let synth = TextureSynth::new(64, 64, 0.02);
    for seed in 0..4u64 {
        let s1 = SceneSpec::sample(0, (seed % 21) as u16, &mut Rng::new(seed));
        let s2 = SceneSpec::sample(1, ((seed + 9) % 21) as u16, &mut Rng::new(seed + 1));
        let pa = pjrt
            .preprocess(&synth.render(&s1, &mut Rng::new(10 + seed)))
            .unwrap();
        let pb = pjrt
            .preprocess(&synth.render(&s2, &mut Rng::new(20 + seed)))
            .unwrap();
        let v_pjrt = pjrt.ssim(&pa, &pb).unwrap();
        let v_native = native.ssim(&pa, &pb).unwrap();
        assert!(
            (v_pjrt - v_native).abs() < 1e-3,
            "ssim mismatch: pjrt {v_pjrt} vs native {v_native}"
        );
        // self-similarity is exactly 1
        assert!((pjrt.ssim(&pa, &pa).unwrap() - 1.0).abs() < 1e-4);
    }
}

#[test]
fn classifier_single_and_batch_agree() {
    let dir = require_artifacts!();
    let pjrt = PjrtBackend::from_dir(&dir).unwrap();
    let synth = TextureSynth::new(64, 64, 0.02);
    let pres: Vec<_> = (0..5u64)
        .map(|seed| {
            let s = SceneSpec::sample(seed as u32, (seed % 21) as u16, &mut Rng::new(seed));
            pjrt.preprocess(&synth.render(&s, &mut Rng::new(seed + 50)))
                .unwrap()
        })
        .collect();
    let singles: Vec<u32> = pres.iter().map(|p| pjrt.classify(p).unwrap()).collect();
    let refs: Vec<&_> = pres.iter().collect();
    let batch = pjrt.classify_many(&refs).unwrap();
    assert_eq!(singles, batch, "batched classifier must match single calls");
    assert!(singles.iter().all(|&l| l < 21));
}

#[test]
fn classifier_is_deterministic_and_capture_stable() {
    let dir = require_artifacts!();
    let pjrt = PjrtBackend::from_dir(&dir).unwrap();
    let synth = TextureSynth::new(64, 64, 0.004);
    let mut stable = 0;
    let total = 8;
    for seed in 0..total {
        let s = SceneSpec::sample(seed as u32, (seed % 21) as u16, &mut Rng::new(seed as u64));
        let p1 = pjrt
            .preprocess(&synth.render(&s, &mut Rng::new(seed as u64 + 100)))
            .unwrap();
        let p2 = pjrt
            .preprocess(&synth.render(&s, &mut Rng::new(seed as u64 + 200)))
            .unwrap();
        assert_eq!(
            pjrt.classify(&p1).unwrap(),
            pjrt.classify(&p1).unwrap(),
            "same input must classify identically"
        );
        if pjrt.classify(&p1).unwrap() == pjrt.classify(&p2).unwrap() {
            stable += 1;
        }
    }
    assert!(
        stable >= total - 1,
        "labels unstable across captures: {stable}/{total}"
    );
}

#[test]
fn pjrt_backend_passes_shared_conformance() {
    let dir = require_artifacts!();
    let pjrt = PjrtBackend::from_dir(&dir).unwrap();
    // Same checks NativeBackend passes in unit tests.
    let synth = TextureSynth::new(64, 64, 0.05);
    let scene_a = SceneSpec::sample(0, 2, &mut Rng::new(1));
    let img_a1 = synth.render(&scene_a, &mut Rng::new(10));
    let img_a2 = synth.render(&scene_a, &mut Rng::new(11));
    let pa1 = pjrt.preprocess(&img_a1).unwrap();
    let pa2 = pjrt.preprocess(&img_a2).unwrap();
    assert!(pjrt.ssim(&pa1, &pa2).unwrap() > 0.7);
    assert_eq!(pjrt.lsh_bucket(&pa1).unwrap(), pjrt.lsh_bucket(&pa2).unwrap());
    assert!((pjrt.lsh_bucket(&pa1).unwrap() as usize) < pjrt.num_buckets());
}

#[test]
fn engine_rejects_wrong_shapes() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let wrong = Tensor::f32(vec![8, 8, 3], vec![0.0; 192]).unwrap();
    assert!(engine.execute("preprocess", &[wrong]).is_err());
    let ok_shape = Tensor::f32(vec![64, 64, 3], vec![0.0; 64 * 64 * 3]).unwrap();
    assert!(engine.execute("preprocess", &[ok_shape.clone(), ok_shape]).is_err());
}

#[test]
fn full_sim_on_pjrt_backend_smoke() {
    let dir = require_artifacts!();
    use ccrsat::coordinator::Scenario;
    use ccrsat::simulator::Simulation;
    let mut cfg = SimConfig::paper_default(3);
    cfg.workload.total_tasks = 36;
    let backend = PjrtBackend::from_dir(&dir).unwrap();
    let slcr = Simulation::new(&cfg, &backend, Scenario::Slcr).run().unwrap();
    let scratch = Simulation::new(&cfg, &backend, Scenario::WithoutCr)
        .run()
        .unwrap();
    assert_eq!(slcr.total_tasks, 36);
    assert!(slcr.reused_tasks > 0);
    assert!(slcr.completion_time < scratch.completion_time);
}
