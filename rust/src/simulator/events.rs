//! Discrete-event queue on the virtual clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::scrt::Record;
use crate::workload::SatId;

/// Event payloads.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A task arrives at its satellite (index into the workload task vec).
    Arrival(usize),
    /// The satellite's in-flight task completes.
    Completion(SatId),
    /// One broadcast record reaches a destination satellite. Broadcasts are
    /// *streamed*: record `k` of a τ-record share arrives after `k+1`
    /// payload transmission times, so receivers start benefiting before the
    /// whole share lands. The payload is `Arc`-shared across the fan-out so
    /// the whole engine state is `Send` (a future sharded/parallel engine
    /// will not need an event-type rewrite).
    BroadcastDeliver {
        dst: SatId,
        /// LSH bucket of the record (identical hyperplanes fleet-wide).
        bucket: u32,
        record: std::sync::Arc<Record>,
    },
    /// One chunk of a lossy, chunked broadcast reaches a destination. The
    /// record only becomes usable (merged into the destination SCRT) once
    /// the satellite's reassembly state reports all `total_chunks` pieces
    /// present; duplicates and out-of-order arrivals are absorbed there.
    ChunkDeliver {
        dst: SatId,
        bucket: u32,
        record: std::sync::Arc<Record>,
        chunk_seq: usize,
        total_chunks: usize,
    },
    /// A retransmission timeout fires at the broadcast source: one chunk
    /// attempt was lost or corrupted. `dropped` marks retry exhaustion —
    /// the chunk is abandoned for this transfer.
    LinkTimeout { src: SatId, dropped: bool },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    pub time: f64,
    /// Tie-breaker: events at equal times fire in schedule order.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event pops first.
        // Time is keyed through the IEEE-754 total order (`f64::total_cmp`,
        // the same remedy as the SCRT recency index): a NaN time is still a
        // scheduling bug (the `debug_assert` in `push` catches it in debug
        // builds), but it can no longer panic a release run mid-simulation
        // — it simply orders at the extremes of the time axis.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// The earliest queued event without popping it — the sharded engine
    /// peeks to decide whether the head still falls inside the current
    /// conservative window.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::Completion(0));
        q.push(1.0, EventKind::Completion(1));
        q.push(2.0, EventKind::Completion(2));
        assert_eq!(q.peek().map(|e| e.time), Some(1.0));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert!(q.peek().is_none());
    }

    #[test]
    fn equal_times_fifo_by_seq() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Completion(10));
        q.push(1.0, EventKind::Completion(20));
        q.push(1.0, EventKind::Completion(30));
        let sats: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Completion(s) => s,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(sats, vec![10, 20, 30]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn push_rejects_nan_time_in_debug() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventKind::Completion(0));
    }

    #[test]
    fn nan_event_time_orders_totally_without_panic() {
        // Regression: `Event::cmp` used `partial_cmp().expect(..)`, so one
        // NaN time panicked a release run (where the push-side debug_assert
        // is compiled out). The total-order comparator must instead give
        // NaN a deterministic place at the extremes of the time axis.
        let mk = |time: f64, seq: u64| Event {
            time,
            seq,
            kind: EventKind::Completion(0),
        };
        // Sign-controlled NaNs: `f64::NAN`'s sign bit is unspecified, so
        // pin it explicitly with copysign.
        let pos_nan = f64::NAN.copysign(1.0);
        let neg_nan = f64::NAN.copysign(-1.0);
        let mut heap = BinaryHeap::new();
        heap.push(mk(pos_nan, 0));
        heap.push(mk(1.0, 1));
        heap.push(mk(f64::NEG_INFINITY, 2));
        heap.push(mk(neg_nan, 3));
        let order: Vec<u64> =
            std::iter::from_fn(|| heap.pop().map(|e| e.seq)).collect();
        // IEEE-754 total order: -NaN < -inf < 1.0 < +NaN.
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn equal_nan_times_still_fifo_by_seq() {
        let mk = |seq: u64| Event {
            time: f64::NAN,
            seq,
            kind: EventKind::Completion(0),
        };
        let mut heap = BinaryHeap::new();
        heap.push(mk(2));
        heap.push(mk(0));
        heap.push(mk(1));
        let order: Vec<u64> =
            std::iter::from_fn(|| heap.pop().map(|e| e.seq)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }
}
