//! Discrete-event queue on the virtual clock.
//!
//! Since PR 9 the queue is a two-level *calendar* (bucketed/ladder) queue:
//! a ring of near-future time buckets absorbs the dense head of the
//! schedule with O(1) amortized push/pop, and a far-future overflow heap
//! holds everything past the calendar horizon. The pop order is the same
//! total order the old `BinaryHeap` used — `(f64::total_cmp(time), seq)` —
//! and because that order is *total* (unique `seq` tie-break), any correct
//! priority queue pops the identical sequence: bucketing is an indexing
//! strategy, never an ordering authority (the head-bucket/overflow
//! comparison at pop time is what decides).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::scrt::Record;
use crate::workload::SatId;

/// Event payloads.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A task arrives at its satellite (index into the workload task vec).
    Arrival(usize),
    /// The satellite's in-flight task completes. `task` is the workload
    /// index the completion was scheduled for: a crash drops the in-flight
    /// task but cannot unschedule this event, so the handler ignores a
    /// completion whose task no longer matches the satellite's in-flight
    /// state (lazy cancellation — a dropped task is never re-served, so
    /// the match is unique).
    Completion { sat: SatId, task: usize },
    /// The satellite crashes: its in-flight task and queue are lost and
    /// (under the wipe policy) the SCRT is cleared. Pre-seeded from the
    /// [`crate::network::NodeFaultPlan`] at run start.
    CrashAt(SatId),
    /// A crashed satellite reboots and resumes accepting tasks.
    RebootAt(SatId),
    /// A failover response timeout fires at a requester whose selected
    /// collaboration source died before answering: attempt `attempt` of
    /// the failover cascade is declared failed. `fallback` marks retry
    /// exhaustion — the requester degrades to local compute.
    CollabTimeout {
        /// The waiting requester.
        req: SatId,
        /// Zero-based failover attempt index that just timed out.
        attempt: usize,
        /// Final attempt: no further source is tried.
        fallback: bool,
    },
    /// One broadcast record reaches a destination satellite. Broadcasts are
    /// *streamed*: record `k` of a τ-record share arrives after `k+1`
    /// payload transmission times, so receivers start benefiting before the
    /// whole share lands. The payload is `Arc`-shared across the fan-out so
    /// the whole engine state is `Send` (a future sharded/parallel engine
    /// will not need an event-type rewrite).
    BroadcastDeliver {
        dst: SatId,
        /// LSH bucket of the record (identical hyperplanes fleet-wide).
        bucket: u32,
        record: std::sync::Arc<Record>,
    },
    /// One chunk of a lossy, chunked broadcast reaches a destination. The
    /// record only becomes usable (merged into the destination SCRT) once
    /// the satellite's reassembly state reports all `total_chunks` pieces
    /// present; duplicates and out-of-order arrivals are absorbed there.
    ChunkDeliver {
        dst: SatId,
        bucket: u32,
        record: std::sync::Arc<Record>,
        chunk_seq: usize,
        total_chunks: usize,
    },
    /// A retransmission timeout fires at the broadcast source: one chunk
    /// attempt was lost or corrupted. `dropped` marks retry exhaustion —
    /// the chunk is abandoned for this transfer.
    LinkTimeout { src: SatId, dropped: bool },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    pub time: f64,
    /// Tie-breaker: events at equal times fire in schedule order.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event pops first.
        // Time is keyed through the IEEE-754 total order (`f64::total_cmp`,
        // the same remedy as the SCRT recency index): a NaN time is still a
        // scheduling bug (the `debug_assert` in `push` catches it in debug
        // builds), but it can no longer panic a release run mid-simulation
        // — it simply orders at the extremes of the time axis.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Number of calendar buckets. A power of two so the logical-slot →
/// physical-index map is a mask instead of a modulo.
const NUM_BUCKETS: usize = 256;
/// Bounds for the adaptive bucket width (seconds of virtual time).
const MIN_WIDTH: f64 = 1e-9;
const MAX_WIDTH: f64 = 1e9;
/// Pop-gap samples required before the width re-adapts.
const ADAPT_SAMPLES: u64 = 64;

/// Two-level calendar/ladder event queue with total-order pop.
///
/// Level 1 is a ring of [`NUM_BUCKETS`] buckets covering the logical slots
/// `[cur_slot, cur_slot + NUM_BUCKETS)`, where `slot(t) = ⌊t / width⌋` —
/// division by a positive constant, `floor` and the saturating `as i64`
/// cast are each monotone, so bucket order respects time order for every
/// finite time. Level 2 is the old inverted-`Ord` `BinaryHeap`, holding
/// events past the calendar horizon and every non-finite time (whose slot
/// is meaningless). Pops compare the head-bucket minimum against the
/// overflow minimum under `(total_cmp(time), seq)`, so the popped sequence
/// is bit-identical to the plain heap's by construction; the head bucket
/// is sorted lazily (descending, pop from the back) and pushes into a
/// sorted head binary-insert to keep it sorted. The bucket width adapts to
/// the observed mean pop gap, but only while the calendar is empty, so a
/// width change can never re-map a live event.
#[derive(Debug)]
pub struct EventQueue {
    /// Physical bucket ring; index = `slot & (NUM_BUCKETS - 1)`.
    buckets: Vec<Vec<Event>>,
    /// Events in `buckets` (the overflow heap tracks its own length).
    in_buckets: usize,
    /// First logical slot the calendar covers. Past events (slot <
    /// `cur_slot`) clamp into the head bucket, which stays correct because
    /// the pop comparison — not the bucketing — decides order.
    cur_slot: i64,
    /// Whether the head bucket is currently sorted descending by
    /// `(time, seq)` (earliest at the back).
    head_sorted: bool,
    /// Virtual seconds per calendar bucket.
    width: f64,
    /// Far-future + non-finite-time events, earliest first (inverted Ord).
    overflow: BinaryHeap<Event>,
    next_seq: u64,
    /// Pop-gap statistics feeding the width adaptation.
    last_pop_time: f64,
    gap_sum: f64,
    gap_count: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue {
            buckets: vec![Vec::new(); NUM_BUCKETS],
            in_buckets: 0,
            cur_slot: 0,
            head_sorted: false,
            width: 1.0,
            overflow: BinaryHeap::new(),
            next_seq: 0,
            last_pop_time: f64::NAN,
            gap_sum: 0.0,
            gap_count: 0,
        }
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// The natural (pop) order: ascending time under the IEEE total order,
    /// then schedule order. The inverse of `Event::cmp` (which is inverted
    /// for the max-heap).
    fn natural(a: &Event, b: &Event) -> Ordering {
        a.time.total_cmp(&b.time).then(a.seq.cmp(&b.seq))
    }

    fn earlier(a: &Event, b: &Event) -> bool {
        Self::natural(a, b) == Ordering::Less
    }

    fn phys(slot: i64) -> usize {
        (slot & (NUM_BUCKETS as i64 - 1)) as usize
    }

    fn slot_of(&self, time: f64) -> i64 {
        // Saturating f64 → i64 cast: monotone at the extremes, and any
        // saturated slot lands past the horizon check into the overflow
        // heap, where ordering is the heap's business.
        (time / self.width).floor() as i64
    }

    fn horizon(&self) -> i64 {
        self.cur_slot.saturating_add(NUM_BUCKETS as i64)
    }

    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.push_unchecked(time, kind);
    }

    /// `push` without the finiteness debug assertion. Non-finite times are
    /// a scheduling bug, but the queue must order them deterministically
    /// rather than panic a release run; tests drive this path directly.
    fn push_unchecked(&mut self, time: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let ev = Event { time, seq, kind };
        if !time.is_finite() {
            // A non-finite slot is meaningless: the overflow heap orders
            // these through the same total order as everything else.
            self.overflow.push(ev);
            return;
        }
        if self.in_buckets == 0 {
            self.re_anchor(time);
        }
        if self.slot_of(time) >= self.horizon() {
            self.overflow.push(ev);
            return;
        }
        self.bucket_insert(ev);
    }

    /// Place a finite-time event whose slot is below the horizon. Past
    /// events (slot < `cur_slot`) clamp into the head bucket: they still
    /// pop first there, because the head bucket is ordered internally and
    /// every later bucket holds strictly later times.
    fn bucket_insert(&mut self, ev: Event) {
        let slot = self.slot_of(ev.time).max(self.cur_slot);
        debug_assert!(slot < self.horizon());
        let head = slot == self.cur_slot;
        let bucket = &mut self.buckets[Self::phys(slot)];
        if head && self.head_sorted {
            // Keep the sorted head sorted: descending, so find the first
            // strictly-earlier element and insert before it (equal times
            // have lower seqs, which are earlier — FIFO preserved).
            let at = bucket.partition_point(|e| Self::earlier(&ev, e));
            bucket.insert(at, ev);
        } else {
            bucket.push(ev);
        }
        self.in_buckets += 1;
    }

    /// Reset the calendar origin. Only legal while the calendar is empty —
    /// the one moment the bucket width may also adapt, since no live event
    /// can be re-mapped by either change.
    fn re_anchor(&mut self, time: f64) {
        debug_assert_eq!(self.in_buckets, 0);
        if self.gap_count >= ADAPT_SAMPLES {
            let avg = self.gap_sum / self.gap_count as f64;
            if avg.is_finite() && avg > 0.0 {
                // Aim for a couple of events per bucket.
                self.width = (avg * 2.0).clamp(MIN_WIDTH, MAX_WIDTH);
            }
            self.gap_sum = 0.0;
            self.gap_count = 0;
        }
        self.cur_slot = self.slot_of(time);
        self.head_sorted = false;
    }

    /// With the calendar empty, pull overflow events below the (re-anchored)
    /// horizon back into buckets so they pop at calendar cost. Stops at the
    /// first non-finite or beyond-horizon head; a non-finite overflow
    /// minimum simply stays in the heap and wins pops by comparison.
    fn migrate_overflow(&mut self) {
        debug_assert_eq!(self.in_buckets, 0);
        let anchor = match self.overflow.peek() {
            Some(ev) if ev.time.is_finite() => ev.time,
            _ => return,
        };
        self.re_anchor(anchor);
        while let Some(ev) = self.overflow.peek() {
            if !ev.time.is_finite() || self.slot_of(ev.time) >= self.horizon() {
                break;
            }
            let ev = self.overflow.pop().expect("peeked overflow event");
            self.bucket_insert(ev);
        }
    }

    /// Advance `cur_slot` to the first non-empty bucket and sort it
    /// (descending) if a push unsorted it. Requires `in_buckets > 0`, which
    /// bounds the scan: every bucketed event lives in the current window.
    fn advance_head(&mut self) {
        debug_assert!(self.in_buckets > 0);
        if self.buckets[Self::phys(self.cur_slot)].is_empty() {
            for _ in 0..NUM_BUCKETS {
                self.cur_slot = self.cur_slot.saturating_add(1);
                self.head_sorted = false;
                if !self.buckets[Self::phys(self.cur_slot)].is_empty() {
                    break;
                }
            }
        }
        debug_assert!(!self.buckets[Self::phys(self.cur_slot)].is_empty());
        if !self.head_sorted {
            self.buckets[Self::phys(self.cur_slot)]
                .sort_unstable_by(|a, b| Self::natural(b, a));
            self.head_sorted = true;
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        if self.in_buckets == 0 {
            if self.overflow.is_empty() {
                return None;
            }
            self.migrate_overflow();
        }
        if self.in_buckets > 0 {
            self.advance_head();
        }
        // The authoritative comparison: head-bucket minimum (back of the
        // sorted head) vs overflow minimum, under the same total order the
        // plain heap used — bucketing never decides, only indexes.
        let take_head = {
            let head = if self.in_buckets > 0 {
                self.buckets[Self::phys(self.cur_slot)].last()
            } else {
                None
            };
            match (head, self.overflow.peek()) {
                (Some(h), Some(o)) => Self::earlier(h, o),
                (Some(_), None) => true,
                (None, _) => false,
            }
        };
        let ev = if take_head {
            self.in_buckets -= 1;
            self.buckets[Self::phys(self.cur_slot)]
                .pop()
                .expect("non-empty head bucket")
        } else {
            self.overflow.pop()?
        };
        if ev.time.is_finite() {
            if self.last_pop_time.is_finite() {
                let gap = ev.time - self.last_pop_time;
                if gap.is_finite() && gap > 0.0 {
                    self.gap_sum += gap;
                    self.gap_count += 1;
                }
            }
            self.last_pop_time = ev.time;
        }
        Some(ev)
    }

    /// The earliest queued event without popping it — the sharded engine
    /// peeks to decide whether the head still falls inside the current
    /// conservative window. Read-only: an unsorted head bucket is scanned
    /// linearly instead of being sorted in place (on the pop-then-peek
    /// pattern the engines use, the head is already sorted and this is the
    /// O(1) back-of-bucket read).
    pub fn peek(&self) -> Option<&Event> {
        let head = self.calendar_min();
        match (head, self.overflow.peek()) {
            (Some(h), Some(o)) => Some(if Self::earlier(h, o) { h } else { o }),
            (Some(h), None) => Some(h),
            (None, o) => o,
        }
    }

    /// The earliest calendar event, without mutating (`peek` support).
    fn calendar_min(&self) -> Option<&Event> {
        if self.in_buckets == 0 {
            return None;
        }
        let mut slot = self.cur_slot;
        for _ in 0..NUM_BUCKETS {
            let bucket = &self.buckets[Self::phys(slot)];
            if !bucket.is_empty() {
                return if slot == self.cur_slot && self.head_sorted {
                    bucket.last()
                } else {
                    bucket.iter().min_by(|a, b| Self::natural(a, b))
                };
            }
            slot = slot.saturating_add(1);
        }
        debug_assert!(false, "in_buckets > 0 but no bucket holds an event");
        None
    }

    pub fn len(&self) -> usize {
        self.in_buckets + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::CrashAt(0));
        q.push(1.0, EventKind::CrashAt(1));
        q.push(2.0, EventKind::CrashAt(2));
        assert_eq!(q.peek().map(|e| e.time), Some(1.0));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert!(q.peek().is_none());
    }

    #[test]
    fn equal_times_fifo_by_seq() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::CrashAt(10));
        q.push(1.0, EventKind::CrashAt(20));
        q.push(1.0, EventKind::CrashAt(30));
        let sats: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::CrashAt(s) => s,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(sats, vec![10, 20, 30]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn push_rejects_nan_time_in_debug() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventKind::CrashAt(0));
    }

    #[test]
    fn nan_event_time_orders_totally_without_panic() {
        // Regression: `Event::cmp` used `partial_cmp().expect(..)`, so one
        // NaN time panicked a release run (where the push-side debug_assert
        // is compiled out). The total-order comparator must instead give
        // NaN a deterministic place at the extremes of the time axis.
        let mk = |time: f64, seq: u64| Event {
            time,
            seq,
            kind: EventKind::CrashAt(0),
        };
        // Sign-controlled NaNs: `f64::NAN`'s sign bit is unspecified, so
        // pin it explicitly with copysign.
        let pos_nan = f64::NAN.copysign(1.0);
        let neg_nan = f64::NAN.copysign(-1.0);
        let mut heap = BinaryHeap::new();
        heap.push(mk(pos_nan, 0));
        heap.push(mk(1.0, 1));
        heap.push(mk(f64::NEG_INFINITY, 2));
        heap.push(mk(neg_nan, 3));
        let order: Vec<u64> =
            std::iter::from_fn(|| heap.pop().map(|e| e.seq)).collect();
        // IEEE-754 total order: -NaN < -inf < 1.0 < +NaN.
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn equal_nan_times_still_fifo_by_seq() {
        let mk = |seq: u64| Event {
            time: f64::NAN,
            seq,
            kind: EventKind::CrashAt(0),
        };
        let mut heap = BinaryHeap::new();
        heap.push(mk(2));
        heap.push(mk(0));
        heap.push(mk(1));
        let order: Vec<u64> =
            std::iter::from_fn(|| heap.pop().map(|e| e.seq)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn bucketed_queue_orders_non_finite_times_like_the_total_order() {
        // The PR 4 total-order pins, driven through the bucketed queue
        // itself (non-finite times route to the overflow heap, and the pop
        // comparison must interleave them with finite calendar events at
        // the IEEE total-order extremes).
        let mut q = EventQueue::new();
        q.push_unchecked(f64::NAN.copysign(1.0), EventKind::CrashAt(0));
        q.push_unchecked(1.0, EventKind::CrashAt(1));
        q.push_unchecked(f64::NEG_INFINITY, EventKind::CrashAt(2));
        q.push_unchecked(f64::NAN.copysign(-1.0), EventKind::CrashAt(3));
        q.push_unchecked(f64::INFINITY, EventKind::CrashAt(4));
        assert_eq!(q.len(), 5);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.seq)).collect();
        // -NaN < -inf < 1.0 < +inf < +NaN.
        assert_eq!(order, vec![3, 2, 1, 4, 0]);
    }

    #[test]
    fn far_future_events_cross_the_horizon_and_return() {
        // Times far past the calendar horizon park in the overflow heap
        // and must migrate back (or pop directly) in exact order, across
        // several re-anchors.
        let mut q = EventQueue::new();
        for i in 0..50u64 {
            // Descending pushes spanning ~12 orders of magnitude.
            q.push((50 - i) as f64 * 1e6 + 0.25, EventKind::CrashAt(i as usize));
        }
        for i in 0..50u64 {
            q.push(i as f64 * 1e-3, EventKind::CrashAt(i as usize));
        }
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some(ev) = q.pop() {
            assert!(ev.time >= last, "pop went backwards: {} < {last}", ev.time);
            last = ev.time;
            n += 1;
        }
        assert_eq!(n, 100);
    }

    /// Satellite task (PR 9): the bucketed queue against a kept
    /// `BinaryHeap<Event>` reference — pop-order identity across randomized
    /// push/pop streams including same-time `seq` ties, far-future/past
    /// mixes, and the non-finite total-order cases pinned in PR 4. The two
    /// structures share the `seq` counter in lockstep, so identity is
    /// checked down to the exact `(time bits, seq)` of every pop and peek.
    #[test]
    fn prop_bucketed_queue_matches_binary_heap_reference() {
        let key = |e: &Event| (e.time.to_bits(), e.seq);
        for seed in 0..40u64 {
            let mut rng = Rng::new(seed ^ 0xE5E7);
            let mut q = EventQueue::new();
            let mut reference: BinaryHeap<Event> = BinaryHeap::new();
            let mut next_seq = 0u64;
            let mut clock = 0.0f64;
            let mut last_pushed = 0.0f64;
            for step in 0..600 {
                if rng.below(5) < 3 {
                    let time = match rng.below(12) {
                        // far future: way past the calendar horizon
                        0 => clock + 1.0 + rng.f64() * 1e9,
                        // the past, relative to the pop clock
                        1 => (clock - rng.f64() * 10.0).max(0.0),
                        // exact duplicate of an earlier push: seq tie
                        2 => last_pushed,
                        // the PR 4 non-finite total-order cases
                        3 => match rng.below(4) {
                            0 => f64::NAN.copysign(1.0),
                            1 => f64::NAN.copysign(-1.0),
                            2 => f64::INFINITY,
                            _ => f64::NEG_INFINITY,
                        },
                        // near future: lands in the calendar
                        _ => clock + rng.f64() * 5.0,
                    };
                    q.push_unchecked(time, EventKind::CrashAt(step));
                    reference.push(Event {
                        time,
                        seq: next_seq,
                        kind: EventKind::CrashAt(step),
                    });
                    next_seq += 1;
                    if time.is_finite() {
                        last_pushed = time;
                    }
                } else {
                    let got = q.pop();
                    let want = reference.pop();
                    assert_eq!(
                        got.as_ref().map(key),
                        want.as_ref().map(key),
                        "seed {seed} step {step}: pop diverged"
                    );
                    if let Some(ev) = &got {
                        if ev.time.is_finite() {
                            clock = ev.time.max(clock);
                        }
                    }
                }
                assert_eq!(
                    q.peek().map(key),
                    reference.peek().map(key),
                    "seed {seed} step {step}: peek diverged"
                );
                assert_eq!(q.len(), reference.len(), "seed {seed} step {step}");
                assert_eq!(q.is_empty(), reference.is_empty());
            }
            // Drain: the tails must match too.
            loop {
                let got = q.pop();
                let want = reference.pop();
                assert_eq!(
                    got.as_ref().map(key),
                    want.as_ref().map(key),
                    "seed {seed} drain: pop diverged"
                );
                if got.is_none() {
                    break;
                }
            }
        }
    }
}
