//! Discrete-event queue on the virtual clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::coordinator::scrt::Record;
use crate::workload::SatId;

/// Event payloads.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A task arrives at its satellite (index into the workload task vec).
    Arrival(usize),
    /// The satellite's in-flight task completes.
    Completion(SatId),
    /// One broadcast record reaches a destination satellite. Broadcasts are
    /// *streamed*: record `k` of a τ-record share arrives after `k+1`
    /// payload transmission times, so receivers start benefiting before the
    /// whole share lands.
    BroadcastDeliver {
        dst: SatId,
        /// LSH bucket of the record (identical hyperplanes fleet-wide).
        bucket: u32,
        record: std::rc::Rc<Record>,
    },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    pub time: f64,
    /// Tie-breaker: events at equal times fire in schedule order.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event pops first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::Completion(0));
        q.push(1.0, EventKind::Completion(1));
        q.push(2.0, EventKind::Completion(2));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_times_fifo_by_seq() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Completion(10));
        q.push(1.0, EventKind::Completion(20));
        q.push(1.0, EventKind::Completion(30));
        let sats: Vec<usize> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Completion(s) => s,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(sats, vec![10, 20, 30]);
    }

    #[test]
    #[should_panic]
    fn rejects_nan_time_in_debug() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventKind::Completion(0));
        q.push(1.0, EventKind::Completion(1));
        // popping with a NaN comparison panics (or the debug_assert fired)
        while q.pop().is_some() {}
        panic!("should have panicked earlier");
    }
}
