//! Discrete-event simulator for the constellation.
//!
//! Virtual time carries the paper's analytic cost model (eqs. 6–9, the
//! Table I constants); the *data* — hashes, SSIM gates, classifications —
//! is computed for real through the [`ComputeBackend`] (the AOT Pallas/JAX
//! artifacts on the production path), so reuse decisions and reuse
//! *accuracy* are genuinely data-dependent, exactly as in the paper.
//!
//! The simulator is layered (see `docs/ARCHITECTURE.md`):
//!
//! * [`engine`] — the event-loop core: one [`crate::satellite::SatNode`]
//!   per satellite, events dispatched through small handler methods;
//! * [`crate::coordinator::policy`] — scenario behaviour (Alg. 2
//!   triggering, damping, source selection) behind the
//!   [`crate::coordinator::CollabPolicy`] trait;
//! * [`observer`] — run observation hooks (tracing, custom diagnostics)
//!   replacing inline `eprintln!`s;
//! * [`source`] — prepared-input delivery: fully-materialized
//!   ([`Prepared`] / [`SharedPrepared`]) or streaming with bounded
//!   residency ([`StreamingSource`]);
//! * `sharded` — the conservative parallel engine behind
//!   [`Simulation::threads`]: satellites partition across worker shards,
//!   cross-shard broadcasts synchronize at windows sized by the minimum
//!   ISL record-hop latency, and the report stays bit-identical to the
//!   single-threaded engine's.
//!
//! Event flow per task: `Arrival` → (FIFO queue per satellite) → service
//! (Alg. 1 decides reuse vs scratch, the cost model prices it) →
//! `Completion` → SRS update → possibly an Alg. 2 collaboration, which
//! schedules `BroadcastDeliver` events per receiving satellite.
//!
//! [`Simulation::run_reference`] keeps the pre-refactor monolithic loop
//! verbatim as the determinism reference; the golden-pin tests assert
//! fixed-seed [`RunReport`] identity between it and the engine for every
//! scenario.

pub mod engine;
pub mod events;
pub mod observer;
mod sharded;
pub mod source;
pub mod srs_index;

use std::sync::Arc;

use crate::compute::{ComputeBackend, Preprocessed};
use crate::config::SimConfig;
use crate::coordinator::sccr::select_source;
use crate::coordinator::scrt::Scrt;
use crate::coordinator::slcr::process_task;
use crate::coordinator::srs::srs;
use crate::coordinator::Scenario;
use crate::error::{Error, Result};
use crate::metrics::{aggregate, RunReport, SatSummary, TaskLog};
use crate::network::{CommModel, GridTopology};
use crate::satellite::{InFlight, SatelliteState};
use crate::workload::{build_workload, ImageData, SatId, Task, Workload};
use events::{EventKind, EventQueue};

pub use engine::Engine;
pub use observer::{NullObserver, Observer, TraceObserver};
pub use sharded::ShardPartition;
pub use source::{PreparedSource, SharedPrepared, StreamConfig, StreamingSource};

/// A configured simulation, ready to run.
pub struct Simulation<'a> {
    cfg: &'a SimConfig,
    backend: &'a dyn ComputeBackend,
    scenario: Scenario,
    /// Optional pre-built workload (shared across scenario runs so every
    /// scenario sees the *same* task stream, as in the paper).
    workload: Option<&'a Workload>,
    /// Optional pre-computed per-task inputs + oracle labels.
    prepared: Option<&'a Prepared>,
    /// Drop per-task logs, keep only running aggregates (O(1) per task).
    aggregate_only: bool,
    /// `Some(k)` routes the run through the sharded conservative engine
    /// with `k` worker shards; `None` keeps the single-threaded engine.
    threads: Option<usize>,
    /// How the sharded engine maps satellites onto shards. Only read when
    /// `threads` is set; the report is bit-identical either way.
    partition: ShardPartition,
}

/// Pre-computed per-task data, shareable across scenario runs.
pub struct Prepared {
    pub pres: Vec<Preprocessed>,
    pub oracle: Vec<u32>,
}

impl Prepared {
    /// The preprocessed input and oracle label of task `idx` — the one
    /// bounds-checked accessor behind both [`SharedPrepared`]'s `fetch`
    /// and the sharded engine's lock-free shared-table reads.
    pub fn entry(&self, idx: usize) -> Result<(&Preprocessed, u32)> {
        match (self.pres.get(idx), self.oracle.get(idx)) {
            (Some(pre), Some(&label)) => Ok((pre, label)),
            _ => Err(Error::simulation(format!(
                "task index {idx} outside the prepared table ({} tasks)",
                self.pres.len()
            ))),
        }
    }
}

/// Floor on tasks per preprocessing thread: below this the spawn overhead
/// beats the win, so small workloads stay effectively sequential.
const MIN_TASKS_PER_THREAD: usize = 16;

/// Preprocessing fan-out width for `n` tasks.
fn preprocess_threads(n: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    hw.min(n.div_ceil(MIN_TASKS_PER_THREAD)).max(1)
}

/// Pre-process a task slice and compute its oracle labels.
///
/// Preprocessing fans out across scoped threads (the same pattern as
/// `run_scenarios_parallel`): the task list is split into contiguous
/// chunks, each worker runs the backend's batched
/// [`ComputeBackend::preprocess_many`] on its chunk, and the chunk results
/// are concatenated in task order. The oracle labels then come from one
/// [`ComputeBackend::classify_many`] pass (a real GEMM on the native
/// backend). Because every per-task result is independent and the batched
/// kernels share the single-task reduction order, the output is
/// *identical* to [`prepare_sequential`] for any chunking — asserted by
/// the determinism tests below and in `tests/properties.rs`. This is also
/// why [`StreamingSource`]'s on-demand chunks are bit-identical to the
/// up-front table.
pub fn prepare_tasks(backend: &dyn ComputeBackend, tasks: &[Task]) -> Result<Prepared> {
    let n = tasks.len();
    let threads = preprocess_threads(n);
    let chunk_len = n.div_ceil(threads).max(1);
    let num_chunks = n.div_ceil(chunk_len);
    let mut chunk_results: Vec<Option<Result<Vec<Preprocessed>>>> =
        (0..num_chunks).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (slot, chunk) in chunk_results.iter_mut().zip(tasks.chunks(chunk_len)) {
            scope.spawn(move || {
                let raws: Vec<&ImageData> = chunk.iter().map(|t| &t.raw).collect();
                *slot = Some(backend.preprocess_many(&raws));
            });
        }
    });
    let mut pres = Vec::with_capacity(n);
    for r in chunk_results {
        pres.extend(r.expect("preprocess worker completed")?);
    }
    let refs: Vec<&Preprocessed> = pres.iter().collect();
    let oracle = backend.classify_many(&refs)?;
    Ok(Prepared { pres, oracle })
}

/// Pre-process every task of a workload and compute oracle labels.
pub fn prepare(backend: &dyn ComputeBackend, workload: &Workload) -> Result<Prepared> {
    prepare_tasks(backend, &workload.tasks)
}

/// Sequential, unbatched reference implementation of [`prepare`] — one
/// `preprocess` and one `classify` call per task, in task order. Kept for
/// determinism cross-checks and single-core environments.
pub fn prepare_sequential(
    backend: &dyn ComputeBackend,
    workload: &Workload,
) -> Result<Prepared> {
    let mut pres = Vec::with_capacity(workload.tasks.len());
    for t in &workload.tasks {
        pres.push(backend.preprocess(&t.raw)?);
    }
    let mut oracle = Vec::with_capacity(pres.len());
    for p in &pres {
        oracle.push(backend.classify(p)?);
    }
    Ok(Prepared { pres, oracle })
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: &'a SimConfig,
        backend: &'a dyn ComputeBackend,
        scenario: Scenario,
    ) -> Self {
        Simulation {
            cfg,
            backend,
            scenario,
            workload: None,
            prepared: None,
            aggregate_only: false,
            threads: None,
            partition: ShardPartition::default(),
        }
    }

    /// Run the event loop on the **sharded conservative engine** with
    /// `threads` worker shards (clamped to ≥ 1). Satellites partition
    /// across shards per [`Simulation::partition`] (contiguous id blocks
    /// by default); cross-shard broadcasts synchronize at
    /// conservative windows sized by the minimum ISL record-hop latency,
    /// and the resulting [`RunReport`] is bit-identical to the
    /// single-threaded engine's for every scenario and source (pinned by
    /// the golden and property suites). `threads = 1` still exercises the
    /// sharded machinery with one shard — useful for tests; builders that
    /// never call this keep the classic engine. With `CCRSAT_TRACE` set
    /// the run falls back to the single-threaded engine, which traces
    /// exactly (the sharded loop has no observer seam).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }

    /// Select the sharded engine's satellite ↔ shard mapping (default:
    /// [`ShardPartition::Blocks`], which keeps whole orbital planes on
    /// one shard). Only meaningful together with [`Simulation::threads`];
    /// the partition relabels shard ownership only, so the report stays
    /// bit-identical across variants.
    pub fn partition(mut self, partition: ShardPartition) -> Self {
        self.partition = partition;
        self
    }

    /// Share a pre-built workload (same task stream across scenarios).
    pub fn with_workload(mut self, wl: &'a Workload) -> Self {
        self.workload = Some(wl);
        self
    }

    /// Share pre-computed inputs + oracle labels.
    pub fn with_prepared(mut self, p: &'a Prepared) -> Self {
        self.prepared = Some(p);
        self
    }

    /// Keep only running aggregates: the report's `tasks` vec comes back
    /// empty and per-task log memory is never held. All aggregate metrics
    /// are identical to the full run.
    pub fn aggregate_only(mut self) -> Self {
        self.aggregate_only = true;
        self
    }

    /// The shared workload, or a freshly built one when none was shared.
    fn resolve_workload(&self) -> std::borrow::Cow<'a, Workload> {
        match self.workload {
            Some(w) => std::borrow::Cow::Borrowed(w),
            None => std::borrow::Cow::Owned(build_workload(self.cfg)),
        }
    }

    /// Run to completion and aggregate the paper's criteria.
    ///
    /// Fully-materialized path: the shared (or freshly built) [`Prepared`]
    /// table serves every task. For bounded-memory preparation see
    /// [`Simulation::run_streaming`] / [`Simulation::run_with_source`].
    pub fn run(&self) -> Result<RunReport> {
        let wall_start = std::time::Instant::now();
        self.cfg.validate()?;
        let wl = self.resolve_workload();
        let owned_prep;
        let prep = match self.prepared {
            Some(p) => p,
            None => {
                owned_prep = prepare(self.backend, &wl)?;
                &owned_prep
            }
        };
        if prep.pres.len() != wl.tasks.len() {
            return Err(Error::simulation("prepared data does not match workload"));
        }
        let mut source = SharedPrepared::new(prep);
        self.run_engine(wall_start, &wl, &mut source)
    }

    /// Run with streaming preparation: per-task inputs are prepared in
    /// on-demand chunks with residency bounded by `stream`'s window
    /// instead of the task count. The report is bit-identical to
    /// [`Simulation::run`].
    pub fn run_streaming(&self, stream: StreamConfig) -> Result<RunReport> {
        let wall_start = std::time::Instant::now();
        self.cfg.validate()?;
        let wl = self.resolve_workload();
        let mut source = StreamingSource::new(self.backend, &wl, stream)?;
        self.run_engine(wall_start, &wl, &mut source)
    }

    /// Run against a caller-provided [`PreparedSource`] (callers that want
    /// to inspect source statistics — peak residency, recomputed chunks —
    /// after the run keep ownership this way). Mutually exclusive with
    /// [`Simulation::with_prepared`]: a shared table would be silently
    /// shadowed by the source, so the combination errors instead.
    pub fn run_with_source(&self, source: &mut dyn PreparedSource) -> Result<RunReport> {
        let wall_start = std::time::Instant::now();
        self.cfg.validate()?;
        if self.prepared.is_some() {
            return Err(Error::simulation(
                "run_with_source would shadow the table installed via \
                 with_prepared — share the table (run) or the source, not both",
            ));
        }
        let wl = self.resolve_workload();
        if source.len() != wl.tasks.len() {
            return Err(Error::simulation(format!(
                "prepared source covers {} tasks, workload has {}",
                source.len(),
                wl.tasks.len()
            )));
        }
        self.run_engine(wall_start, &wl, source)
    }

    /// Construct the engine and drive it, wiring the `CCRSAT_TRACE`
    /// observer when the environment asks for it. `wall_start` is the
    /// instant the public entry point began, so the report's `wallclock_s`
    /// covers workload build + preparation exactly as the pre-refactor
    /// monolith did.
    fn run_engine(
        &self,
        wall_start: std::time::Instant,
        wl: &Workload,
        source: &mut dyn PreparedSource,
    ) -> Result<RunReport> {
        if let Some(threads) = self.threads {
            if std::env::var("CCRSAT_TRACE").is_err() {
                return sharded::run_sharded(
                    self.cfg,
                    self.backend,
                    self.scenario,
                    wl,
                    !self.aggregate_only,
                    threads,
                    self.partition,
                    source,
                    wall_start,
                );
            }
        }
        let engine = Engine::new(
            self.cfg,
            self.backend,
            self.scenario,
            wl,
            !self.aggregate_only,
        );
        if std::env::var("CCRSAT_TRACE").is_ok() {
            engine.run_from(wall_start, source, &mut TraceObserver)
        } else {
            engine.run_from(wall_start, source, &mut NullObserver)
        }
    }

    /// The pre-refactor monolithic event loop, kept verbatim as the
    /// determinism reference for [`Engine`] (the same pattern as
    /// [`prepare_sequential`]). The golden-pin tests in
    /// `tests/engine_identity.rs` assert fixed-seed [`RunReport`] identity
    /// between this and [`Simulation::run`] for every scenario; new
    /// features land in the engine only.
    pub fn run_reference(&self) -> Result<RunReport> {
        let wall_start = std::time::Instant::now();
        self.cfg.validate()?;
        // The reference loop predates the fault model and must stay
        // verbatim; lossy runs are cross-checked engine-vs-sharded instead.
        if self.cfg.comm.faults_active() {
            return Err(Error::simulation(
                "run_reference does not model lossy links — \
                 compare Simulation::run against the sharded engine instead",
            ));
        }
        // Same contract for time-varying contact plans (degenerate
        // always-on plans run fine: they take the identical legacy path).
        if self.cfg.topology.is_dynamic() {
            return Err(Error::simulation(
                "run_reference does not model time-varying contact plans — \
                 compare Simulation::run against the sharded engine instead",
            ));
        }
        // And for satellite crash/reboot fault injection.
        if self.cfg.faults.node_faults_active() {
            return Err(Error::simulation(
                "run_reference does not model node faults — \
                 compare Simulation::run against the sharded engine instead",
            ));
        }

        let owned_wl;
        let wl = match self.workload {
            Some(w) => w,
            None => {
                owned_wl = build_workload(self.cfg);
                &owned_wl
            }
        };
        let owned_prep;
        let prep = match self.prepared {
            Some(p) => p,
            None => {
                owned_prep = prepare(self.backend, wl)?;
                &owned_prep
            }
        };
        if prep.pres.len() != wl.tasks.len() {
            return Err(Error::simulation("prepared data does not match workload"));
        }

        let topo = GridTopology::new(self.cfg.network.n);
        let comm = CommModel::new(&self.cfg.network, &self.cfg.comm);
        let sats = topo.len();
        let cap = self.cfg.cache_capacity_records();
        let num_buckets = self.backend.num_buckets();

        let mut states: Vec<SatelliteState> =
            (0..sats).map(SatelliteState::new).collect();
        let mut scrts: Vec<Scrt> = (0..sats)
            .map(|_| Scrt::new(num_buckets, cap))
            .collect();
        let mut queues: Vec<std::collections::VecDeque<usize>> =
            vec![std::collections::VecDeque::new(); sats];
        let mut in_flight: Vec<Option<InFlight>> = vec![None; sats];
        // Hysteresis: once a satellite's request triggered a broadcast, it
        // may not request again until its SRS has recovered above th_co —
        // a satellite that keeps benefiting never re-requests, and one that
        // did not benefit waits for the situation to change.
        let mut collab_armed: Vec<bool> = vec![true; sats];

        // Cost model (eqs. 6–8).
        let c_comp = self.cfg.compute.capability_flops;
        let scratch_s = self.cfg.compute.task_flops / c_comp;
        let lookup_s =
            self.cfg.compute.lookup_fixed_s + self.cfg.compute.lookup_flops / c_comp;

        let mut q = EventQueue::new();
        for (idx, task) in wl.tasks.iter().enumerate() {
            q.push(task.arrival, EventKind::Arrival(idx));
        }

        let mut logs: Vec<TaskLog> = Vec::with_capacity(wl.tasks.len());
        let mut transfer_bytes = 0.0f64;
        let mut comm_seconds = 0.0f64;
        // While a broadcast is in flight the inter-satellite links are
        // saturated with record payloads; new collaborations wait.
        let mut network_quiet_until = f64::NEG_INFINITY;
        let mut collab_events = 0usize;
        let mut expanded_events = 0usize;
        let mut aborted_collabs = 0usize;
        let mut broadcast_records = 0usize;

        let trace = std::env::var("CCRSAT_TRACE").is_ok();
        while let Some(ev) = q.pop() {
            let now = ev.time;
            match ev.kind {
                EventKind::Arrival(idx) => {
                    let sat = wl.tasks[idx].satellite;
                    queues[sat].push_back(idx);
                    if in_flight[sat].is_none() {
                        self.start_service_reference(
                            sat,
                            now,
                            wl,
                            prep,
                            &mut scrts,
                            &mut states,
                            &mut queues,
                            &mut in_flight,
                            &mut q,
                            scratch_s,
                            lookup_s,
                        )?;
                    }
                }
                EventKind::Completion { sat, .. } => {
                    let fl = in_flight[sat]
                        .take()
                        .ok_or_else(|| Error::simulation("completion w/o task"))?;
                    let task: &Task = &wl.tasks[fl.task_idx];
                    if fl.reused {
                        states[sat].tasks_reused += 1;
                        if fl.correct {
                            states[sat].reused_correct += 1;
                        }
                    }
                    logs.push(TaskLog {
                        task_id: task.id,
                        sat,
                        arrival: task.arrival,
                        start: fl.start,
                        completion: now,
                        reused: fl.reused,
                        correct: fl.correct,
                        ssim: fl.ssim,
                        scene: task.scene,
                        reused_from_scene: fl.reused_from_scene,
                        reused_from_sat: fl.reused_from_sat,
                    });

                    // Alg. 2 trigger: SRS below th_co on a collaborating
                    // scenario, outside the cooldown window.
                    if let Some(policy) = self.scenario.area_policy() {
                        let my_srs = srs(
                            self.cfg.reuse.beta,
                            states[sat].reuse_rate(),
                            states[sat].cpu_occupancy(now),
                        );
                        let cooled = now - states[sat].last_collab_request
                            >= self.cfg.reuse.collab_cooldown_s;
                        if my_srs >= self.cfg.reuse.th_co {
                            collab_armed[sat] = true; // recovered: re-arm
                        }
                        // The damping mechanisms (request hysteresis,
                        // receiver suppression, link quiet period) are part
                        // of the PROPOSED on-demand design; the naive SRS
                        // Priority baseline floods whenever its cooldown
                        // allows.
                        let damped = self.scenario != Scenario::SrsPriority;
                        if my_srs < self.cfg.reuse.th_co
                            && cooled
                            && (!damped
                                || (collab_armed[sat]
                                    && now >= network_quiet_until))
                        {
                            states[sat].last_collab_request = now;
                            states[sat].collab_requests += 1;
                            let all_srs: Vec<f64> = (0..sats)
                                .map(|s| {
                                    srs(
                                        self.cfg.reuse.beta,
                                        states[s].reuse_rate(),
                                        states[s].cpu_occupancy(now),
                                    )
                                })
                                .collect();
                            if trace {
                                let max = all_srs
                                    .iter()
                                    .cloned()
                                    .fold(f64::NEG_INFINITY, f64::max);
                                eprintln!(
                                    "[trace] t={now:7.2} req={sat:3} srs={my_srs:.3} max_srs={max:.3}"
                                );
                            }
                            match select_source(
                                &topo,
                                sat,
                                &all_srs,
                                self.cfg.reuse.th_co,
                                policy,
                            ) {
                                Some(decision) => {
                                    let records =
                                        scrts[decision.source].top_tau(self.cfg.reuse.tau);
                                    if records.is_empty() {
                                        aborted_collabs += 1;
                                    } else {
                                        collab_events += 1;
                                        collab_armed[sat] = false;
                                        if trace {
                                            eprintln!(
                                                "[trace] t={now:7.2} EVENT src={} area={} recs={} expanded={}",
                                                decision.source,
                                                decision.area.len(),
                                                records.len(),
                                                decision.expanded
                                            );
                                        }
                                        if decision.expanded {
                                            expanded_events += 1;
                                        }
                                        states[decision.source].times_source += 1;
                                        broadcast_records += records.len();
                                        // Spanning-tree flood over the area.
                                        let plan = comm.plan_broadcast(
                                            &topo,
                                            decision.source,
                                            &decision.area,
                                            records.len(),
                                        );
                                        transfer_bytes += plan.bytes;
                                        comm_seconds += plan.airtime_s;
                                        network_quiet_until = now
                                            + plan.completion_offset(records.len());
                                        let shared: Vec<(u32, Arc<_>)> = records
                                            .into_iter()
                                            .map(|(b, r)| (b, Arc::new(r)))
                                            .collect();
                                        for &(dst, depth) in &plan.arrivals {
                                            for (k, (bucket, rec)) in
                                                shared.iter().enumerate()
                                            {
                                                q.push(
                                                    now + plan
                                                        .arrival_offset(k, depth),
                                                    EventKind::BroadcastDeliver {
                                                        dst,
                                                        bucket: *bucket,
                                                        record: rec.clone(),
                                                    },
                                                );
                                            }
                                        }
                                    }
                                }
                                None => aborted_collabs += 1,
                            }
                        }
                    }

                    if !queues[sat].is_empty() {
                        self.start_service_reference(
                            sat,
                            now,
                            wl,
                            prep,
                            &mut scrts,
                            &mut states,
                            &mut queues,
                            &mut in_flight,
                            &mut q,
                            scratch_s,
                            lookup_s,
                        )?;
                    }
                }
                EventKind::BroadcastDeliver {
                    dst,
                    bucket,
                    record,
                } => {
                    scrts[dst].merge_broadcast(bucket, record.as_ref(), now);
                    // A satellite that just received shared records has had
                    // its need addressed: suppress its own collaboration
                    // request until its SRS recovers above th_co again.
                    collab_armed[dst] = false;
                    states[dst].last_collab_request =
                        states[dst].last_collab_request.max(now);
                }
                // The guards above refuse lossy-link, contact-plan and
                // node-fault configs, so the chunked-transfer and fault
                // event kinds can never be scheduled in this loop.
                other => {
                    return Err(Error::simulation(format!(
                        "unexpected event kind in the reference loop: {other:?}"
                    )))
                }
            }
        }

        // Assemble per-satellite summaries.
        let makespan = logs.iter().map(|t| t.completion).fold(0.0, f64::max);
        let per_satellite: Vec<SatSummary> = (0..sats)
            .map(|s| SatSummary {
                sat: s,
                tasks: states[s].tasks_processed,
                reused: states[s].tasks_reused,
                busy_s: states[s].busy_time(),
                cpu_occupancy: states[s].cpu_occupancy(makespan),
                collab_requests: states[s].collab_requests,
                times_source: states[s].times_source,
                scrt_len: scrts[s].len(),
                evictions: scrts[s].evictions,
            })
            .collect();

        let counters = crate::metrics::RunCounters {
            transfer_bytes,
            comm_seconds,
            collab_events,
            expanded_events,
            aborted_collabs,
            broadcast_records,
            ..Default::default()
        };
        Ok(aggregate(
            self.scenario,
            self.cfg.network.n,
            logs,
            per_satellite,
            self.cfg.alpha,
            &counters,
            wall_start.elapsed().as_secs_f64(),
        ))
    }

    /// Dequeue and start the next task on an idle satellite (reference
    /// path; the engine's version is `engine::Engine::start_service`).
    #[allow(clippy::too_many_arguments)]
    fn start_service_reference(
        &self,
        sat: SatId,
        now: f64,
        wl: &Workload,
        prep: &Prepared,
        scrts: &mut [Scrt],
        states: &mut [SatelliteState],
        queues: &mut [std::collections::VecDeque<usize>],
        in_flight: &mut [Option<InFlight>],
        q: &mut EventQueue,
        scratch_s: f64,
        lookup_s: f64,
    ) -> Result<()> {
        let idx = queues[sat].pop_front().ok_or_else(|| {
            Error::simulation(format!(
                "start_service on satellite {sat} with an empty queue"
            ))
        })?;
        let task = &wl.tasks[idx];
        let pre = &prep.pres[idx];

        let (service_s, reused, correct, ssim, reused_from_scene, reused_from_sat) = if self
            .scenario
            .uses_reuse()
        {
            let outcome = process_task(
                &mut scrts[sat],
                self.backend,
                sat,
                task.id,
                task.task_type,
                pre,
                self.cfg.reuse.th_sim,
                now,
            )?;
            let correct = outcome.result == prep.oracle[idx];
            let service = if outcome.reused {
                lookup_s // eq. 7: χ_reuse = x_t · W
            } else {
                lookup_s + scratch_s // eq. 6: χ_compute = W + F_t / C^comp
            };
            // record ids are the creating task's global id, so the serving
            // record's scene is recoverable from the workload.
            let from_scene = outcome
                .reused_from
                .map(|rec_id| wl.tasks[rec_id].scene);
            let from_sat = outcome
                .reused_from
                .map(|rec_id| wl.tasks[rec_id].satellite);
            (
                service,
                outcome.reused,
                correct,
                outcome.ssim,
                from_scene,
                from_sat,
            )
        } else {
            // w/o CR: straight to the pre-trained model, no lookup at all.
            (scratch_s, false, true, None, None, None)
        };

        let (start, completion) = states[sat].serve(now, service_s);
        in_flight[sat] = Some(InFlight {
            task_idx: idx,
            start,
            reused,
            correct,
            ssim,
            reused_from_scene,
            reused_from_sat,
        });
        q.push(completion, EventKind::Completion { sat, task: idx });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeBackend;

    fn tiny_cfg(n: usize, tasks: usize) -> SimConfig {
        let mut cfg = SimConfig::paper_default(n);
        cfg.workload.total_tasks = tasks;
        cfg
    }

    fn run(cfg: &SimConfig, scenario: Scenario) -> RunReport {
        let backend = NativeBackend::new(cfg);
        Simulation::new(cfg, &backend, scenario).run().unwrap()
    }

    #[test]
    fn without_cr_processes_everything_no_reuse() {
        let cfg = tiny_cfg(3, 36);
        let r = run(&cfg, Scenario::WithoutCr);
        assert_eq!(r.total_tasks, 36);
        assert_eq!(r.reused_tasks, 0);
        assert_eq!(r.reuse_rate, 0.0);
        assert_eq!(r.reuse_accuracy, 1.0);
        assert_eq!(r.data_transfer_mb, 0.0);
        assert_eq!(r.collab_events, 0);
        assert!(r.completion_time > 0.0);
    }

    #[test]
    fn slcr_reuses_and_stays_local() {
        let cfg = tiny_cfg(3, 45);
        let r = run(&cfg, Scenario::Slcr);
        assert_eq!(r.total_tasks, 45);
        assert!(r.reused_tasks > 0, "temporal locality must produce reuse");
        assert_eq!(r.data_transfer_mb, 0.0, "SLCR never transfers");
        assert_eq!(r.collab_events, 0);
        assert!(r.completion_time > 0.0);
    }

    #[test]
    fn slcr_faster_than_scratch() {
        let cfg = tiny_cfg(3, 45);
        let scratch = run(&cfg, Scenario::WithoutCr);
        let slcr = run(&cfg, Scenario::Slcr);
        assert!(
            slcr.completion_time < scratch.completion_time,
            "slcr {} !< scratch {}",
            slcr.completion_time,
            scratch.completion_time
        );
        assert!(slcr.cpu_occupancy < scratch.cpu_occupancy);
    }

    #[test]
    fn sccr_collaborates_and_transfers() {
        let cfg = tiny_cfg(3, 60);
        let r = run(&cfg, Scenario::Sccr);
        assert!(
            r.collab_events + r.aborted_collabs > 0,
            "low-SRS satellites must request collaboration"
        );
        if r.collab_events > 0 {
            assert!(r.data_transfer_mb > 0.0);
            assert!(r.broadcast_records > 0);
        }
    }

    #[test]
    fn deterministic_runs() {
        let cfg = tiny_cfg(3, 30);
        let a = run(&cfg, Scenario::Sccr);
        let b = run(&cfg, Scenario::Sccr);
        assert_eq!(a.completion_time, b.completion_time);
        assert_eq!(a.reused_tasks, b.reused_tasks);
        assert_eq!(a.data_transfer_mb, b.data_transfer_mb);
        assert_eq!(a.collab_events, b.collab_events);
    }

    #[test]
    fn parallel_batched_prepare_matches_sequential() {
        let cfg = tiny_cfg(3, 40);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let par = prepare(&backend, &wl).unwrap();
        let seq = prepare_sequential(&backend, &wl).unwrap();
        assert_eq!(par.pres.len(), seq.pres.len());
        for (i, (a, b)) in par.pres.iter().zip(&seq.pres).enumerate() {
            assert_eq!(a, b, "pre {i} diverged");
        }
        assert_eq!(par.oracle, seq.oracle);

        // ... and a run over either Prepared produces identical reports.
        let ra = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&par)
            .run()
            .unwrap();
        let rb = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&seq)
            .run()
            .unwrap();
        assert_eq!(ra.completion_time, rb.completion_time);
        assert_eq!(ra.reused_tasks, rb.reused_tasks);
        assert_eq!(ra.reuse_accuracy, rb.reuse_accuracy);
        assert_eq!(ra.data_transfer_mb, rb.data_transfer_mb);
    }

    #[test]
    fn prepare_handles_empty_workloads() {
        let cfg = tiny_cfg(3, 12);
        let backend = NativeBackend::new(&cfg);
        let wl = Workload {
            tasks: Vec::new(),
            per_satellite: vec![0; 9],
            num_scenes: 0,
        };
        let prep = prepare(&backend, &wl).unwrap();
        assert!(prep.pres.is_empty());
        assert!(prep.oracle.is_empty());
    }

    #[test]
    fn shared_workload_keeps_stream_constant() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let a = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let b = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .run()
            .unwrap();
        assert_eq!(a.completion_time, b.completion_time);
    }

    #[test]
    fn task_logs_consistent() {
        let cfg = tiny_cfg(3, 30);
        let r = run(&cfg, Scenario::Slcr);
        assert_eq!(r.tasks.len(), 30);
        for t in &r.tasks {
            assert!(t.start >= t.arrival, "service before arrival");
            assert!(t.completion > t.start);
        }
        // per-satellite FIFO: completions ordered per sat
        for sat in 0..9 {
            let mut last = 0.0;
            for t in r.tasks.iter().filter(|t| t.sat == sat) {
                assert!(t.completion >= last);
                last = t.completion;
            }
        }
    }

    #[test]
    fn aggregate_only_drops_logs_but_keeps_metrics() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let full = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let slim = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .aggregate_only()
            .run()
            .unwrap();
        assert!(slim.tasks.is_empty(), "aggregate-only must not keep logs");
        assert_eq!(full.tasks.len(), 30);
        assert_eq!(slim.completion_time, full.completion_time);
        assert_eq!(slim.compute_seconds, full.compute_seconds);
        assert_eq!(slim.makespan, full.makespan);
        assert_eq!(slim.reuse_rate, full.reuse_rate);
        assert_eq!(slim.reuse_accuracy, full.reuse_accuracy);
        assert_eq!(slim.cpu_occupancy, full.cpu_occupancy);
        assert_eq!(slim.mean_latency, full.mean_latency);
        assert_eq!(slim.p95_latency, full.p95_latency);
        assert_eq!(slim.data_transfer_mb, full.data_transfer_mb);
        assert_eq!(slim.collab_events, full.collab_events);
        assert_eq!(slim.total_tasks, full.total_tasks);
        assert_eq!(slim.reused_tasks, full.reused_tasks);
    }

    #[test]
    fn streaming_run_matches_materialized_run() {
        let cfg = tiny_cfg(3, 45);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let materialized = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        let stream = StreamConfig {
            chunk_tasks: 8,
            window_chunks: 2,
        };
        let mut source = StreamingSource::new(&backend, &wl, stream).unwrap();
        let streamed = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .run_with_source(&mut source)
            .unwrap();
        assert_eq!(streamed.completion_time, materialized.completion_time);
        assert_eq!(streamed.reused_tasks, materialized.reused_tasks);
        assert_eq!(streamed.reuse_accuracy, materialized.reuse_accuracy);
        assert_eq!(streamed.data_transfer_mb, materialized.data_transfer_mb);
        assert_eq!(streamed.collab_events, materialized.collab_events);
        assert!(
            source.peak_resident() <= stream.window_tasks(),
            "residency {} must stay within the window {}",
            source.peak_resident(),
            stream.window_tasks()
        );
        assert!(source.peak_resident() < wl.tasks.len());
    }

    #[test]
    fn run_streaming_entry_point_matches_run() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let materialized = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        // Shared-workload path.
        let streamed = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .run_streaming(StreamConfig {
                chunk_tasks: 4,
                window_chunks: 2,
            })
            .unwrap();
        assert_eq!(streamed.completion_time, materialized.completion_time);
        assert_eq!(streamed.reused_tasks, materialized.reused_tasks);
        assert_eq!(streamed.reuse_accuracy, materialized.reuse_accuracy);
        assert_eq!(streamed.tasks.len(), materialized.tasks.len());
        // Self-built-workload path (same seed → same stream).
        let self_built = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .run_streaming(StreamConfig::default())
            .unwrap();
        assert_eq!(self_built.completion_time, materialized.completion_time);
    }

    #[test]
    fn mismatched_source_rejected() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let mut other_cfg = cfg.clone();
        other_cfg.workload.total_tasks = 12;
        let other_wl = build_workload(&other_cfg);
        let prep = prepare(&backend, &other_wl).unwrap();
        let mut source = SharedPrepared::new(&prep);
        let err = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .run_with_source(&mut source);
        assert!(err.is_err(), "12-task source vs 30-task workload");
    }

    #[test]
    fn run_with_source_rejects_a_shadowed_prepared_table() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let other = prepare(&backend, &wl).unwrap();
        let mut source = SharedPrepared::new(&other);
        let err = Simulation::new(&cfg, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run_with_source(&mut source);
        assert!(err.is_err(), "with_prepared + run_with_source must error");
    }

    #[test]
    fn sharded_run_matches_single_threaded() {
        let cfg = tiny_cfg(3, 45);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let single = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        for threads in [1usize, 2, 4] {
            let sharded = Simulation::new(&cfg, &backend, Scenario::Sccr)
                .with_workload(&wl)
                .with_prepared(&prep)
                .threads(threads)
                .run()
                .unwrap();
            assert_eq!(sharded.completion_time, single.completion_time, "{threads}");
            assert_eq!(sharded.compute_seconds, single.compute_seconds, "{threads}");
            assert_eq!(sharded.makespan, single.makespan, "{threads}");
            assert_eq!(sharded.reused_tasks, single.reused_tasks, "{threads}");
            assert_eq!(sharded.reuse_accuracy, single.reuse_accuracy, "{threads}");
            assert_eq!(
                sharded.data_transfer_mb, single.data_transfer_mb,
                "{threads}"
            );
            assert_eq!(sharded.collab_events, single.collab_events, "{threads}");
            assert_eq!(sharded.mean_latency, single.mean_latency, "{threads}");
            assert_eq!(sharded.p95_latency, single.p95_latency, "{threads}");
            assert_eq!(sharded.tasks.len(), single.tasks.len(), "{threads}");
        }
    }

    #[test]
    fn sharded_partitions_produce_identical_reports() {
        let cfg = tiny_cfg(3, 45);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let single = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        for part in [ShardPartition::RoundRobin, ShardPartition::Blocks] {
            for threads in [2usize, 4] {
                let sharded = Simulation::new(&cfg, &backend, Scenario::Sccr)
                    .with_workload(&wl)
                    .with_prepared(&prep)
                    .threads(threads)
                    .partition(part)
                    .run()
                    .unwrap();
                let tag = format!("{} x{threads}", part.name());
                assert_eq!(sharded.completion_time, single.completion_time, "{tag}");
                assert_eq!(sharded.compute_seconds, single.compute_seconds, "{tag}");
                assert_eq!(sharded.reused_tasks, single.reused_tasks, "{tag}");
                assert_eq!(sharded.data_transfer_mb, single.data_transfer_mb, "{tag}");
                assert_eq!(sharded.collab_events, single.collab_events, "{tag}");
                assert_eq!(sharded.p95_latency, single.p95_latency, "{tag}");
                assert_eq!(
                    sharded.per_satellite.len(),
                    single.per_satellite.len(),
                    "{tag}"
                );
                for (a, b) in sharded.per_satellite.iter().zip(&single.per_satellite) {
                    assert_eq!(a.sat, b.sat, "{tag}: summary order");
                    assert_eq!(a.tasks, b.tasks, "{tag}: sat {}", a.sat);
                    assert_eq!(a.busy_s, b.busy_s, "{tag}: sat {}", a.sat);
                }
            }
        }
    }

    #[test]
    fn sharded_aggregate_only_matches_full_aggregates() {
        let cfg = tiny_cfg(3, 30);
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        let prep = prepare(&backend, &wl).unwrap();
        let full = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .threads(2)
            .run()
            .unwrap();
        let slim = Simulation::new(&cfg, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .threads(2)
            .aggregate_only()
            .run()
            .unwrap();
        assert!(slim.tasks.is_empty());
        assert_eq!(full.tasks.len(), 30);
        assert_eq!(slim.completion_time, full.completion_time);
        assert_eq!(slim.p95_latency, full.p95_latency);
        assert_eq!(slim.cpu_occupancy, full.cpu_occupancy);
    }

    #[test]
    fn srs_priority_floods_network() {
        let cfg = tiny_cfg(3, 60);
        let sccr = run(&cfg, Scenario::Sccr);
        let srs_p = run(&cfg, Scenario::SrsPriority);
        if sccr.collab_events > 0 && srs_p.collab_events > 0 {
            let per_collab_sccr = sccr.data_transfer_mb / sccr.collab_events as f64;
            let per_collab_srs = srs_p.data_transfer_mb / srs_p.collab_events as f64;
            assert!(
                per_collab_srs > per_collab_sccr,
                "network-wide broadcast must cost more per event"
            );
        }
    }

    #[test]
    fn cache_capacity_respected() {
        let mut cfg = tiny_cfg(3, 45);
        cfg.reuse.cache_bytes = 5.0 * (cfg.comm.record_input_bytes + cfg.comm.record_output_bytes);
        let r = run(&cfg, Scenario::Slcr);
        for s in &r.per_satellite {
            assert!(s.scrt_len <= 5, "sat {} holds {}", s.sat, s.scrt_len);
        }
    }
}
