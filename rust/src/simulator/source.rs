//! Prepared-input sources: how the engine obtains per-task
//! [`Preprocessed`] inputs and oracle labels.
//!
//! The seed design required every task to be preprocessed and held in
//! memory up front ([`Prepared`]) — a hard ceiling on constellation and
//! workload scale. [`PreparedSource`] abstracts that: the engine asks for
//! `(pre, oracle)` by task index and does not care whether the answer is
//! a lookup in a fully-materialized table or a just-in-time batch.
//!
//! * [`SharedPrepared`] — a borrow of a fully-materialized [`Prepared`],
//!   the determinism reference (and what the parallel experiment harness
//!   shares across scenario threads).
//! * [`StreamingSource`] — prepares fixed-size chunks on demand (batched
//!   and threaded exactly like [`prepare`]) and keeps only a bounded
//!   LRU window of them resident, so on 21×21–31×31 grids and long task
//!   streams the *prepared* residency is bounded by the window, not the
//!   task count. (The raw sensor tiles of the [`Workload`] itself remain
//!   fully resident — `Workload::raw_bytes` is the number to watch there,
//!   and the CLI's streaming summary prints it.) Because the batched
//!   kernels are bit-identical to the single-task paths regardless of
//!   chunking, a streaming run's `RunReport` is bit-identical to a
//!   materialized run's (asserted by the determinism tests and
//!   `tests/properties.rs`).
//!
//! [`prepare`]: crate::simulator::prepare

use std::collections::VecDeque;

use crate::compute::{ComputeBackend, Preprocessed};
use crate::error::{Error, Result};
use crate::simulator::{prepare_tasks, Prepared};
use crate::workload::Workload;

/// Serves per-task prepared inputs to the engine, by task index.
///
/// `Send` is a supertrait: the sharded engine hands one source to all of
/// its worker shards — lock-free when [`PreparedSource::as_shared_table`]
/// exposes an immutable table, behind a mutex otherwise (fetches are then
/// serialized; the data a source returns is deterministic per index, so
/// concurrent shard access changes fetch *order* — and thereby streaming
/// residency statistics — but never the returned bytes). Both built-in
/// sources are plain data over `Send + Sync` borrows, so the bound costs
/// implementors nothing.
pub trait PreparedSource: Send {
    /// Total number of tasks this source covers.
    fn len(&self) -> usize;

    /// Is the source empty?
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The preprocessed input and oracle label of task `idx`.
    fn fetch(&mut self, idx: usize) -> Result<(&Preprocessed, u32)>;

    /// For sources that are a borrow of an immutable, fully-materialized
    /// [`Prepared`] table: expose it, so concurrent consumers (the
    /// sharded engine's shard workers) can read entries lock-free
    /// instead of serializing `fetch` calls behind a mutex and cloning
    /// each payload out of the critical section. Stateful sources
    /// (streaming windows) return `None` — the default.
    fn as_shared_table(&self) -> Option<&Prepared> {
        None
    }

    /// Peak number of [`Preprocessed`] entries simultaneously resident so
    /// far (for a materialized source this is simply the task count).
    fn peak_resident(&self) -> usize;
}

/// A borrowed, fully-materialized [`Prepared`] as a source — the zero-cost
/// path the experiment harness shares across scenario threads.
pub struct SharedPrepared<'a>(&'a Prepared);

impl<'a> SharedPrepared<'a> {
    pub fn new(prepared: &'a Prepared) -> Self {
        SharedPrepared(prepared)
    }
}

impl PreparedSource for SharedPrepared<'_> {
    fn len(&self) -> usize {
        self.0.pres.len()
    }

    fn as_shared_table(&self) -> Option<&Prepared> {
        Some(self.0)
    }

    fn fetch(&mut self, idx: usize) -> Result<(&Preprocessed, u32)> {
        self.0.entry(idx)
    }

    fn peak_resident(&self) -> usize {
        self.0.pres.len()
    }
}

/// Shape of a streaming window: tasks per chunk × resident chunks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamConfig {
    /// Tasks prepared per on-demand batch.
    pub chunk_tasks: usize,
    /// Maximum chunks resident at once (LRU-evicted beyond this).
    pub window_chunks: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            chunk_tasks: 64,
            window_chunks: 4,
        }
    }
}

impl StreamConfig {
    /// Derive a config from a total window budget in tasks (the CLI's
    /// `--stream-window`): roughly four chunks per window, chunk size
    /// capped so single chunks stay batch-kernel friendly, and the
    /// resulting [`StreamConfig::window_tasks`] ceiling never *exceeds*
    /// the budget (the chunk count rounds down). A zero budget yields a
    /// degenerate config that [`StreamConfig::validate`] rejects — it is
    /// not silently clamped up.
    pub fn with_window_tasks(window_tasks: usize) -> Self {
        if window_tasks == 0 {
            return StreamConfig {
                chunk_tasks: 0,
                window_chunks: 0,
            };
        }
        let chunk_tasks = (window_tasks / 4).clamp(1, 256);
        let window_chunks = (window_tasks / chunk_tasks).max(1);
        StreamConfig {
            chunk_tasks,
            window_chunks,
        }
    }

    /// Upper bound on simultaneously-resident prepared tasks.
    pub fn window_tasks(&self) -> usize {
        self.chunk_tasks * self.window_chunks
    }

    /// Reject degenerate windows.
    pub fn validate(&self) -> Result<()> {
        if self.chunk_tasks == 0 || self.window_chunks == 0 {
            return Err(Error::config(format!(
                "streaming window must be positive (chunk_tasks={}, window_chunks={})",
                self.chunk_tasks, self.window_chunks
            )));
        }
        Ok(())
    }
}

/// On-demand chunked preparation with a bounded LRU residency window.
///
/// Chunks are prepared with [`prepare_tasks`] — the same threaded, batched
/// path as the up-front [`prepare`] — over contiguous arrival-ordered task
/// ranges. A chunk evicted by the window and later re-requested (a long
/// satellite queue reaching back past the window) is simply recomputed;
/// preparation is deterministic, so the recomputed chunk is identical.
///
/// [`prepare`]: crate::simulator::prepare
pub struct StreamingSource<'a> {
    backend: &'a dyn ComputeBackend,
    wl: &'a Workload,
    cfg: StreamConfig,
    /// Resident chunks, LRU order (most recently used at the back).
    chunks: VecDeque<(usize, Prepared)>,
    /// Which chunk ids have ever been prepared (recompute accounting).
    prepared_once: Vec<bool>,
    peak_resident: usize,
    prepared_chunks: usize,
    recomputed_chunks: usize,
}

impl<'a> StreamingSource<'a> {
    pub fn new(
        backend: &'a dyn ComputeBackend,
        wl: &'a Workload,
        cfg: StreamConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let num_chunks = wl.tasks.len().div_ceil(cfg.chunk_tasks);
        Ok(StreamingSource {
            backend,
            wl,
            cfg,
            chunks: VecDeque::new(),
            prepared_once: vec![false; num_chunks],
            peak_resident: 0,
            prepared_chunks: 0,
            recomputed_chunks: 0,
        })
    }

    /// Chunk preparations run so far (≥ the chunk count when the window
    /// forced recomputation).
    pub fn prepared_chunks(&self) -> usize {
        self.prepared_chunks
    }

    /// Chunks that had to be prepared a second time after eviction.
    pub fn recomputed_chunks(&self) -> usize {
        self.recomputed_chunks
    }

    /// The window shape this source runs with.
    pub fn stream_config(&self) -> StreamConfig {
        self.cfg
    }

    /// Make chunk `cid` resident and return its position in the LRU deque.
    fn ensure_resident(&mut self, cid: usize) -> Result<usize> {
        if let Some(pos) = self.chunks.iter().position(|&(id, _)| id == cid) {
            if pos + 1 != self.chunks.len() {
                let entry = self.chunks.remove(pos).expect("position in range");
                self.chunks.push_back(entry);
            }
            return Ok(self.chunks.len() - 1);
        }
        // Evict BEFORE preparing: true residency (including the chunk
        // being built) must never exceed the window, and `peak_resident`
        // must report the honest maximum.
        while self.chunks.len() >= self.cfg.window_chunks {
            self.chunks.pop_front();
        }
        let lo = cid * self.cfg.chunk_tasks;
        let hi = (lo + self.cfg.chunk_tasks).min(self.wl.tasks.len());
        let chunk = prepare_tasks(self.backend, &self.wl.tasks[lo..hi])?;
        if self.prepared_once[cid] {
            self.recomputed_chunks += 1;
        } else {
            self.prepared_once[cid] = true;
        }
        self.prepared_chunks += 1;
        self.chunks.push_back((cid, chunk));
        let resident: usize = self.chunks.iter().map(|(_, p)| p.pres.len()).sum();
        self.peak_resident = self.peak_resident.max(resident);
        Ok(self.chunks.len() - 1)
    }
}

impl PreparedSource for StreamingSource<'_> {
    fn len(&self) -> usize {
        self.wl.tasks.len()
    }

    fn fetch(&mut self, idx: usize) -> Result<(&Preprocessed, u32)> {
        if idx >= self.wl.tasks.len() {
            return Err(Error::simulation(format!(
                "task index {idx} outside the workload ({} tasks)",
                self.wl.tasks.len()
            )));
        }
        let cid = idx / self.cfg.chunk_tasks;
        let pos = self.ensure_resident(cid)?;
        let (_, chunk) = &self.chunks[pos];
        let off = idx - cid * self.cfg.chunk_tasks;
        Ok((&chunk.pres[off], chunk.oracle[off]))
    }

    fn peak_resident(&self) -> usize {
        self.peak_resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeBackend;
    use crate::config::SimConfig;
    use crate::simulator::prepare;
    use crate::workload::build_workload;

    fn setup() -> (SimConfig, NativeBackend, Workload) {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 30;
        cfg.workload.raw_h = 16;
        cfg.workload.raw_w = 16;
        let backend = NativeBackend::new(&cfg);
        let wl = build_workload(&cfg);
        (cfg, backend, wl)
    }

    #[test]
    fn streaming_fetch_matches_materialized_in_any_order() {
        let (_cfg, backend, wl) = setup();
        let full = prepare(&backend, &wl).unwrap();
        let mut src = StreamingSource::new(
            &backend,
            &wl,
            StreamConfig {
                chunk_tasks: 7,
                window_chunks: 2,
            },
        )
        .unwrap();
        assert_eq!(src.len(), 30);
        // An out-of-order access pattern spanning evicted chunks.
        for &idx in &[0usize, 29, 3, 15, 1, 28, 7, 0, 22, 29] {
            let (pre, label) = src.fetch(idx).unwrap();
            assert_eq!(pre, &full.pres[idx], "pre {idx} diverged");
            assert_eq!(label, full.oracle[idx], "oracle {idx} diverged");
        }
        assert!(
            src.recomputed_chunks() > 0,
            "this pattern must thrash a 2-chunk window"
        );
    }

    #[test]
    fn residency_is_bounded_by_the_window() {
        let (_cfg, backend, wl) = setup();
        let cfg = StreamConfig {
            chunk_tasks: 5,
            window_chunks: 2,
        };
        let mut src = StreamingSource::new(&backend, &wl, cfg).unwrap();
        for idx in 0..30 {
            src.fetch(idx).unwrap();
        }
        assert!(src.peak_resident() <= cfg.window_tasks());
        assert!(src.peak_resident() < wl.tasks.len());
        assert_eq!(src.recomputed_chunks(), 0, "sequential access never thrashes");
        assert_eq!(src.prepared_chunks(), 6);
    }

    #[test]
    fn shared_prepared_reports_full_residency_and_bounds() {
        let (_cfg, backend, wl) = setup();
        let full = prepare(&backend, &wl).unwrap();
        let mut src = SharedPrepared::new(&full);
        assert_eq!(src.len(), 30);
        assert_eq!(src.peak_resident(), 30);
        let (pre, label) = src.fetch(12).unwrap();
        assert_eq!(pre, &full.pres[12]);
        assert_eq!(label, full.oracle[12]);
        assert!(src.fetch(30).is_err(), "out-of-range must error");
    }

    #[test]
    fn stream_config_from_window_budget() {
        let c = StreamConfig::with_window_tasks(128);
        assert_eq!(c.chunk_tasks, 32);
        assert_eq!(c.window_chunks, 4);
        assert_eq!(c.window_tasks(), 128);
        // tiny budgets stay valid
        let tiny = StreamConfig::with_window_tasks(1);
        tiny.validate().unwrap();
        assert!(tiny.window_tasks() >= 1);
        // a zero budget is rejected, not clamped up
        assert!(StreamConfig::with_window_tasks(0).validate().is_err());
        // the derived ceiling never exceeds the requested budget
        for budget in [1usize, 3, 5, 130, 257, 10_000] {
            let c = StreamConfig::with_window_tasks(budget);
            c.validate().unwrap();
            assert!(
                c.window_tasks() <= budget,
                "budget {budget} -> ceiling {}",
                c.window_tasks()
            );
        }
        // huge budgets cap the chunk size, not the window
        let big = StreamConfig::with_window_tasks(10_000);
        assert_eq!(big.chunk_tasks, 256);
        assert!(big.window_tasks() >= 9_000);
        assert!(StreamConfig {
            chunk_tasks: 0,
            window_chunks: 1
        }
        .validate()
        .is_err());
    }
}
