//! The discrete-event engine core.
//!
//! [`Engine`] owns one [`SatNode`] per satellite (server state, SCRT, FIFO
//! queue, in-flight task, hysteresis flag — previously five parallel
//! `Vec`s inside a ~300-line monolithic loop) and dispatches events
//! through small handler methods:
//!
//! * [`EventKind::Arrival`] → `on_arrival`: enqueue, start service if idle;
//! * [`EventKind::Completion`] → `on_completion`: log + counters, run the
//!   Alg. 2 trigger through the scenario's [`CollabPolicy`], dequeue next;
//! * [`EventKind::BroadcastDeliver`] → `on_broadcast_deliver`: merge the
//!   record, apply receiver-side damping.
//!
//! Scenario behaviour (triggering, damping, source selection) lives behind
//! the [`CollabPolicy`] trait; run observation goes through [`Observer`]
//! hooks; task inputs come from a [`PreparedSource`], so fully-materialized
//! and streaming preparation run through the identical loop. Metrics are
//! accumulated incrementally ([`MetricsAccum`]) as completions fire.
//!
//! The pre-refactor monolithic loop is kept verbatim as
//! [`Simulation::run_reference`] and the golden-pin tests assert fixed-seed
//! [`RunReport`] identity between the two for every scenario.
//!
//! [`Simulation::run_reference`]: crate::simulator::Simulation::run_reference

use std::sync::Arc;

use crate::compute::ComputeBackend;
use crate::config::SimConfig;
use crate::coordinator::policy::CollabPolicy;
use crate::coordinator::slcr::process_task;
use crate::coordinator::srs::srs;
use crate::coordinator::Scenario;
use crate::error::{Error, Result};
use crate::metrics::{MetricsAccum, RunReport, SatSummary, TaskLog};
use crate::network::{CommModel, GridTopology};
use crate::satellite::{InFlight, SatNode};
use crate::simulator::events::{EventKind, EventQueue};
use crate::simulator::observer::Observer;
use crate::simulator::source::PreparedSource;
use crate::workload::{SatId, Workload};

/// Collaboration-side run counters (folded into the final report).
#[derive(Clone, Copy, Debug, Default)]
struct CollabCounters {
    transfer_bytes: f64,
    comm_seconds: f64,
    collab_events: usize,
    expanded_events: usize,
    aborted_collabs: usize,
    broadcast_records: usize,
}

/// One configured run of the event loop. Construct with [`Engine::new`],
/// consume with [`Engine::run`].
pub struct Engine<'a> {
    cfg: &'a SimConfig,
    backend: &'a dyn ComputeBackend,
    scenario: Scenario,
    policy: Option<&'static dyn CollabPolicy>,
    wl: &'a Workload,
    topo: GridTopology,
    comm: CommModel,
    nodes: Vec<SatNode>,
    q: EventQueue,
    /// Cost model (eqs. 6–8): seconds of a from-scratch execution.
    scratch_s: f64,
    /// Seconds of the lookup path (probe + gate).
    lookup_s: f64,
    /// While a broadcast is in flight the inter-satellite links are
    /// saturated with record payloads; new collaborations wait. This is
    /// what keeps collaboration *rare* (the paper's Table III volumes
    /// imply on the order of one broadcast per mission).
    network_quiet_until: f64,
    collab: CollabCounters,
    metrics: MetricsAccum,
}

impl<'a> Engine<'a> {
    /// Build an engine over a workload. `keep_logs` selects full per-task
    /// [`TaskLog`] retention versus aggregate-only accumulation.
    pub fn new(
        cfg: &'a SimConfig,
        backend: &'a dyn ComputeBackend,
        scenario: Scenario,
        wl: &'a Workload,
        keep_logs: bool,
    ) -> Self {
        let topo = GridTopology::new(cfg.network.n);
        let comm = CommModel::new(&cfg.network, &cfg.comm);
        let sats = topo.len();
        let cap = cfg.cache_capacity_records();
        let num_buckets = backend.num_buckets();
        let nodes = (0..sats)
            .map(|s| SatNode::new(s, num_buckets, cap))
            .collect();
        let c_comp = cfg.compute.capability_flops;
        Engine {
            cfg,
            backend,
            scenario,
            policy: scenario.collab_policy(),
            wl,
            topo,
            comm,
            nodes,
            q: EventQueue::new(),
            scratch_s: cfg.compute.task_flops / c_comp,
            lookup_s: cfg.compute.lookup_fixed_s + cfg.compute.lookup_flops / c_comp,
            network_quiet_until: f64::NEG_INFINITY,
            collab: CollabCounters::default(),
            metrics: MetricsAccum::new(keep_logs),
        }
    }

    /// Drive the event loop to completion and aggregate the paper's
    /// criteria. `source` serves per-task prepared inputs; `obs` receives
    /// the run's observation hooks. The report's `wallclock_s` covers the
    /// loop only; callers that prepare inputs up front and want the whole
    /// call timed (as [`crate::simulator::Simulation::run`] does, matching
    /// the pre-refactor accounting) use [`Engine::run_from`].
    pub fn run(
        self,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<RunReport> {
        self.run_from(std::time::Instant::now(), source, obs)
    }

    /// [`Engine::run`] with a caller-supplied wall-clock start, so
    /// `wallclock_s` can include workload build + preparation time spent
    /// before the engine was constructed.
    pub fn run_from(
        mut self,
        wall_start: std::time::Instant,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<RunReport> {
        let wl = self.wl;
        for (idx, task) in wl.tasks.iter().enumerate() {
            self.q.push(task.arrival, EventKind::Arrival(idx));
        }
        while let Some(ev) = self.q.pop() {
            let now = ev.time;
            match ev.kind {
                EventKind::Arrival(idx) => self.on_arrival(idx, now, source)?,
                EventKind::Completion(sat) => {
                    self.on_completion(sat, now, source, obs)?
                }
                EventKind::BroadcastDeliver {
                    dst,
                    bucket,
                    record,
                } => self.on_broadcast_deliver(dst, bucket, &record, now, obs),
            }
        }

        // Assemble per-satellite summaries.
        let makespan = self.metrics.makespan();
        let per_satellite: Vec<SatSummary> = self
            .nodes
            .iter()
            .map(|node| SatSummary {
                sat: node.state.id,
                tasks: node.state.tasks_processed,
                reused: node.state.tasks_reused,
                busy_s: node.state.busy_time(),
                cpu_occupancy: node.state.cpu_occupancy(makespan),
                collab_requests: node.state.collab_requests,
                times_source: node.state.times_source,
                scrt_len: node.scrt.len(),
                evictions: node.scrt.evictions,
            })
            .collect();

        Ok(self.metrics.finish(
            self.scenario,
            self.cfg.network.n,
            per_satellite,
            self.cfg.alpha,
            self.collab.comm_seconds,
            self.collab.transfer_bytes,
            self.collab.collab_events,
            self.collab.expanded_events,
            self.collab.aborted_collabs,
            self.collab.broadcast_records,
            wall_start.elapsed().as_secs_f64(),
        ))
    }

    /// Current SRS (eq. 11) of one satellite.
    fn srs_of(&self, sat: SatId, now: f64) -> f64 {
        srs(
            self.cfg.reuse.beta,
            self.nodes[sat].state.reuse_rate(),
            self.nodes[sat].state.cpu_occupancy(now),
        )
    }

    /// A task arrives: enqueue and start service if the satellite is idle.
    fn on_arrival(
        &mut self,
        idx: usize,
        now: f64,
        source: &mut dyn PreparedSource,
    ) -> Result<()> {
        let sat = self.wl.tasks[idx].satellite;
        self.nodes[sat].queue.push_back(idx);
        if self.nodes[sat].in_flight.is_none() {
            self.start_service(sat, now, source)?;
        }
        Ok(())
    }

    /// A task completes: log it, run the Alg. 2 trigger, dequeue the next.
    fn on_completion(
        &mut self,
        sat: SatId,
        now: f64,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<()> {
        let fl: InFlight = self.nodes[sat]
            .in_flight
            .take()
            .ok_or_else(|| Error::simulation("completion w/o task"))?;
        let task = &self.wl.tasks[fl.task_idx];
        if fl.reused {
            let state = &mut self.nodes[sat].state;
            state.tasks_reused += 1;
            if fl.correct {
                state.reused_correct += 1;
            }
        }
        let log = TaskLog {
            task_id: task.id,
            sat,
            arrival: task.arrival,
            start: fl.start,
            completion: now,
            reused: fl.reused,
            correct: fl.correct,
            ssim: fl.ssim,
            scene: task.scene,
            reused_from_scene: fl.reused_from_scene,
            reused_from_sat: fl.reused_from_sat,
        };
        obs.on_task_complete(&log);
        self.metrics.record(log);

        self.maybe_collaborate(sat, now, obs);

        if !self.nodes[sat].queue.is_empty() {
            self.start_service(sat, now, source)?;
        }
        Ok(())
    }

    /// Alg. 2 trigger at a completion, delegated to the scenario's
    /// [`CollabPolicy`]: re-arm the hysteresis, ask the policy whether to
    /// request, select the source and schedule the broadcast fan-out.
    fn maybe_collaborate(&mut self, sat: SatId, now: f64, obs: &mut dyn Observer) {
        let Some(policy) = self.policy else {
            return;
        };
        let th_co = self.cfg.reuse.th_co;
        let my_srs = self.srs_of(sat, now);
        let cooled = now - self.nodes[sat].state.last_collab_request
            >= self.cfg.reuse.collab_cooldown_s;
        if my_srs >= th_co {
            self.nodes[sat].collab_armed = true; // recovered: re-arm
        }
        if !policy.should_request(
            self.nodes[sat].collab_armed,
            my_srs,
            th_co,
            cooled,
            now,
            self.network_quiet_until,
        ) {
            return;
        }
        self.nodes[sat].state.last_collab_request = now;
        self.nodes[sat].state.collab_requests += 1;
        let all_srs: Vec<f64> = (0..self.nodes.len())
            .map(|s| self.srs_of(s, now))
            .collect();
        obs.on_collab_request(now, sat, my_srs, &all_srs);
        let Some(decision) = policy.select_source(&self.topo, sat, &all_srs, th_co)
        else {
            self.collab.aborted_collabs += 1;
            return;
        };
        let records = self.nodes[decision.source].scrt.top_tau(self.cfg.reuse.tau);
        if records.is_empty() {
            self.collab.aborted_collabs += 1;
            return;
        }
        self.collab.collab_events += 1;
        self.nodes[sat].collab_armed = false;
        obs.on_collab_broadcast(now, &decision, records.len());
        if decision.expanded {
            self.collab.expanded_events += 1;
        }
        self.nodes[decision.source].state.times_source += 1;
        self.collab.broadcast_records += records.len();
        // Spanning-tree flood over the area.
        let plan = self.comm.plan_broadcast(
            &self.topo,
            decision.source,
            &decision.area,
            records.len(),
        );
        self.collab.transfer_bytes += plan.bytes;
        self.collab.comm_seconds += plan.airtime_s;
        self.network_quiet_until = now + plan.completion_offset(records.len());
        let shared: Vec<(u32, Arc<_>)> = records
            .into_iter()
            .map(|(b, r)| (b, Arc::new(r)))
            .collect();
        for &(dst, depth) in &plan.arrivals {
            for (k, (bucket, rec)) in shared.iter().enumerate() {
                self.q.push(
                    now + plan.arrival_offset(k, depth),
                    EventKind::BroadcastDeliver {
                        dst,
                        bucket: *bucket,
                        record: rec.clone(),
                    },
                );
            }
        }
    }

    /// One broadcast record lands: merge it and apply receiver damping.
    fn on_broadcast_deliver(
        &mut self,
        dst: SatId,
        bucket: u32,
        record: &crate::coordinator::scrt::Record,
        now: f64,
        obs: &mut dyn Observer,
    ) {
        let node = &mut self.nodes[dst];
        node.scrt.merge_broadcast(bucket, record.clone(), now);
        // A satellite that just received shared records has had its need
        // addressed: suppress its own collaboration request until its SRS
        // recovers above th_co again.
        node.collab_armed = false;
        node.state.last_collab_request = node.state.last_collab_request.max(now);
        obs.on_broadcast_deliver(now, dst);
    }

    /// Dequeue and start the next task on an idle satellite.
    fn start_service(
        &mut self,
        sat: SatId,
        now: f64,
        source: &mut dyn PreparedSource,
    ) -> Result<()> {
        let idx = self.nodes[sat].queue.pop_front().ok_or_else(|| {
            Error::simulation(format!(
                "start_service on satellite {sat} with an empty queue"
            ))
        })?;
        let wl = self.wl;
        let task = &wl.tasks[idx];

        let (service_s, reused, correct, ssim, reused_from_scene, reused_from_sat) =
            if self.scenario.uses_reuse() {
                let (pre, oracle) = source.fetch(idx)?;
                let outcome = process_task(
                    &mut self.nodes[sat].scrt,
                    self.backend,
                    sat,
                    task.id,
                    task.task_type,
                    pre,
                    self.cfg.reuse.th_sim,
                    now,
                )?;
                let correct = outcome.result == oracle;
                let service = if outcome.reused {
                    self.lookup_s // eq. 7: χ_reuse = x_t · W
                } else {
                    self.lookup_s + self.scratch_s // eq. 6: χ_compute = W + F_t / C^comp
                };
                // record ids are the creating task's global id, so the
                // serving record's scene is recoverable from the workload.
                let from_scene = outcome.reused_from.map(|rec_id| wl.tasks[rec_id].scene);
                let from_sat =
                    outcome.reused_from.map(|rec_id| wl.tasks[rec_id].satellite);
                (
                    service,
                    outcome.reused,
                    correct,
                    outcome.ssim,
                    from_scene,
                    from_sat,
                )
            } else {
                // w/o CR: straight to the pre-trained model, no lookup at all.
                (self.scratch_s, false, true, None, None, None)
            };

        let (start, completion) = self.nodes[sat].state.serve(now, service_s);
        self.nodes[sat].in_flight = Some(InFlight {
            task_idx: idx,
            start,
            reused,
            correct,
            ssim,
            reused_from_scene,
            reused_from_sat,
        });
        self.q.push(completion, EventKind::Completion(sat));
        Ok(())
    }
}
