//! The discrete-event engine core.
//!
//! [`Engine`] owns one [`SatNode`] per satellite (server state, SCRT, FIFO
//! queue, in-flight task, hysteresis flag — previously five parallel
//! `Vec`s inside a ~300-line monolithic loop) and dispatches events
//! through small handler methods:
//!
//! * [`EventKind::Arrival`] → `on_arrival`: enqueue, start service if idle;
//! * [`EventKind::Completion`] → `on_completion`: log + counters, run the
//!   Alg. 2 trigger through the scenario's [`CollabPolicy`], dequeue next;
//! * [`EventKind::BroadcastDeliver`] → `on_broadcast_deliver`: merge the
//!   record, apply receiver-side damping.
//!
//! Scenario behaviour (triggering, damping, source selection) lives behind
//! the [`CollabPolicy`] trait; run observation goes through [`Observer`]
//! hooks; task inputs come from a [`PreparedSource`], so fully-materialized
//! and streaming preparation run through the identical loop. Metrics are
//! accumulated incrementally ([`MetricsAccum`]) as completions fire.
//!
//! The pre-refactor monolithic loop is kept verbatim as
//! [`Simulation::run_reference`] and the golden-pin tests assert fixed-seed
//! [`RunReport`] identity between the two for every scenario.
//!
//! [`Simulation::run_reference`]: crate::simulator::Simulation::run_reference

use std::sync::Arc;

use crate::compute::{ComputeBackend, Preprocessed};
use crate::config::SimConfig;
use crate::coordinator::policy::CollabPolicy;
use crate::coordinator::scrt::{Record, Scrt};
use crate::coordinator::slcr::process_task;
use crate::coordinator::Scenario;
use crate::error::{Error, Result};
use crate::metrics::{MetricsAccum, RunCounters, RunReport, SatSummary, TaskLog};
use crate::network::{CommModel, ContactPlan, GridTopology, LinkState, NodeFaultPlan};
use crate::satellite::{InFlight, SatNode};
use crate::simulator::events::{EventKind, EventQueue};
use crate::simulator::observer::Observer;
use crate::simulator::source::PreparedSource;
use crate::simulator::srs_index::SrsIndex;
use crate::workload::{SatId, Workload};

/// The priced outcome of serving one task — what an [`InFlight`] records.
pub(crate) struct ServiceSpec {
    pub(crate) service_s: f64,
    pub(crate) reused: bool,
    pub(crate) correct: bool,
    pub(crate) ssim: Option<f32>,
    pub(crate) reused_from_scene: Option<u32>,
    pub(crate) reused_from_sat: Option<usize>,
}

/// The no-reuse (`w/o CR`) service: straight to the pre-trained model,
/// no lookup at all (eq. 6 without the `W` term).
pub(crate) fn scratch_service(scratch_s: f64) -> ServiceSpec {
    ServiceSpec {
        service_s: scratch_s,
        reused: false,
        correct: true,
        ssim: None,
        reused_from_scene: None,
        reused_from_sat: None,
    }
}

/// Alg. 1 against one satellite's SCRT plus the eq. 6/7 pricing — the
/// per-task core shared verbatim by the single-threaded engine and the
/// sharded engine's shard workers, so the two cannot drift.
#[allow(clippy::too_many_arguments)]
pub(crate) fn reuse_service(
    scrt: &mut Scrt,
    backend: &dyn ComputeBackend,
    wl: &Workload,
    sat: SatId,
    idx: usize,
    pre: &Preprocessed,
    oracle: u32,
    th_sim: f64,
    scratch_s: f64,
    lookup_s: f64,
    now: f64,
) -> Result<ServiceSpec> {
    let task = &wl.tasks[idx];
    let outcome =
        process_task(scrt, backend, sat, task.id, task.task_type, pre, th_sim, now)?;
    let correct = outcome.result == oracle;
    let service_s = if outcome.reused {
        lookup_s // eq. 7: χ_reuse = x_t · W
    } else {
        lookup_s + scratch_s // eq. 6: χ_compute = W + F_t / C^comp
    };
    // record ids are the creating task's global id, so the serving
    // record's scene is recoverable from the workload.
    let reused_from_scene = outcome.reused_from.map(|rec_id| wl.tasks[rec_id].scene);
    let reused_from_sat = outcome.reused_from.map(|rec_id| wl.tasks[rec_id].satellite);
    Ok(ServiceSpec {
        service_s,
        reused: outcome.reused,
        correct,
        ssim: outcome.ssim,
        reused_from_scene,
        reused_from_sat,
    })
}

/// Completion bookkeeping shared by both engines: take the in-flight
/// task, fold the reuse counters, build the [`TaskLog`].
pub(crate) fn take_completed(
    node: &mut SatNode,
    wl: &Workload,
    now: f64,
) -> Result<TaskLog> {
    let fl: InFlight = node
        .in_flight
        .take()
        .ok_or_else(|| Error::simulation("completion w/o task"))?;
    let task = &wl.tasks[fl.task_idx];
    if fl.reused {
        node.state.tasks_reused += 1;
        if fl.correct {
            node.state.reused_correct += 1;
        }
    }
    Ok(TaskLog {
        task_id: task.id,
        sat: node.state.id,
        arrival: task.arrival,
        start: fl.start,
        completion: now,
        reused: fl.reused,
        correct: fl.correct,
        ssim: fl.ssim,
        scene: task.scene,
        reused_from_scene: fl.reused_from_scene,
        reused_from_sat: fl.reused_from_sat,
    })
}

/// One configured run of the event loop. Construct with [`Engine::new`],
/// consume with [`Engine::run`].
pub struct Engine<'a> {
    cfg: &'a SimConfig,
    backend: &'a dyn ComputeBackend,
    scenario: Scenario,
    policy: Option<&'static dyn CollabPolicy>,
    wl: &'a Workload,
    topo: GridTopology,
    comm: CommModel,
    nodes: Vec<SatNode>,
    q: EventQueue,
    /// Cost model (eqs. 6–8): seconds of a from-scratch execution.
    scratch_s: f64,
    /// Seconds of the lookup path (probe + gate).
    lookup_s: f64,
    /// While a broadcast is in flight the inter-satellite links are
    /// saturated with record payloads; new collaborations wait. This is
    /// what keeps collaboration *rare* (the paper's Table III volumes
    /// imply on the order of one broadcast per mission).
    network_quiet_until: f64,
    collab: RunCounters,
    metrics: MetricsAccum,
    /// `Some` iff the fault model is on ([`CommConfig::faults_active`])
    /// *or* the contact plan is dynamic: the shared transfer-cache /
    /// link-contention state every lossy broadcast plans against. `None`
    /// keeps the legacy ideal-link path byte-for-byte, so loss = 0 runs
    /// over a degenerate plan reproduce existing goldens. A dynamic plan
    /// routes every broadcast through the chunked planner even with loss
    /// off — contact gating happens per chunk.
    ///
    /// [`CommConfig::faults_active`]: crate::config::CommConfig::faults_active
    link: Option<LinkState>,
    /// When each ISL is up (degenerate always-on plan for static configs).
    contacts: ContactPlan,
    /// Pre-resolved node-fault schedule (empty for the legacy immortal
    /// constellation). Resolved once from pure inputs before the run, so
    /// every crash/reboot fate is engine-independent.
    faults: NodeFaultPlan,
    /// Reusable all-satellite SRS buffer: one allocation for the whole
    /// run instead of one per collaboration request.
    srs_scratch: Vec<f64>,
    /// SoA mirror of every satellite's SRS inputs, re-synced after each
    /// `serve`/`take_completed` mutation; the Alg. 2 snapshot reads this
    /// flat index instead of striding through the [`SatNode`]s.
    srs_index: SrsIndex,
    /// Reusable `(bucket, Arc<Record>)` share buffer for the broadcast
    /// fan-out (the queued events hold their own `Arc` clones).
    share_scratch: Vec<(u32, Arc<Record>)>,
}

impl<'a> Engine<'a> {
    /// Build an engine over a workload. `keep_logs` selects full per-task
    /// [`TaskLog`] retention versus aggregate-only accumulation.
    pub fn new(
        cfg: &'a SimConfig,
        backend: &'a dyn ComputeBackend,
        scenario: Scenario,
        wl: &'a Workload,
        keep_logs: bool,
    ) -> Self {
        let topo = GridTopology::new(cfg.network.n);
        let comm = CommModel::new(&cfg.network, &cfg.comm);
        let contacts = ContactPlan::new(cfg.network.n, &cfg.topology);
        let sats = topo.len();
        let cap = cfg.cache_capacity_records();
        let num_buckets = backend.num_buckets();
        let nodes = (0..sats)
            .map(|s| SatNode::new(s, num_buckets, cap))
            .collect();
        // The fault horizon is the last task arrival — a pure function of
        // the workload, so both engines resolve the identical plan, and a
        // finite horizon guarantees MTBF crash generation terminates.
        let horizon = wl.tasks.iter().fold(0.0f64, |a, t| a.max(t.arrival));
        let faults = if cfg.faults.node_faults_active() {
            NodeFaultPlan::new(&cfg.faults, cfg.workload.seed, sats, horizon)
        } else {
            NodeFaultPlan::none(sats)
        };
        let c_comp = cfg.compute.capability_flops;
        Engine {
            cfg,
            backend,
            scenario,
            policy: scenario.collab_policy(),
            wl,
            topo,
            comm,
            nodes,
            q: EventQueue::new(),
            scratch_s: cfg.compute.task_flops / c_comp,
            lookup_s: cfg.compute.lookup_fixed_s + cfg.compute.lookup_flops / c_comp,
            network_quiet_until: f64::NEG_INFINITY,
            collab: RunCounters::default(),
            metrics: MetricsAccum::new(keep_logs),
            link: (cfg.comm.faults_active()
                || contacts.is_dynamic()
                || cfg.faults.node_faults_active())
            .then(|| LinkState::new(cfg.workload.seed)),
            contacts,
            faults,
            srs_scratch: Vec::new(),
            srs_index: SrsIndex::new(sats),
            share_scratch: Vec::new(),
        }
    }

    /// Drive the event loop to completion and aggregate the paper's
    /// criteria. `source` serves per-task prepared inputs; `obs` receives
    /// the run's observation hooks. The report's `wallclock_s` covers the
    /// loop only; callers that prepare inputs up front and want the whole
    /// call timed (as [`crate::simulator::Simulation::run`] does, matching
    /// the pre-refactor accounting) use [`Engine::run_from`].
    pub fn run(
        self,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<RunReport> {
        self.run_from(std::time::Instant::now(), source, obs)
    }

    /// [`Engine::run`] with a caller-supplied wall-clock start, so
    /// `wallclock_s` can include workload build + preparation time spent
    /// before the engine was constructed.
    pub fn run_from(
        mut self,
        wall_start: std::time::Instant,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<RunReport> {
        // A nonsensical fault model or contact plan is a simulation the
        // engine refuses to run — the same contract as the sharded
        // engine's degenerate-lookahead rejection, and shared with it via
        // `fault_check` / `TopologyConfig::check`.
        if let Err(msg) = self.cfg.comm.fault_check() {
            return Err(Error::simulation(msg));
        }
        if let Err(msg) = self.cfg.topology.check(self.cfg.network.n) {
            return Err(Error::simulation(msg));
        }
        if let Err(msg) = self.cfg.faults.node_fault_check(self.cfg.network.n) {
            return Err(Error::simulation(msg));
        }
        let wl = self.wl;
        // Crash/reboot events are seeded BEFORE arrivals (satellite order,
        // then task order) so a crash and an arrival at the identical
        // instant tie-break the same way in both engines: the crash wins
        // and the arriving task is lost.
        for sat in 0..self.nodes.len() {
            for &(crash, reboot) in self.faults.spans(sat) {
                self.q.push(crash, EventKind::CrashAt(sat));
                self.q.push(reboot, EventKind::RebootAt(sat));
            }
        }
        for (idx, task) in wl.tasks.iter().enumerate() {
            self.q.push(task.arrival, EventKind::Arrival(idx));
        }
        while let Some(ev) = self.q.pop() {
            let now = ev.time;
            match ev.kind {
                EventKind::Arrival(idx) => self.on_arrival(idx, now, source)?,
                EventKind::Completion { sat, task } => {
                    // Lazy cancellation: a crash clears `in_flight`, and a
                    // dropped task is never re-served, so a completion
                    // whose task doesn't match the current in-flight one
                    // is a stale ghost of a crashed service.
                    if self.nodes[sat]
                        .in_flight
                        .as_ref()
                        .is_some_and(|fl| fl.task_idx == task)
                    {
                        self.on_completion(sat, now, source, obs)?
                    }
                }
                EventKind::CrashAt(sat) => {
                    let lost = self.nodes[sat]
                        .crash(now, !self.cfg.faults.scrt_persist);
                    self.collab.crashes += 1;
                    self.collab.lost_tasks += lost;
                }
                EventKind::RebootAt(sat) => {
                    self.nodes[sat].reboot();
                    if !self.cfg.faults.scrt_persist {
                        self.collab.cold_scrt_rebuilds += 1;
                    }
                }
                EventKind::CollabTimeout {
                    req,
                    attempt,
                    fallback,
                } => {
                    debug_assert!(
                        req < self.nodes.len()
                            && attempt <= self.cfg.faults.max_failover_retries
                            && fallback
                                == (attempt == self.cfg.faults.max_failover_retries),
                        "fallback marks exactly the final failover attempt"
                    );
                    if fallback {
                        self.collab.timeout_fallbacks += 1;
                    } else {
                        self.collab.failover_reselections += 1;
                    }
                }
                EventKind::BroadcastDeliver {
                    dst,
                    bucket,
                    record,
                } => self.on_broadcast_deliver(dst, bucket, &record, now, obs),
                EventKind::ChunkDeliver {
                    dst,
                    bucket,
                    record,
                    chunk_seq,
                    total_chunks,
                } => {
                    if self.nodes[dst].accept_chunk(
                        record.id,
                        chunk_seq,
                        total_chunks,
                    ) {
                        self.on_broadcast_deliver(dst, bucket, &record, now, obs);
                    }
                }
                EventKind::LinkTimeout { src: _, dropped } => {
                    if dropped {
                        self.collab.dropped_chunks += 1;
                    } else {
                        self.collab.retransmits += 1;
                    }
                }
            }
        }

        // Assemble per-satellite summaries.
        let makespan = self.metrics.makespan();
        let per_satellite: Vec<SatSummary> = self
            .nodes
            .iter()
            .map(|node| SatSummary {
                sat: node.state.id,
                tasks: node.state.tasks_processed,
                reused: node.state.tasks_reused,
                busy_s: node.state.busy_time(),
                cpu_occupancy: node.state.cpu_occupancy(makespan),
                collab_requests: node.state.collab_requests,
                times_source: node.state.times_source,
                scrt_len: node.scrt.len(),
                evictions: node.scrt.evictions,
            })
            .collect();

        Ok(self.metrics.finish(
            self.scenario,
            self.cfg.network.n,
            per_satellite,
            self.cfg.alpha,
            &self.collab,
            wall_start.elapsed().as_secs_f64(),
        ))
    }

    /// Current SRS (eq. 11) of one satellite, read off the SoA index
    /// (bit-identical to recomputing from the node state — same counters
    /// through the same canonical pure functions).
    fn srs_of(&self, sat: SatId, now: f64) -> f64 {
        self.srs_index.srs_of(self.cfg.reuse.beta, sat, now)
    }

    /// A task arrives: enqueue and start service if the satellite is idle.
    fn on_arrival(
        &mut self,
        idx: usize,
        now: f64,
        source: &mut dyn PreparedSource,
    ) -> Result<()> {
        let sat = self.wl.tasks[idx].satellite;
        if self.nodes[sat].down {
            // A crashed satellite accepts nothing: the task is lost.
            self.collab.lost_tasks += 1;
            return Ok(());
        }
        self.nodes[sat].queue.push_back(idx);
        if self.nodes[sat].in_flight.is_none() {
            self.start_service(sat, now, source)?;
        }
        Ok(())
    }

    /// A task completes: log it, run the Alg. 2 trigger, dequeue the next.
    fn on_completion(
        &mut self,
        sat: SatId,
        now: f64,
        source: &mut dyn PreparedSource,
        obs: &mut dyn Observer,
    ) -> Result<()> {
        let log = take_completed(&mut self.nodes[sat], self.wl, now)?;
        self.srs_index.sync(sat, &self.nodes[sat].state);
        obs.on_task_complete(&log);
        self.metrics.record(log);

        self.maybe_collaborate(sat, now, obs);

        if !self.nodes[sat].queue.is_empty() {
            self.start_service(sat, now, source)?;
        }
        Ok(())
    }

    /// Alg. 2 trigger at a completion, delegated to the scenario's
    /// [`CollabPolicy`]: re-arm the hysteresis, ask the policy whether to
    /// request, select the source and schedule the broadcast fan-out.
    fn maybe_collaborate(&mut self, sat: SatId, now: f64, obs: &mut dyn Observer) {
        let Some(policy) = self.policy else {
            return;
        };
        let th_co = self.cfg.reuse.th_co;
        let my_srs = self.srs_of(sat, now);
        let cooled = now - self.nodes[sat].state.last_collab_request
            >= self.cfg.reuse.collab_cooldown_s;
        if my_srs >= th_co {
            self.nodes[sat].collab_armed = true; // recovered: re-arm
        }
        if !policy.should_request(
            self.nodes[sat].collab_armed,
            my_srs,
            th_co,
            cooled,
            now,
            self.network_quiet_until,
        ) {
            return;
        }
        self.nodes[sat].state.last_collab_request = now;
        self.nodes[sat].state.collab_requests += 1;
        // All-satellite SRS snapshot: one contiguous pass over the SoA
        // index into the reusable scratch buffer.
        let mut all_srs = std::mem::take(&mut self.srs_scratch);
        self.srs_index
            .snapshot_into(self.cfg.reuse.beta, now, &mut all_srs);
        obs.on_collab_request(now, sat, my_srs, &all_srs);
        // Failover cascade — a single pass when node faults are off. The
        // whole cascade is resolved here, at the request instant, from the
        // SRS(t0) snapshot and the pre-resolved fault plan (a pure rule,
        // so both engines derive the identical outcome): attempt `k` at
        // `t_try` re-runs Alg. 2 excluding satellites down at `t_try`, and
        // succeeds iff the chosen source survives the response window
        // `collab_timeout_s · backoff^k`. A source crash inside the window
        // is detected at its end (a `CollabTimeout` event — reselection,
        // or the final fallback to local compute); a *requester* crash
        // before the detection instant evaporates the cascade with it.
        let mut t_try = now;
        let mut chosen = None;
        for attempt in 0..=self.cfg.faults.max_failover_retries {
            let faults = &self.faults;
            let alive_at = t_try;
            let decision = policy.select_source_alive(
                &self.topo,
                sat,
                &all_srs,
                th_co,
                &|s| !faults.is_down(s, alive_at),
            );
            let Some(decision) = decision else {
                break; // no live source clears th_co: terminate (Alg. 2)
            };
            if self.faults.is_empty() {
                chosen = Some((decision, t_try));
                break;
            }
            let window = self.cfg.faults.collab_timeout_s
                * self.cfg.faults.failover_backoff.powi(attempt as i32);
            let t_det = t_try + window;
            if !self.faults.crashes_within(decision.source, t_try, t_det) {
                chosen = Some((decision, t_try));
                break;
            }
            if self.faults.crashes_within(sat, t_try, t_det) {
                break; // the requester dies before it could detect
            }
            let fallback = attempt == self.cfg.faults.max_failover_retries;
            self.q.push(
                t_det,
                EventKind::CollabTimeout {
                    req: sat,
                    attempt,
                    fallback,
                },
            );
            t_try = t_det;
        }
        self.srs_scratch = all_srs;
        let Some((decision, t_go)) = chosen else {
            self.collab.aborted_collabs += 1;
            return;
        };
        let records = self.nodes[decision.source].scrt.top_tau(self.cfg.reuse.tau);
        if records.is_empty() {
            self.collab.aborted_collabs += 1;
            return;
        }
        self.collab.collab_events += 1;
        self.nodes[sat].collab_armed = false;
        obs.on_collab_broadcast(now, &decision, records.len());
        if decision.expanded {
            self.collab.expanded_events += 1;
        }
        self.nodes[decision.source].state.times_source += 1;
        self.collab.broadcast_records += records.len();
        if let Some(mut link) = self.link.take() {
            // Lossy path: resolve the whole chunked transfer (contention,
            // fates, retries, dedup) here and replay its fixed schedule.
            let record_ids: Vec<usize> =
                records.iter().map(|(_, r)| r.id).collect();
            // The transfer resolves at the successful attempt's instant
            // `t_go` (== `now` whenever node faults are off), with the
            // fault plan filtering dead endpoints chunk by chunk.
            let plan = self.comm.plan_lossy_broadcast_with_faults(
                &self.topo,
                &self.contacts,
                &self.faults,
                !self.cfg.faults.scrt_persist,
                &mut link,
                decision.source,
                &decision.area,
                &record_ids,
                t_go,
            );
            self.link = Some(link);
            self.collab.transfer_bytes += plan.bytes;
            self.collab.comm_seconds += plan.airtime_s;
            self.collab.dedup_saved_bytes += plan.dedup_saved_bytes;
            self.collab.handovers += plan.handovers;
            self.collab.contact_wait_s += plan.contact_wait_s;
            self.collab.stranded_chunks += plan.stranded_chunks;
            self.collab.crash_dropped_chunks += plan.crash_dropped_chunks;
            self.network_quiet_until = plan.quiet_until;
            let mut shared = std::mem::take(&mut self.share_scratch);
            shared.clear();
            shared.extend(records.into_iter().map(|(b, r)| (b, Arc::new(r))));
            for d in &plan.deliveries {
                let (bucket, rec) = &shared[d.rec_slot];
                self.q.push(
                    d.time,
                    EventKind::ChunkDeliver {
                        dst: d.dst,
                        bucket: *bucket,
                        record: rec.clone(),
                        chunk_seq: d.chunk_seq,
                        total_chunks: d.total_chunks,
                    },
                );
            }
            for t in &plan.timeouts {
                self.q.push(
                    t.time,
                    EventKind::LinkTimeout {
                        src: t.src,
                        dropped: t.dropped,
                    },
                );
            }
            shared.clear();
            self.share_scratch = shared;
            return;
        }
        // Spanning-tree flood over the area.
        let plan = self.comm.plan_broadcast(
            &self.topo,
            decision.source,
            &decision.area,
            records.len(),
        );
        self.collab.transfer_bytes += plan.bytes;
        self.collab.comm_seconds += plan.airtime_s;
        self.network_quiet_until = now + plan.completion_offset(records.len());
        // Arc each record once into the reusable share buffer; the
        // fan-out below clones only the Arc, never the payload.
        let mut shared = std::mem::take(&mut self.share_scratch);
        shared.clear();
        shared.extend(records.into_iter().map(|(b, r)| (b, Arc::new(r))));
        for &(dst, depth) in &plan.arrivals {
            for (k, (bucket, rec)) in shared.iter().enumerate() {
                self.q.push(
                    now + plan.arrival_offset(k, depth),
                    EventKind::BroadcastDeliver {
                        dst,
                        bucket: *bucket,
                        record: rec.clone(),
                    },
                );
            }
        }
        shared.clear(); // the queued events hold their own Arcs
        self.share_scratch = shared;
    }

    /// One broadcast record lands: merge it and apply receiver damping.
    /// The `Arc`-shared payload is threaded through by reference — a
    /// dedup hit costs only the O(1) identity probe, and even an actual
    /// insert merely bumps the record's shared payload `Arc`.
    fn on_broadcast_deliver(
        &mut self,
        dst: SatId,
        bucket: u32,
        record: &Record,
        now: f64,
        obs: &mut dyn Observer,
    ) {
        let node = &mut self.nodes[dst];
        node.scrt.merge_broadcast(bucket, record, now);
        // A satellite that just received shared records has had its need
        // addressed: suppress its own collaboration request until its SRS
        // recovers above th_co again.
        node.collab_armed = false;
        node.state.last_collab_request = node.state.last_collab_request.max(now);
        obs.on_broadcast_deliver(now, dst);
    }

    /// Dequeue and start the next task on an idle satellite.
    fn start_service(
        &mut self,
        sat: SatId,
        now: f64,
        source: &mut dyn PreparedSource,
    ) -> Result<()> {
        let idx = self.nodes[sat].queue.pop_front().ok_or_else(|| {
            Error::simulation(format!(
                "start_service on satellite {sat} with an empty queue"
            ))
        })?;
        let spec = if self.scenario.uses_reuse() {
            let (pre, oracle) = source.fetch(idx)?;
            reuse_service(
                &mut self.nodes[sat].scrt,
                self.backend,
                self.wl,
                sat,
                idx,
                pre,
                oracle,
                self.cfg.reuse.th_sim,
                self.scratch_s,
                self.lookup_s,
                now,
            )?
        } else {
            scratch_service(self.scratch_s)
        };

        let (start, completion) = self.nodes[sat].state.serve(now, spec.service_s);
        self.srs_index.sync(sat, &self.nodes[sat].state);
        self.nodes[sat].in_flight = Some(InFlight {
            task_idx: idx,
            start,
            reused: spec.reused,
            correct: spec.correct,
            ssim: spec.ssim,
            reused_from_scene: spec.reused_from_scene,
            reused_from_sat: spec.reused_from_sat,
        });
        self.q.push(completion, EventKind::Completion { sat, task: idx });
        Ok(())
    }
}
