//! Sharded conservative parallel event engine.
//!
//! Satellites are partitioned across K worker shards by a
//! [`ShardPartition`] — contiguous id blocks by default (row-major grid
//! ids make an orbital plane one contiguous range, so most broadcast
//! deliveries stay intra-shard), or the classic round-robin `sat % K`
//! interleave — each shard owning a private [`EventQueue`] for its
//! satellites' `Arrival` / `Completion` events. The only *event* that crosses
//! satellites is `BroadcastDeliver`, and every broadcast record needs at
//! least [`CommModel::lookahead_at`] of virtual time to reach its first
//! receiver — which is exactly the lookahead a conservative parallel
//! discrete-event engine needs: inside a window `[T, T + lookahead)` no
//! shard's local events can depend on another shard's future. The
//! lookahead is queried *per window* against the run's [`ContactPlan`]:
//! for a degenerate (always-on) plan it is
//! [`CommModel::min_hop_seconds`] bit-for-bit, and for a dynamic plan it
//! is the effective minimum edge time under the plan's slowing-only rate
//! modifiers — contact gating itself only ever defers transmissions, so
//! the bound is pause-safe and float-exact either way (see
//! [`CommModel::lookahead_at`] for the full argument). The coordinator
//! repeats:
//!
//! 1. **Advance** (parallel): every shard processes its local events up to
//!    the window end on its own thread — the expensive per-task reuse
//!    path (`lsh_bucket` + NN scan + SSIM gate + classify) runs K-wide.
//! 2. **Resolve** (sequential): an Alg. 2 trigger is *not* shard-local —
//!    it snapshots every satellite's SRS and reads the source's SCRT at
//!    one instant. A shard that hits a passing trigger pauses mid-handler
//!    and the coordinator resolves the pending requests in global time
//!    order, exactly as the single-threaded engine interleaves them.
//!    Shards that already ran past the trigger instant answer those reads
//!    retroactively: per-window SRS checkpoints reconstruct any
//!    satellite's SRS at the trigger time, and the SCRT op journal
//!    ([`crate::coordinator::scrt::Scrt::top_tau_at`]) reconstructs the
//!    source's top-τ records. A resolved broadcast's deliveries land at
//!    least one lookahead in the future, so they are exchanged at the
//!    next window boundary, never inside the current one.
//! 3. **Exchange**: queued deliveries are routed into the owning shards
//!    and the next window opens at the globally earliest pending event.
//!
//! Determinism: merge order everywhere is keyed by
//! `(f64::total_cmp(time), seq)` exactly as the single-threaded
//! [`EventQueue`] orders events — shard queues preserve the relative push
//! order of their events, pending requests resolve in ascending time
//! (requester id on the measure-zero tie), and the per-shard completion
//! logs fold into one [`crate::metrics::MetricsAccum`] in global
//! completion order ([`crate::metrics::fold_sharded`]). The result is a
//! bit-identical
//! [`RunReport`] for every scenario and both prepared sources, pinned by
//! `tests/engine_identity.rs` and swept in `tests/properties.rs`.
//!
//! Scenarios without a collaboration policy (`w/o CR`, `SLCR`) never
//! broadcast at all: the window stretches to infinity and the run is one
//! embarrassingly parallel pass. Note one trade-off: shards always retain
//! their completion logs until the final merge, so an `aggregate_only`
//! sharded run holds O(tasks) log memory transiently where the
//! single-threaded engine streams them into the accumulator.

use std::sync::{Arc, Mutex};

use crate::compute::ComputeBackend;
use crate::config::SimConfig;
use crate::coordinator::policy::CollabPolicy;
use crate::coordinator::scrt::Record;
use crate::coordinator::srs::srs;
use crate::coordinator::Scenario;
use crate::error::{Error, Result};
use crate::metrics::{fold_sharded, RunCounters, RunReport, SatSummary, TaskLog};
use crate::network::{CommModel, ContactPlan, GridTopology, LinkState, NodeFaultPlan};
use crate::satellite::{InFlight, SatNode, SatelliteState};
use crate::simulator::engine::{reuse_service, scratch_service, take_completed};
use crate::simulator::events::{EventKind, EventQueue};
use crate::simulator::source::PreparedSource;
use crate::simulator::srs_index::SrsIndex;
use crate::workload::{SatId, Workload};

/// How global satellite ids map onto worker shards.
///
/// Either partition assigns every satellite to exactly one shard, and the
/// engine's merge discipline is partition-agnostic — gates resolve in
/// global `(time, requester id)` order, completion logs fold in global
/// `(completion, start, task_id)` order, fault counters sum commutatively
/// — so the choice only *relabels ownership*: the [`RunReport`] is
/// bit-identical across variants and K (pinned in `tests/properties.rs`).
/// What changes is locality: with row-major grid ids (`orbit * n + slot`)
/// a contiguous block keeps whole orbital planes on one shard, so most
/// broadcast deliveries stay intra-shard instead of crossing on every
/// hop as under the interleave.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardPartition {
    /// Interleaved `sat % K` — the engine's original layout, kept for
    /// comparison and as the worst-case-locality reference.
    RoundRobin,
    /// Contiguous satellite-id ranges of near-equal size (the first
    /// `sats % K` shards own one extra satellite). The default.
    #[default]
    Blocks,
}

impl ShardPartition {
    /// Parse a `--partition` flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "roundrobin" | "round-robin" | "rr" => Some(Self::RoundRobin),
            "blocks" | "block" => Some(Self::Blocks),
            _ => None,
        }
    }

    /// The canonical flag spelling, for reports and error messages.
    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "roundrobin",
            Self::Blocks => "blocks",
        }
    }
}

/// A [`ShardPartition`] resolved for a concrete satellite count and shard
/// count: the bidirectional `sat ↔ (shard, local)` mapping every routing
/// site goes through.
#[derive(Clone, Copy, Debug)]
struct PartitionMap {
    kind: ShardPartition,
    sats: usize,
    k: usize,
    /// Blocks: `sats / k` satellites per shard before the remainder is
    /// spread over the leading shards.
    base: usize,
    /// Blocks: the first `rem` shards own `base + 1` satellites.
    rem: usize,
}

impl PartitionMap {
    fn new(kind: ShardPartition, sats: usize, k: usize) -> Self {
        debug_assert!(k >= 1, "partition over zero shards");
        Self {
            kind,
            sats,
            k,
            base: sats / k,
            rem: sats % k,
        }
    }

    /// First satellite id of a Blocks shard.
    fn block_start(&self, shard: usize) -> usize {
        shard * self.base + shard.min(self.rem)
    }

    /// The shard owning satellite `sat`.
    fn shard_of(&self, sat: SatId) -> usize {
        match self.kind {
            ShardPartition::RoundRobin => sat % self.k,
            ShardPartition::Blocks => {
                // The first `rem` shards cover ids `[0, rem * (base+1))`.
                let split = self.rem * (self.base + 1);
                if sat < split {
                    sat / (self.base + 1)
                } else {
                    // `base == 0` implies `split == sats`, so a valid id
                    // never reaches this branch with a zero divisor.
                    self.rem + (sat - split) / self.base.max(1)
                }
            }
        }
    }

    /// Satellite `sat`'s slot within its owning shard.
    fn local_of(&self, sat: SatId) -> usize {
        match self.kind {
            ShardPartition::RoundRobin => sat / self.k,
            ShardPartition::Blocks => sat - self.block_start(self.shard_of(sat)),
        }
    }

    /// The global satellite id at `(shard, local)` — `local_of`'s inverse.
    fn sat_of(&self, shard: usize, local: usize) -> SatId {
        match self.kind {
            ShardPartition::RoundRobin => local * self.k + shard,
            ShardPartition::Blocks => self.block_start(shard) + local,
        }
    }

    /// How many satellites shard `shard` owns.
    fn len_of(&self, shard: usize) -> usize {
        match self.kind {
            // Count of `s ∈ [0, sats)` with `s ≡ shard (mod k)`.
            ShardPartition::RoundRobin => (self.sats + self.k - 1 - shard) / self.k,
            ShardPartition::Blocks => self.base + usize::from(shard < self.rem),
        }
    }
}

/// One SRS-relevant state checkpoint of a satellite inside the current
/// window, taken after every mutation (service start, completion
/// bookkeeping). `time = NEG_INFINITY` marks the lazily-recorded
/// window-entry baseline.
#[derive(Clone, Copy, Debug)]
struct SrsCheckpoint {
    time: f64,
    tasks_processed: usize,
    tasks_reused: usize,
    busy_s: f64,
}

/// A completion whose Alg. 2 gate passed: the shard stopped mid-handler
/// (bookkeeping committed, request side effects not) and waits for the
/// coordinator to resolve the request in global order.
#[derive(Clone, Copy, Debug)]
struct PendingGate {
    local: usize,
    now: f64,
    my_srs: f64,
}

/// An event scheduled by a resolved collaboration (a whole-record or
/// chunk delivery, or a retransmission timeout), waiting for the next
/// window boundary to enter its owning shard's queue.
struct PendingEvent {
    time: f64,
    kind: EventKind,
}

/// How shard workers reach the prepared inputs.
enum SourceAccess<'a, S: PreparedSource + ?Sized> {
    /// An immutable fully-materialized table
    /// ([`PreparedSource::as_shared_table`]): entries are read lock-free
    /// and borrowed straight into the reuse path — the same zero-copy
    /// access the single-threaded engine has.
    Shared(&'a crate::simulator::Prepared),
    /// A stateful source (streaming windows): `fetch` is serialized
    /// behind a mutex and the fetched input is cloned out, so the
    /// expensive reuse path runs outside the lock.
    Locked(&'a Mutex<&'a mut S>),
}

/// Read-only run context shared by every shard worker.
struct ShardCtx<'a, S: PreparedSource + ?Sized> {
    wl: &'a Workload,
    backend: &'a dyn ComputeBackend,
    /// One prepared source serves all shards.
    source: SourceAccess<'a, S>,
    uses_reuse: bool,
    policy: Option<&'static dyn CollabPolicy>,
    /// Record SRS checkpoints + SCRT ops (only collaborating scenarios
    /// ever read them back; non-collaborating runs use one infinite
    /// window, where an unbounded journal would be a leak).
    journal: bool,
    th_sim: f64,
    th_co: f64,
    beta: f64,
    cooldown_s: f64,
    scratch_s: f64,
    lookup_s: f64,
    /// Does the SCRT survive a crash (non-volatile storage)? `false` is
    /// the cold-start policy: a crash wipes the table and the reassembly
    /// buffers.
    scrt_persist: bool,
}

/// One worker shard: the satellites it owns, their private event queue,
/// its completion-log stream and the per-window journals.
struct Shard {
    /// Shard index within the partition.
    id: usize,
    /// The resolved satellite ↔ shard mapping (one copy per shard; it is
    /// a handful of words and `Copy`).
    part: PartitionMap,
    nodes: Vec<SatNode>,
    q: EventQueue,
    /// Completed-task logs in this shard's completion order.
    logs: Vec<TaskLog>,
    /// Per-local-satellite SRS checkpoints for the current window.
    srs_journal: Vec<Vec<SrsCheckpoint>>,
    /// SoA mirror of the local satellites' live SRS inputs (keyed by
    /// local id), re-synced at the same two mutation points the journal
    /// checkpoints: serve and the reuse fold of `take_completed`.
    srs: SrsIndex,
    /// The unresolved Alg. 2 gate this shard paused at, if any.
    pause: Option<PendingGate>,
    /// Shard-local fault counters, bumped by `LinkTimeout` /
    /// `CrashAt` / `RebootAt` / `CollabTimeout` handlers and summed into
    /// the run counters at the end — integer sums commute, so the totals
    /// match the single-threaded engine's exactly no matter how the
    /// events interleave across shards.
    retransmits: u64,
    dropped_chunks: u64,
    crashes: u64,
    lost_tasks: u64,
    cold_scrt_rebuilds: u64,
    failover_reselections: u64,
    timeout_fallbacks: u64,
}

impl Shard {
    fn sat_of(&self, local: usize) -> SatId {
        self.part.sat_of(self.id, local)
    }

    /// Reset the per-window journals (SRS checkpoints + SCRT ops).
    fn begin_window(&mut self) {
        for journal in &mut self.srs_journal {
            journal.clear();
        }
        for node in &mut self.nodes {
            node.scrt.clear_journal();
        }
    }

    /// Record the pre-mutation baseline on a satellite's first mutation
    /// inside the window.
    fn checkpoint_baseline(&mut self, local: usize) {
        if self.srs_journal[local].is_empty() {
            let state = &self.nodes[local].state;
            self.srs_journal[local].push(SrsCheckpoint {
                time: f64::NEG_INFINITY,
                tasks_processed: state.tasks_processed,
                tasks_reused: state.tasks_reused,
                busy_s: state.busy_time(),
            });
        }
    }

    /// Record a post-mutation checkpoint at virtual time `time`.
    fn checkpoint(&mut self, local: usize, time: f64) {
        let state = &self.nodes[local].state;
        self.srs_journal[local].push(SrsCheckpoint {
            time,
            tasks_processed: state.tasks_processed,
            tasks_reused: state.tasks_reused,
            busy_s: state.busy_time(),
        });
    }

    /// A local satellite's SRS at virtual time `t` — even when this shard
    /// has already processed the satellite past `t` within the current
    /// window (the checkpoints reach back to the window entry; events at
    /// exactly `t` are included, matching the single-threaded engine,
    /// which applies a completion's own bookkeeping before its trigger).
    fn srs_at(&self, local: usize, t: f64, beta: f64) -> f64 {
        let journal = &self.srs_journal[local];
        let (processed, reused, busy_s) =
            match journal.iter().rev().find(|c| c.time <= t) {
                Some(c) => (c.tasks_processed, c.tasks_reused, c.busy_s),
                // No SRS-input mutation this window: the live SoA lane is
                // the state at any instant inside it.
                None => self.srs.lane(local),
            };
        srs(
            beta,
            SatelliteState::reuse_rate_of(reused, processed),
            SatelliteState::occupancy_of(busy_s, t),
        )
    }

    /// Earliest queued event time, if any.
    fn next_time(&self) -> Option<f64> {
        self.q.peek().map(|e| e.time)
    }

    /// Process local events with `time < window_end` in `(time, seq)`
    /// order, stopping early (with `self.pause` set) at the first
    /// completion whose Alg. 2 gate passes. `quiet_until` is the link
    /// quiet horizon as of this shard's last synchronization point; it
    /// can only be *behind* the authoritative value, and a staler (i.e.
    /// smaller) horizon admits a superset of requests — so a gate that
    /// passes here is re-checked by the coordinator, and one that fails
    /// would fail against the authoritative horizon too.
    fn advance<S: PreparedSource + ?Sized>(
        &mut self,
        ctx: &ShardCtx<'_, S>,
        window_end: f64,
        quiet_until: f64,
    ) -> Result<()> {
        debug_assert!(self.pause.is_none(), "advance while paused");
        while self.q.peek().is_some_and(|e| e.time < window_end) {
            let ev = self.q.pop().expect("peeked event");
            let now = ev.time;
            match ev.kind {
                EventKind::Arrival(idx) => {
                    let sat = ctx.wl.tasks[idx].satellite;
                    debug_assert_eq!(self.part.shard_of(sat), self.id, "foreign arrival");
                    let local = self.part.local_of(sat);
                    if self.nodes[local].down {
                        // A task arriving at a crashed satellite is lost —
                        // same rule as the single-threaded engine.
                        self.lost_tasks += 1;
                    } else {
                        self.nodes[local].queue.push_back(idx);
                        if self.nodes[local].in_flight.is_none() {
                            self.start_service(ctx, local, now)?;
                        }
                    }
                }
                EventKind::Completion { sat, task } => {
                    let local = self.part.local_of(sat);
                    // Lazy cancellation: a crash drops the in-flight task
                    // but leaves its completion event queued; the stale
                    // event no longer matches the (empty or different)
                    // in-flight slot and is ignored. A dropped task index
                    // is never re-served, so a false match is impossible.
                    if self.nodes[local]
                        .in_flight
                        .as_ref()
                        .is_some_and(|fl| fl.task_idx == task)
                        && self.on_completion(ctx, local, now, quiet_until)?
                    {
                        return Ok(()); // paused at an unresolved gate
                    }
                }
                EventKind::CrashAt(sat) => {
                    let local = self.part.local_of(sat);
                    self.lost_tasks += self.nodes[local].crash(now, !ctx.scrt_persist);
                    self.crashes += 1;
                }
                EventKind::RebootAt(sat) => {
                    let local = self.part.local_of(sat);
                    self.nodes[local].reboot();
                    if !ctx.scrt_persist {
                        self.cold_scrt_rebuilds += 1;
                    }
                }
                EventKind::CollabTimeout { req, fallback, .. } => {
                    // Pure counter bump — the failover cascade itself was
                    // resolved by the coordinator when the request fired.
                    debug_assert_eq!(
                        self.part.shard_of(req),
                        self.id,
                        "foreign collab timeout"
                    );
                    if fallback {
                        self.timeout_fallbacks += 1;
                    } else {
                        self.failover_reselections += 1;
                    }
                }
                EventKind::BroadcastDeliver {
                    dst,
                    bucket,
                    record,
                } => {
                    debug_assert_eq!(self.part.shard_of(dst), self.id, "foreign delivery");
                    let node = &mut self.nodes[self.part.local_of(dst)];
                    node.scrt.merge_broadcast(bucket, record.as_ref(), now);
                    // Receiver damping, as in the single-threaded engine.
                    node.collab_armed = false;
                    node.state.last_collab_request =
                        node.state.last_collab_request.max(now);
                }
                EventKind::ChunkDeliver {
                    dst,
                    bucket,
                    record,
                    chunk_seq,
                    total_chunks,
                } => {
                    debug_assert_eq!(self.part.shard_of(dst), self.id, "foreign chunk");
                    let node = &mut self.nodes[self.part.local_of(dst)];
                    if node.accept_chunk(record.id, chunk_seq, total_chunks) {
                        node.scrt.merge_broadcast(bucket, record.as_ref(), now);
                        node.collab_armed = false;
                        node.state.last_collab_request =
                            node.state.last_collab_request.max(now);
                    }
                }
                EventKind::LinkTimeout { src: _, dropped } => {
                    if dropped {
                        self.dropped_chunks += 1;
                    } else {
                        self.retransmits += 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Completion bookkeeping + the *local* half of the Alg. 2 trigger.
    /// Returns true when the gate passed and the shard must pause for the
    /// coordinator (request side effects are deferred to resolution).
    fn on_completion<S: PreparedSource + ?Sized>(
        &mut self,
        ctx: &ShardCtx<'_, S>,
        local: usize,
        now: f64,
        quiet_until: f64,
    ) -> Result<bool> {
        // `take_completed` touches the SRS inputs only when the finishing
        // task was served by reuse (the `tasks_reused` fold); probing the
        // in-flight flag up front lets non-reuse completions — the common
        // case — skip both the baseline and the post-mutation checkpoint,
        // keeping the window journal proportional to *changes* in `rr`,
        // not to event count. `srs_at` is unaffected: with no mutation
        // there is nothing for a reader at this instant to rewind.
        let reused = self.nodes[local]
            .in_flight
            .as_ref()
            .is_some_and(|fl| fl.reused);
        if ctx.journal && reused {
            self.checkpoint_baseline(local);
        }
        let log = take_completed(&mut self.nodes[local], ctx.wl, now)?;
        if reused {
            self.srs.sync(local, &self.nodes[local].state);
            if ctx.journal {
                self.checkpoint(local, now);
            }
        }
        self.logs.push(log);

        if let Some(policy) = ctx.policy {
            let node = &self.nodes[local];
            let my_srs = self.srs.srs_of(ctx.beta, local, now);
            let cooled = now - node.state.last_collab_request >= ctx.cooldown_s;
            if my_srs >= ctx.th_co {
                self.nodes[local].collab_armed = true; // recovered: re-arm
            }
            if policy.should_request(
                self.nodes[local].collab_armed,
                my_srs,
                ctx.th_co,
                cooled,
                now,
                quiet_until,
            ) {
                self.pause = Some(PendingGate { local, now, my_srs });
                return Ok(true);
            }
        }
        self.finish_completion(ctx, local, now)?;
        Ok(false)
    }

    /// The post-trigger tail of a completion: dequeue the next task.
    fn finish_completion<S: PreparedSource + ?Sized>(
        &mut self,
        ctx: &ShardCtx<'_, S>,
        local: usize,
        now: f64,
    ) -> Result<()> {
        if !self.nodes[local].queue.is_empty() {
            self.start_service(ctx, local, now)?;
        }
        Ok(())
    }

    /// Resume after the coordinator resolved (or suppressed) this shard's
    /// pending gate, then keep advancing through the window.
    fn resume_after_gate<S: PreparedSource + ?Sized>(
        &mut self,
        ctx: &ShardCtx<'_, S>,
        window_end: f64,
        quiet_until: f64,
        clear_armed: bool,
    ) -> Result<()> {
        let gate = self.pause.take().expect("resume without a pending gate");
        if clear_armed {
            self.nodes[gate.local].collab_armed = false;
        }
        self.finish_completion(ctx, gate.local, gate.now)?;
        self.advance(ctx, window_end, quiet_until)
    }

    /// Dequeue and start the next task on an idle satellite.
    fn start_service<S: PreparedSource + ?Sized>(
        &mut self,
        ctx: &ShardCtx<'_, S>,
        local: usize,
        now: f64,
    ) -> Result<()> {
        let sat = self.sat_of(local);
        let idx = self.nodes[local].queue.pop_front().ok_or_else(|| {
            Error::simulation(format!(
                "start_service on satellite {sat} with an empty queue"
            ))
        })?;
        let spec = if ctx.uses_reuse {
            match &ctx.source {
                SourceAccess::Shared(prep) => {
                    let (pre, oracle) = prep.entry(idx)?;
                    reuse_service(
                        &mut self.nodes[local].scrt,
                        ctx.backend,
                        ctx.wl,
                        sat,
                        idx,
                        pre,
                        oracle,
                        ctx.th_sim,
                        ctx.scratch_s,
                        ctx.lookup_s,
                        now,
                    )?
                }
                SourceAccess::Locked(mutex) => {
                    let (pre, oracle) = {
                        let mut source = mutex.lock().map_err(|_| {
                            Error::simulation("prepared source lock poisoned")
                        })?;
                        let (pre, oracle) = source.fetch(idx)?;
                        (pre.clone(), oracle)
                    };
                    reuse_service(
                        &mut self.nodes[local].scrt,
                        ctx.backend,
                        ctx.wl,
                        sat,
                        idx,
                        &pre,
                        oracle,
                        ctx.th_sim,
                        ctx.scratch_s,
                        ctx.lookup_s,
                        now,
                    )?
                }
            }
        } else {
            scratch_service(ctx.scratch_s)
        };
        if ctx.journal {
            self.checkpoint_baseline(local);
        }
        let (start, completion) = self.nodes[local].state.serve(now, spec.service_s);
        self.srs.sync(local, &self.nodes[local].state);
        if ctx.journal {
            self.checkpoint(local, now);
        }
        self.nodes[local].in_flight = Some(InFlight {
            task_idx: idx,
            start,
            reused: spec.reused,
            correct: spec.correct,
            ssim: spec.ssim,
            reused_from_scene: spec.reused_from_scene,
            reused_from_sat: spec.reused_from_sat,
        });
        self.q.push(completion, EventKind::Completion { sat, task: idx });
        Ok(())
    }
}

/// Drive a full sharded run. Callers have already validated the config;
/// this validates the *sharding* preconditions (a strictly positive,
/// finite lookahead whenever the scenario can broadcast).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_sharded<S: PreparedSource + ?Sized>(
    cfg: &SimConfig,
    backend: &dyn ComputeBackend,
    scenario: Scenario,
    wl: &Workload,
    keep_logs: bool,
    threads: usize,
    partition: ShardPartition,
    source: &mut S,
    wall_start: std::time::Instant,
) -> Result<RunReport> {
    let shard_count = threads.max(1);
    let topo = GridTopology::new(cfg.network.n);
    let comm = CommModel::new(&cfg.network, &cfg.comm);
    let contacts = ContactPlan::new(cfg.network.n, &cfg.topology);
    let sats = topo.len();
    let policy = scenario.collab_policy();
    // Probe the per-window lookahead at t = 0; the plan families keep it
    // constant over time, so a degenerate probe here is degenerate in
    // every window.
    let lookahead = comm.lookahead_at(&contacts, 0.0);
    if policy.is_some() && !(lookahead.is_finite() && lookahead > 0.0) {
        return Err(Error::simulation(format!(
            "sharded engine needs a strictly positive broadcast lookahead, \
             but this comm config yields {lookahead} s per record-hop — \
             the conservative window could never advance past a broadcast"
        )));
    }
    // A nonsensical fault model or contact plan is rejected on the same
    // contract (shared with the single-threaded engine via `fault_check`
    // / `TopologyConfig::check`).
    if let Err(msg) = cfg.comm.fault_check() {
        return Err(Error::simulation(msg));
    }
    if let Err(msg) = cfg.topology.check(cfg.network.n) {
        return Err(Error::simulation(msg));
    }
    if let Err(msg) = cfg.faults.node_fault_check(cfg.network.n) {
        return Err(Error::simulation(msg));
    }
    // Node-fault plan, resolved up front exactly as in `Engine::new`: the
    // MTBF horizon is the last task arrival — a pure function of the
    // workload — so both engines draw identical crash schedules.
    let horizon = wl.tasks.iter().fold(0.0f64, |a, t| a.max(t.arrival));
    let faults = if cfg.faults.node_faults_active() {
        NodeFaultPlan::new(&cfg.faults, cfg.workload.seed, sats, horizon)
    } else {
        NodeFaultPlan::none(sats)
    };

    let cap = cfg.cache_capacity_records();
    let num_buckets = backend.num_buckets();
    let c_comp = cfg.compute.capability_flops;
    // Materialized tables are read lock-free; anything stateful is
    // serialized behind a mutex. (Probe first, borrow per branch — the
    // classic NLL workaround for branching on a borrowed Option while
    // the other arm needs the value mutably.)
    let locked_storage;
    let source_access = if source.as_shared_table().is_some() {
        SourceAccess::Shared(source.as_shared_table().expect("probed above"))
    } else {
        locked_storage = Mutex::new(&mut *source);
        SourceAccess::Locked(&locked_storage)
    };
    let ctx = ShardCtx {
        wl,
        backend,
        source: source_access,
        uses_reuse: scenario.uses_reuse(),
        policy,
        journal: policy.is_some(),
        th_sim: cfg.reuse.th_sim,
        th_co: cfg.reuse.th_co,
        beta: cfg.reuse.beta,
        cooldown_s: cfg.reuse.collab_cooldown_s,
        scratch_s: cfg.compute.task_flops / c_comp,
        lookup_s: cfg.compute.lookup_fixed_s + cfg.compute.lookup_flops / c_comp,
        scrt_persist: cfg.faults.scrt_persist,
    };

    let part = PartitionMap::new(partition, sats, shard_count);
    let mut shards: Vec<Shard> = (0..shard_count)
        .map(|id| {
            let nodes: Vec<SatNode> = (0..part.len_of(id))
                .map(|local| {
                    let mut node = SatNode::new(part.sat_of(id, local), num_buckets, cap);
                    if ctx.journal {
                        node.scrt.enable_journal();
                    }
                    node
                })
                .collect();
            let locals = nodes.len();
            Shard {
                id,
                part,
                nodes,
                q: EventQueue::new(),
                logs: Vec::new(),
                srs_journal: vec![Vec::new(); locals],
                srs: SrsIndex::new(locals),
                pause: None,
                retransmits: 0,
                dropped_chunks: 0,
                crashes: 0,
                lost_tasks: 0,
                cold_scrt_rebuilds: 0,
                failover_reselections: 0,
                timeout_fallbacks: 0,
            }
        })
        .collect();

    // Seed the crash/reboot schedule first, in ascending satellite order
    // with each satellite's spans in time order — the same push order as
    // the single-threaded engine, so a crash landing at the same instant
    // as an arrival wins the (time, seq) tie on both engines.
    for sat in 0..sats {
        let shard = &mut shards[part.shard_of(sat)];
        for &(crash, reboot) in faults.spans(sat) {
            shard.q.push(crash, EventKind::CrashAt(sat));
            shard.q.push(reboot, EventKind::RebootAt(sat));
        }
    }
    // Seed the arrivals, in task order per shard (same relative order as
    // the single-threaded engine's global arrival pushes).
    for (idx, task) in wl.tasks.iter().enumerate() {
        shards[part.shard_of(task.satellite)]
            .q
            .push(task.arrival, EventKind::Arrival(idx));
    }

    let tau = cfg.reuse.tau;
    let mut quiet_until = f64::NEG_INFINITY;
    let mut collab = RunCounters::default();
    // Transfer-layer bookkeeping for the lossy/contact-gated path; `None`
    // keeps the ideal-link planner (and its exact golden outputs)
    // untouched. A dynamic contact plan forces the chunked planner even
    // with loss off, mirroring `Engine::new`.
    let mut link = (cfg.comm.faults_active()
        || contacts.is_dynamic()
        || cfg.faults.node_faults_active())
    .then(|| LinkState::new(cfg.workload.seed));
    let mut pending: Vec<Vec<PendingEvent>> =
        (0..shard_count).map(|_| Vec::new()).collect();

    loop {
        // Next conservative window: the globally earliest pending event
        // plus one lookahead (infinite when nothing can ever broadcast).
        let window_start = shards
            .iter()
            .filter_map(Shard::next_time)
            .fold(f64::INFINITY, f64::min);
        if window_start == f64::INFINITY {
            break; // every queue drained: the run is complete
        }
        if !window_start.is_finite() {
            return Err(Error::simulation(
                "non-finite event time in the sharded event queue",
            ));
        }
        let window_end = if policy.is_some() {
            // Per-window query over the contact plan. For today's plan
            // families this returns the same f64 every window (and
            // exactly `min_hop_seconds()` when degenerate — preserving
            // pre-contact-plan window boundaries bit-for-bit); the query
            // is in the loop so plans with time-varying rate modifiers
            // slot in without touching the engine.
            window_start + comm.lookahead_at(&contacts, window_start)
        } else {
            f64::INFINITY
        };

        // Phase 1 — parallel advance.
        for shard in &mut shards {
            shard.begin_window();
        }
        let worker_results: Vec<Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .iter_mut()
                .map(|shard| {
                    let ctx = &ctx;
                    scope.spawn(move || shard.advance(ctx, window_end, quiet_until))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::simulation("shard panicked")))
                })
                .collect()
        });
        for result in worker_results {
            result?;
        }

        // Phase 2 — resolve pending Alg. 2 gates in global time order.
        loop {
            let mut earliest: Option<(f64, SatId, usize)> = None;
            for (i, shard) in shards.iter().enumerate() {
                if let Some(gate) = &shard.pause {
                    let sat = shard.sat_of(gate.local);
                    let replace = match &earliest {
                        None => true,
                        Some((best_t, best_sat, _)) => {
                            match gate.now.total_cmp(best_t) {
                                std::cmp::Ordering::Less => true,
                                std::cmp::Ordering::Equal => sat < *best_sat,
                                std::cmp::Ordering::Greater => false,
                            }
                        }
                    };
                    if replace {
                        earliest = Some((gate.now, sat, i));
                    }
                }
            }
            let Some((t, req_sat, i)) = earliest else {
                break;
            };
            let local = part.local_of(req_sat);
            let gate_policy = policy.expect("gates only fire with a collab policy");

            // Re-check against the authoritative quiet horizon (a collab
            // resolved since this shard paused may suppress it).
            let passes = {
                let gate = shards[i].pause.as_ref().expect("selected shard paused");
                let node = &shards[i].nodes[local];
                let cooled = t - node.state.last_collab_request >= ctx.cooldown_s;
                gate_policy.should_request(
                    node.collab_armed,
                    gate.my_srs,
                    ctx.th_co,
                    cooled,
                    t,
                    quiet_until,
                )
            };

            let mut clear_armed = false;
            if passes {
                {
                    let state = &mut shards[i].nodes[local].state;
                    state.last_collab_request = t;
                    state.collab_requests += 1;
                }
                // All-satellite SRS snapshot at `t`, reconstructed where
                // a shard has already processed past it.
                let mut all_srs = vec![0.0f64; sats];
                for (si, shard) in shards.iter().enumerate() {
                    for local_idx in 0..shard.nodes.len() {
                        all_srs[part.sat_of(si, local_idx)] =
                            shard.srs_at(local_idx, t, ctx.beta);
                    }
                }
                // Failover cascade — the same pure rule as the
                // single-threaded engine, resolved against the SRS(t)
                // snapshot with crashed satellites filtered out at each
                // retry instant. `CollabTimeout` events are state-free
                // requester-local counter bumps, so they go straight into
                // the paused requester shard's queue even when the
                // detection instant falls inside this window.
                let mut t_try = t;
                let mut chosen = None;
                for attempt in 0..=cfg.faults.max_failover_retries {
                    let alive_at = t_try;
                    let decision = gate_policy.select_source_alive(
                        &topo,
                        req_sat,
                        &all_srs,
                        ctx.th_co,
                        &|s| !faults.is_down(s, alive_at),
                    );
                    let Some(decision) = decision else { break };
                    if faults.is_empty() {
                        chosen = Some((decision, t_try));
                        break;
                    }
                    let timeout = cfg.faults.collab_timeout_s
                        * cfg.faults.failover_backoff.powi(attempt as i32);
                    let t_det = t_try + timeout;
                    if !faults.crashes_within(decision.source, t_try, t_det) {
                        chosen = Some((decision, t_try));
                        break;
                    }
                    if faults.crashes_within(req_sat, t_try, t_det) {
                        break; // the requester itself dies waiting
                    }
                    let fallback = attempt == cfg.faults.max_failover_retries;
                    shards[i].q.push(
                        t_det,
                        EventKind::CollabTimeout {
                            req: req_sat,
                            attempt,
                            fallback,
                        },
                    );
                    t_try = t_det;
                }
                match chosen {
                    None => collab.aborted_collabs += 1,
                    Some((decision, t_go)) => {
                        let records = shards[part.shard_of(decision.source)].nodes
                            [part.local_of(decision.source)]
                            .scrt
                            .top_tau_at(tau, t);
                        if records.is_empty() {
                            collab.aborted_collabs += 1;
                        } else {
                            collab.collab_events += 1;
                            if decision.expanded {
                                collab.expanded_events += 1;
                            }
                            shards[part.shard_of(decision.source)].nodes
                                [part.local_of(decision.source)]
                                .state
                                .times_source += 1;
                            collab.broadcast_records += records.len();
                            if let Some(link) = link.as_mut() {
                                // Lossy/chunked path: the whole transfer
                                // (retries included) resolves here, at a
                                // globally ordered instant, so the event
                                // schedule is identical across K.
                                let record_ids: Vec<usize> =
                                    records.iter().map(|(_, r)| r.id).collect();
                                let plan = comm.plan_lossy_broadcast_with_faults(
                                    &topo,
                                    &contacts,
                                    &faults,
                                    !cfg.faults.scrt_persist,
                                    link,
                                    decision.source,
                                    &decision.area,
                                    &record_ids,
                                    t_go,
                                );
                                collab.transfer_bytes += plan.bytes;
                                collab.comm_seconds += plan.airtime_s;
                                collab.dedup_saved_bytes += plan.dedup_saved_bytes;
                                collab.handovers += plan.handovers;
                                collab.contact_wait_s += plan.contact_wait_s;
                                collab.stranded_chunks += plan.stranded_chunks;
                                collab.crash_dropped_chunks += plan.crash_dropped_chunks;
                                quiet_until = plan.quiet_until;
                                let shared: Vec<(u32, Arc<Record>)> = records
                                    .into_iter()
                                    .map(|(b, r)| (b, Arc::new(r)))
                                    .collect();
                                for d in &plan.deliveries {
                                    let (bucket, rec) = &shared[d.rec_slot];
                                    pending[part.shard_of(d.dst)].push(PendingEvent {
                                        time: d.time,
                                        kind: EventKind::ChunkDeliver {
                                            dst: d.dst,
                                            bucket: *bucket,
                                            record: rec.clone(),
                                            chunk_seq: d.chunk_seq,
                                            total_chunks: d.total_chunks,
                                        },
                                    });
                                }
                                for to in &plan.timeouts {
                                    pending[part.shard_of(to.src)].push(PendingEvent {
                                        time: to.time,
                                        kind: EventKind::LinkTimeout {
                                            src: to.src,
                                            dropped: to.dropped,
                                        },
                                    });
                                }
                            } else {
                                let plan = comm.plan_broadcast(
                                    &topo,
                                    decision.source,
                                    &decision.area,
                                    records.len(),
                                );
                                collab.transfer_bytes += plan.bytes;
                                collab.comm_seconds += plan.airtime_s;
                                quiet_until = t + plan.completion_offset(records.len());
                                let shared: Vec<(u32, Arc<Record>)> = records
                                    .into_iter()
                                    .map(|(b, r)| (b, Arc::new(r)))
                                    .collect();
                                // Same nested order as the single-threaded
                                // fan-out: per-shard buffers preserve the
                                // relative seq order of equal-time deliveries.
                                for &(dst, depth) in &plan.arrivals {
                                    for (k, (bucket, rec)) in shared.iter().enumerate() {
                                        pending[part.shard_of(dst)].push(PendingEvent {
                                            time: t + plan.arrival_offset(k, depth),
                                            kind: EventKind::BroadcastDeliver {
                                                dst,
                                                bucket: *bucket,
                                                record: rec.clone(),
                                            },
                                        });
                                    }
                                }
                            }
                            clear_armed = true;
                        }
                    }
                }
            }
            // The resumed shard finishes its window alone — every other
            // shard is already past its own pause or at the window end,
            // so nothing is left to overlap with.
            shards[i].resume_after_gate(&ctx, window_end, quiet_until, clear_armed)?;
        }

        // Phase 3 — exchange: deliveries land at `t + (k + depth) ×
        // bottleneck ≥ window_start + lookahead = window_end`, so routing
        // them here can never starve the window just processed.
        for (si, buffer) in pending.iter_mut().enumerate() {
            for ev in buffer.drain(..) {
                // Exact even in floats: every scheduled time is a chain of
                // `start ⊕ t_edge` steps with start ≥ window_start and
                // t_edge ≥ lookahead bit-for-bit, and ⊕ is monotone.
                // Contact gating preserves this: `next_fit` only moves
                // `start` later, and the effective edge time under the
                // plan's slowing-only modifiers is one of `lookahead_at`'s
                // min operands.
                debug_assert!(ev.time >= window_end);
                shards[si].q.push(ev.time, ev.kind);
            }
        }
    }

    // Fold the per-shard completion logs into one accumulator in global
    // completion order, then assemble the per-satellite summaries exactly
    // as the single-threaded engine does.
    let shard_logs: Vec<Vec<TaskLog>> = shards
        .iter_mut()
        .map(|shard| std::mem::take(&mut shard.logs))
        .collect();
    let metrics = fold_sharded(keep_logs, shard_logs);
    // Shard-local fault counters fold with plain sums — commutative, so
    // the totals match the single-threaded handler's sequential bumps.
    collab.retransmits = shards.iter().map(|s| s.retransmits).sum();
    collab.dropped_chunks = shards.iter().map(|s| s.dropped_chunks).sum();
    collab.crashes = shards.iter().map(|s| s.crashes).sum();
    collab.lost_tasks = shards.iter().map(|s| s.lost_tasks).sum();
    collab.cold_scrt_rebuilds = shards.iter().map(|s| s.cold_scrt_rebuilds).sum();
    collab.failover_reselections =
        shards.iter().map(|s| s.failover_reselections).sum();
    collab.timeout_fallbacks = shards.iter().map(|s| s.timeout_fallbacks).sum();
    let makespan = metrics.makespan();
    let per_satellite: Vec<SatSummary> = (0..sats)
        .map(|s| {
            let node = &shards[part.shard_of(s)].nodes[part.local_of(s)];
            SatSummary {
                sat: s,
                tasks: node.state.tasks_processed,
                reused: node.state.tasks_reused,
                busy_s: node.state.busy_time(),
                cpu_occupancy: node.state.cpu_occupancy(makespan),
                collab_requests: node.state.collab_requests,
                times_source: node.state.times_source,
                scrt_len: node.scrt.len(),
                evictions: node.scrt.evictions,
            }
        })
        .collect();

    Ok(metrics.finish(
        scenario,
        cfg.network.n,
        per_satellite,
        cfg.alpha,
        &collab,
        wall_start.elapsed().as_secs_f64(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every `(shard, local)` slot maps to a unique in-range satellite and
    /// `shard_of`/`local_of` invert `sat_of` exactly.
    fn check_bijection(kind: ShardPartition, sats: usize, k: usize) {
        let part = PartitionMap::new(kind, sats, k);
        let mut seen = vec![false; sats];
        let mut total = 0usize;
        for shard in 0..k {
            for local in 0..part.len_of(shard) {
                let sat = part.sat_of(shard, local);
                assert!(sat < sats, "{kind:?} {sats}/{k}: sat {sat} out of range");
                assert!(!seen[sat], "{kind:?} {sats}/{k}: sat {sat} owned twice");
                seen[sat] = true;
                assert_eq!(part.shard_of(sat), shard, "{kind:?} {sats}/{k}: shard_of({sat})");
                assert_eq!(part.local_of(sat), local, "{kind:?} {sats}/{k}: local_of({sat})");
                total += 1;
            }
        }
        assert_eq!(total, sats, "{kind:?} {sats}/{k}: coverage");
    }

    #[test]
    fn partitions_are_bijections() {
        for kind in [ShardPartition::RoundRobin, ShardPartition::Blocks] {
            for sats in [0usize, 1, 2, 3, 9, 25, 49, 225, 441] {
                for k in [1usize, 2, 3, 4, 5, 7, 16] {
                    check_bijection(kind, sats, k);
                }
            }
        }
    }

    #[test]
    fn blocks_ranges_are_contiguous_and_balanced() {
        // 25 satellites over 4 shards: 25 = 4·6 + 1, so shard 0 owns one
        // extra and every shard's range is one contiguous id interval.
        let part = PartitionMap::new(ShardPartition::Blocks, 25, 4);
        assert_eq!(
            (0..4).map(|s| part.len_of(s)).collect::<Vec<_>>(),
            vec![7, 6, 6, 6]
        );
        let mut next = 0usize;
        for shard in 0..4 {
            for local in 0..part.len_of(shard) {
                assert_eq!(part.sat_of(shard, local), next, "non-contiguous block");
                next += 1;
            }
        }
        assert_eq!(next, 25);
    }

    #[test]
    fn blocks_keeps_grid_rows_on_one_shard() {
        // A 4x4 grid over 4 shards: row-major ids make each orbital plane
        // (grid row) exactly one shard — the locality the default buys.
        let part = PartitionMap::new(ShardPartition::Blocks, 16, 4);
        for sat in 0..16 {
            assert_eq!(part.shard_of(sat), sat / 4);
        }
    }

    #[test]
    fn partition_flag_spellings_round_trip() {
        for kind in [ShardPartition::RoundRobin, ShardPartition::Blocks] {
            assert_eq!(ShardPartition::parse(kind.name()), Some(kind));
        }
        assert_eq!(ShardPartition::parse("rr"), Some(ShardPartition::RoundRobin));
        assert_eq!(ShardPartition::parse("hilbert"), None);
        assert_eq!(ShardPartition::default(), ShardPartition::Blocks);
    }
}
