//! Run observation hooks.
//!
//! The engine reports what happens — task completions, collaboration
//! requests, broadcasts, deliveries — through an [`Observer`] instead of
//! ad-hoc `eprintln!` tracing sprinkled through the event loop. The two
//! built-ins:
//!
//! * [`NullObserver`] — the default; every hook is a no-op the optimizer
//!   erases.
//! * [`TraceObserver`] — the `CCRSAT_TRACE` diagnostic stream, emitting
//!   the same `[trace]` lines the pre-refactor inline tracing printed.
//!
//! Incremental *metrics* accumulation is deliberately not an observer: the
//! engine owns a [`crate::metrics::MetricsAccum`] directly (a report must
//! always be produced), and observers are purely additive diagnostics.

use crate::coordinator::sccr::CollabDecision;
use crate::metrics::TaskLog;
use crate::workload::SatId;

/// Hooks the engine fires as the run unfolds. All methods default to
/// no-ops so an observer implements only what it cares about.
pub trait Observer {
    /// A task completed; `log` is the entry the metrics layer records.
    fn on_task_complete(&mut self, log: &TaskLog) {
        let _ = log;
    }

    /// A satellite issued a collaboration request. `all_srs` holds the
    /// current SRS of every satellite (the requester's is `srs`).
    fn on_collab_request(&mut self, now: f64, sat: SatId, srs: f64, all_srs: &[f64]) {
        let _ = (now, sat, srs, all_srs);
    }

    /// A collaboration found a source and launched a broadcast of
    /// `records` records over `decision.area`.
    fn on_collab_broadcast(&mut self, now: f64, decision: &CollabDecision, records: usize) {
        let _ = (now, decision, records);
    }

    /// One broadcast record landed at `dst`.
    fn on_broadcast_deliver(&mut self, now: f64, dst: SatId) {
        let _ = (now, dst);
    }
}

/// The default observer: observes nothing.
pub struct NullObserver;

impl Observer for NullObserver {}

/// `CCRSAT_TRACE` diagnostics: one line per collaboration request and one
/// per launched broadcast, on stderr (the format the inline tracing used).
pub struct TraceObserver;

impl Observer for TraceObserver {
    fn on_collab_request(&mut self, now: f64, sat: SatId, srs: f64, all_srs: &[f64]) {
        let max = all_srs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        eprintln!(
            "[trace] t={now:7.2} req={sat:3} srs={srs:.3} max_srs={max:.3}"
        );
    }

    fn on_collab_broadcast(&mut self, now: f64, decision: &CollabDecision, records: usize) {
        eprintln!(
            "[trace] t={now:7.2} EVENT src={} area={} recs={} expanded={}",
            decision.source,
            decision.area.len(),
            records,
            decision.expanded
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counts every hook — doubles as a compile-time check that custom
    /// observers can accumulate state.
    #[derive(Default)]
    struct Counting {
        completions: usize,
        requests: usize,
        broadcasts: usize,
        deliveries: usize,
    }

    impl Observer for Counting {
        fn on_task_complete(&mut self, _log: &TaskLog) {
            self.completions += 1;
        }
        fn on_collab_request(&mut self, _: f64, _: SatId, _: f64, _: &[f64]) {
            self.requests += 1;
        }
        fn on_collab_broadcast(&mut self, _: f64, _: &CollabDecision, _: usize) {
            self.broadcasts += 1;
        }
        fn on_broadcast_deliver(&mut self, _: f64, _: SatId) {
            self.deliveries += 1;
        }
    }

    #[test]
    fn default_hooks_are_noops_and_custom_hooks_accumulate() {
        let log = TaskLog {
            task_id: 0,
            sat: 0,
            arrival: 0.0,
            start: 0.0,
            completion: 1.0,
            reused: false,
            correct: true,
            ssim: None,
            scene: 0,
            reused_from_scene: None,
            reused_from_sat: None,
        };
        let decision = CollabDecision {
            source: 1,
            area: vec![0, 1],
            expanded: false,
        };
        let mut null = NullObserver;
        null.on_task_complete(&log);
        null.on_collab_request(0.0, 0, 0.1, &[0.1, 0.9]);
        null.on_collab_broadcast(0.0, &decision, 3);
        null.on_broadcast_deliver(0.0, 1);

        let mut c = Counting::default();
        let obs: &mut dyn Observer = &mut c;
        obs.on_task_complete(&log);
        obs.on_collab_request(0.0, 0, 0.1, &[0.1, 0.9]);
        obs.on_collab_broadcast(0.0, &decision, 3);
        obs.on_broadcast_deliver(0.0, 1);
        obs.on_broadcast_deliver(0.5, 0);
        assert_eq!(
            (c.completions, c.requests, c.broadcasts, c.deliveries),
            (1, 1, 1, 2)
        );
    }
}
