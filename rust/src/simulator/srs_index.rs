//! Dense SoA mirror of the per-satellite SRS inputs (eq. 11).
//!
//! Every Alg. 2 trigger snapshots the SRS of *all* satellites and
//! `select_source` scans that snapshot. Reading the inputs straight off
//! the [`SatNode`]s strides through one heap-allocated node per satellite
//! (server state, SCRT, queues — several cache lines apart); this index
//! keeps the three SRS inputs — `tasks_reused`, `tasks_processed`,
//! accumulated busy seconds — in flat parallel arrays so the per-trigger
//! snapshot is one pass over contiguous memory.
//!
//! **Maintenance contract.** The counters only change at two points, and
//! both engines re-sync the owning lane immediately after each:
//!
//! * `SatelliteState::serve` (service start) bumps `tasks_processed` and
//!   `busy_time`;
//! * [`take_completed`](crate::simulator::engine) bumps `tasks_reused`
//!   (only when the completing task was served by reuse).
//!
//! Bit-identity is by construction: [`SrsIndex::srs_of`] feeds the
//! mirrored counters through the *same* canonical pure functions
//! ([`SatelliteState::reuse_rate_of`], [`SatelliteState::occupancy_of`])
//! the node path used, so a synced lane yields bit-for-bit the value
//! `srs(β, state.reuse_rate(), state.cpu_occupancy(now))` would. The
//! sharded engine's `SrsCheckpoint` reconstruction already runs on those
//! same statics, which is what lets one index serve both engines.
//!
//! [`SatNode`]: crate::satellite::SatNode

use crate::coordinator::srs::srs;
use crate::satellite::SatelliteState;
use crate::workload::SatId;

/// Flat SoA copy of every satellite's SRS inputs. See the module docs for
/// the maintenance contract.
#[derive(Clone, Debug)]
pub struct SrsIndex {
    reused: Vec<usize>,
    processed: Vec<usize>,
    busy_s: Vec<f64>,
}

impl SrsIndex {
    /// An index for `sats` satellites, all lanes at their start-of-run
    /// values (zero tasks, zero busy time).
    pub fn new(sats: usize) -> Self {
        SrsIndex {
            reused: vec![0; sats],
            processed: vec![0; sats],
            busy_s: vec![0.0; sats],
        }
    }

    pub fn len(&self) -> usize {
        self.processed.len()
    }

    pub fn is_empty(&self) -> bool {
        self.processed.is_empty()
    }

    /// Re-sync one satellite's lane from its authoritative server state.
    /// Call immediately after any mutation of the SRS inputs (`serve`,
    /// the reuse fold in `take_completed`).
    #[inline]
    pub fn sync(&mut self, sat: SatId, state: &SatelliteState) {
        self.reused[sat] = state.tasks_reused;
        self.processed[sat] = state.tasks_processed;
        self.busy_s[sat] = state.busy_time();
    }

    /// The raw mirrored lane `(tasks_processed, tasks_reused, busy_s)` —
    /// the same triple the sharded engine's `SrsCheckpoint` journals.
    #[inline]
    pub fn lane(&self, sat: SatId) -> (usize, usize, f64) {
        (self.processed[sat], self.reused[sat], self.busy_s[sat])
    }

    /// SRS of one satellite at `now`, bit-identical to
    /// `srs(beta, state.reuse_rate(), state.cpu_occupancy(now))` on a
    /// synced lane (identical inputs through identical pure functions).
    #[inline]
    pub fn srs_of(&self, beta: f64, sat: SatId, now: f64) -> f64 {
        srs(
            beta,
            SatelliteState::reuse_rate_of(self.reused[sat], self.processed[sat]),
            SatelliteState::occupancy_of(self.busy_s[sat], now),
        )
    }

    /// The all-satellite SRS snapshot an Alg. 2 trigger consumes, written
    /// into the caller's reusable buffer: one pass over three contiguous
    /// arrays, no per-satellite pointer chasing.
    pub fn snapshot_into(&self, beta: f64, now: f64, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(self.len());
        for s in 0..self.len() {
            out.push(srs(
                beta,
                SatelliteState::reuse_rate_of(self.reused[s], self.processed[s]),
                SatelliteState::occupancy_of(self.busy_s[s], now),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synced_lane_matches_state_methods_bit_for_bit() {
        let beta = 0.6;
        let mut state = SatelliteState::new(3);
        let mut idx = SrsIndex::new(5);
        for (arrival, service, reused) in
            [(0.0, 2.0, false), (1.0, 0.5, true), (7.0, 1.25, true)]
        {
            state.serve(arrival, service);
            idx.sync(3, &state);
            if reused {
                state.tasks_reused += 1;
                idx.sync(3, &state);
            }
            for now in [0.0, 1.0, 3.75, 100.0] {
                let want = srs(beta, state.reuse_rate(), state.cpu_occupancy(now));
                let got = idx.srs_of(beta, 3, now);
                assert_eq!(got.to_bits(), want.to_bits(), "now {now}");
            }
        }
        assert_eq!(
            idx.lane(3),
            (state.tasks_processed, state.tasks_reused, state.busy_time())
        );
    }

    #[test]
    fn snapshot_matches_per_satellite_reads() {
        let beta = 0.4;
        let mut idx = SrsIndex::new(4);
        let mut states: Vec<SatelliteState> =
            (0..4).map(SatelliteState::new).collect();
        for (s, state) in states.iter_mut().enumerate() {
            state.serve(s as f64, 1.0 + s as f64);
            state.tasks_reused = s % 2;
            idx.sync(s, state);
        }
        let mut snap = Vec::new();
        idx.snapshot_into(beta, 10.0, &mut snap);
        assert_eq!(snap.len(), 4);
        for s in 0..4 {
            assert_eq!(snap[s].to_bits(), idx.srs_of(beta, s, 10.0).to_bits());
            let want = srs(beta, states[s].reuse_rate(), states[s].cpu_occupancy(10.0));
            assert_eq!(snap[s].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn fresh_lanes_read_as_idle() {
        let idx = SrsIndex::new(2);
        // rr = 0, occupancy = 0 → SRS is the beta-weighted floor.
        let v = idx.srs_of(0.5, 1, 5.0);
        let want = srs(0.5, 0.0, 0.0);
        assert_eq!(v.to_bits(), want.to_bits());
        assert_eq!(idx.lane(0), (0, 0, 0.0));
    }
}
