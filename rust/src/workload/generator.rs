//! Task-stream generation: who sees which scene, when.
//!
//! The structure mirrors a constellation sweeping the ground:
//!
//! * each orbit (grid row) images an ordered **ground-track stream** of
//!   scenes, expanded from scene *runs* (a satellite dwells on the same
//!   scene for consecutive captures — temporal locality);
//! * satellites in the same orbit traverse the *same* stream with a slot
//!   lag (`STREAM_LAG` tasks): the leader processes scenes its followers
//!   will see shortly — exactly the redundancy collaborative reuse mines;
//! * adjacent orbits inherit a fraction of each other's scenes
//!   (`INTER_ORBIT_SHARE`), like overlapping swaths of adjacent planes;
//! * per-orbit *redundancy heterogeneity* (run lengths drawn around
//!   `scene_repeat_prob ± repeat_prob_spread/2`) creates the SRS contrast
//!   between reuse-rich and reuse-poor satellites that Alg. 2 exploits;
//! * with probability `1 − shared_pool_prob` a capture is a one-off
//!   private scene (transient events: ships, clouds, fires);
//! * arrivals are Poisson per satellite (the paper's M/M/1 assumption).

use crate::config::SimConfig;
use crate::util::rng::Rng;
use crate::workload::texture::{SceneSpec, TextureSynth};
use crate::workload::{SatId, Task};

/// The generated workload: all tasks, globally sorted by arrival time.
#[derive(Clone, Debug)]
pub struct Workload {
    pub tasks: Vec<Task>,
    /// Tasks per satellite (diagnostics).
    pub per_satellite: Vec<usize>,
    /// Number of distinct scenes generated.
    pub num_scenes: usize,
}

impl Workload {
    /// Tasks arriving at one satellite, in arrival order.
    pub fn tasks_for(&self, sat: SatId) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter(move |t| t.satellite == sat)
    }

    /// Total raw sensor-tile payload held by this workload, in bytes
    /// (pixel buffers only). Streaming preparation bounds the *prepared*
    /// residency, but the raw tiles stay resident for the whole run — this
    /// is the number to watch when sizing constellation-scale streams
    /// (the CLI's streaming summary prints it).
    pub fn raw_bytes(&self) -> usize {
        self.tasks
            .iter()
            .map(|t| t.raw.pixels.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// Classes available to an orbit: a sliding window over the class circle so
/// adjacent orbits overlap heavily and distant orbits diverge.
fn orbit_classes(orbit: usize, num_classes: usize) -> Vec<u16> {
    let window = (num_classes / 3).max(2);
    (0..window)
        .map(|i| (((orbit * 2) + i) % num_classes) as u16)
        .collect()
}

/// Fraction of an orbit stream inherited from the previous orbital plane.
const INTER_ORBIT_SHARE: f64 = 0.4;

/// Tasks per ground region: the sweep dwells on one region's scene pool for
/// this many captures before moving to the next region.
const REGION_LEN: usize = 12;

/// Slot lag between consecutive satellites of one orbit, in tasks: satellite
/// at slot `k` starts `k * STREAM_LAG` positions into the orbit's
/// ground-track stream — higher slots are *leaders* (they image a swath
/// position first), lower slots follow `STREAM_LAG` tasks behind per slot.
/// A large lag means a leader's records cover many of a follower's upcoming
/// scenes, which is the redundancy Alg. 2 mines.
const STREAM_LAG: usize = 6;

/// Build the full workload for a config.
pub fn build_workload(cfg: &SimConfig) -> Workload {
    let n = cfg.network.n;
    let sats = n * n;
    let per_sat = cfg.tasks_per_satellite();
    let mut root = Rng::new(cfg.workload.seed);
    let mut scene_rng = root.split(1);
    let mut capture_rng = root.split(2);
    let mut arrival_rng = root.split(3);
    let mut choice_rng = root.split(4);

    let synth = TextureSynth::new(
        cfg.workload.raw_h,
        cfg.workload.raw_w,
        cfg.workload.intra_scene_jitter,
    );

    let mut next_scene_id: u32 = 0;
    let mut new_scene = |class: u16, rng: &mut Rng| -> SceneSpec {
        let s = SceneSpec::sample(next_scene_id, class, rng);
        next_scene_id += 1;
        s
    };

    // ---- regional ground-track streams --------------------------------------
    // The sweep advances through *regions*: REGION_LEN consecutive captures
    // image one ground region whose small hot-scene pool recurs (Zipf +
    // dwell runs) while the sweep is over it, then the track moves on to
    // the next region. Satellites at higher slots are `STREAM_LAG` tasks
    // ahead per slot — a leader is typically a region ahead of its
    // followers, so the leader's hottest records describe scenery the
    // followers are about to image. Region `r` of orbit `o` inherits part
    // of its pool from region `r − 1` of orbit `o − 1` (adjacent planes
    // sweep overlapping swaths with a time offset).
    let stream_len = per_sat + (n - 1) * STREAM_LAG + 1;
    let num_regions = stream_len.div_ceil(REGION_LEN);
    let pool_size = cfg.workload.scenes_per_satellite.max(2);
    let inherited_count =
        ((INTER_ORBIT_SHARE * pool_size as f64) as usize).min(pool_size - 1);

    // region_pools[orbit][region] -> hot-ranked scene pool
    let mut region_pools: Vec<Vec<Vec<SceneSpec>>> = Vec::with_capacity(n);
    for o in 0..n {
        let classes = orbit_classes(o, cfg.workload.num_classes);
        let mut pools = Vec::with_capacity(num_regions);
        for r in 0..num_regions {
            let mut pool = Vec::with_capacity(pool_size);
            if o > 0 && r > 0 {
                // hot-prefix inheritance from the previous plane's previous
                // region (sweep offset across planes)
                let prev: &Vec<SceneSpec> = &region_pools[o - 1][r - 1];
                pool.extend(prev.iter().take(inherited_count).copied());
            }
            while pool.len() < pool_size {
                let class = classes[choice_rng.below(classes.len())];
                pool.push(new_scene(class, &mut scene_rng));
            }
            pools.push(pool);
        }
        region_pools.push(pools);
    }

    let mut orbit_streams: Vec<Vec<SceneSpec>> = Vec::with_capacity(n);
    for o in 0..n {
        // Per-orbit dwell probability: how redundant this orbit's ground
        // track is. Drawn around the configured base with the configured
        // spread — the heterogeneity knob that creates SRS contrast.
        let jitter = (choice_rng.f64() - 0.5) * cfg.workload.repeat_prob_spread;
        let dwell =
            (cfg.workload.scene_repeat_prob + jitter).clamp(0.05, 0.92);
        let mut stream = Vec::with_capacity(stream_len);
        while stream.len() < stream_len {
            let region = (stream.len() / REGION_LEN).min(num_regions - 1);
            let pool = &region_pools[o][region];
            let weights: Vec<f64> =
                (0..pool.len()).map(|k| 1.0 / (k + 1) as f64).collect();
            let scene = pool[choice_rng.weighted(&weights)];
            // geometric run length with mean 1 / (1 - dwell)
            let mut run = 1usize;
            while choice_rng.f64() < dwell && run < 12 {
                run += 1;
            }
            for _ in 0..run {
                if stream.len() < stream_len {
                    stream.push(scene);
                }
            }
        }
        orbit_streams.push(stream);
    }

    // ---- task streams -----------------------------------------------------
    // The paper distributes the 625-image total evenly; trailing satellites
    // absorb any shortfall so the total matches exactly.
    let mut tasks = Vec::with_capacity(cfg.workload.total_tasks);
    let mut per_satellite = vec![0usize; sats];
    let mut remaining = cfg.workload.total_tasks;
    for sat in 0..sats {
        let count = per_sat.min(remaining);
        remaining -= count;
        per_satellite[sat] = count;
        let orbit = sat / n;
        let slot = sat % n;
        let offset = slot * STREAM_LAG;
        let mut t = 0.0f64;
        for j in 0..count {
            t += arrival_rng.exponential(cfg.workload.arrival_rate_per_sat);
            let scene = if choice_rng.f64() < cfg.workload.shared_pool_prob {
                orbit_streams[orbit][(offset + j) % stream_len]
            } else {
                // transient private scene (one-off capture)
                let classes = orbit_classes(orbit, cfg.workload.num_classes);
                let class = classes[choice_rng.below(classes.len())];
                new_scene(class, &mut scene_rng)
            };
            let raw = synth.render(&scene, &mut capture_rng);
            tasks.push(Task {
                id: 0, // assigned after the arrival sort
                satellite: sat,
                arrival: t,
                scene: scene.id,
                class_id: scene.class_id,
                task_type: 0,
                raw,
            });
        }
    }

    // Total order so a degenerate arrival draw can never panic the sort;
    // the sort is stable, so equal arrivals keep their generation order
    // (ascending satellite id) and task ids stay deterministic.
    tasks.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    // Ids in arrival order: `task.id == index in tasks` — the simulator and
    // record-provenance lookups rely on this invariant.
    for (i, t) in tasks.iter_mut().enumerate() {
        t.id = i;
    }
    Workload {
        tasks,
        per_satellite,
        num_scenes: next_scene_id as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 45;
        cfg.workload.raw_h = 16;
        cfg.workload.raw_w = 16;
        cfg
    }

    #[test]
    fn arrival_sort_is_total_and_stable() {
        // Regression: the arrival sort used `partial_cmp().unwrap()`, so a
        // degenerate (NaN) arrival panicked `build_workload`. The total
        // order places NaN at the axis extreme and, being stable, equal
        // arrivals keep their generation order.
        use crate::workload::ImageData;
        let mk = |satellite: usize, arrival: f64| Task {
            id: 0,
            satellite,
            arrival,
            scene: 0,
            class_id: 0,
            task_type: 0,
            raw: ImageData::new(1, 1, vec![0.0; 3]),
        };
        let mut tasks = vec![
            mk(0, 2.0),
            mk(1, f64::NAN.copysign(1.0)),
            mk(2, 2.0),
            mk(3, 1.0),
        ];
        tasks.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let order: Vec<usize> = tasks.iter().map(|t| t.satellite).collect();
        assert_eq!(order, vec![3, 0, 2, 1]);
    }

    #[test]
    fn total_task_count_exact() {
        let wl = build_workload(&small_cfg());
        assert_eq!(wl.tasks.len(), 45);
        assert_eq!(wl.per_satellite.iter().sum::<usize>(), 45);
    }

    #[test]
    fn paper_5x5_distribution() {
        let mut cfg = SimConfig::paper_default(5);
        cfg.workload.raw_h = 8; // keep the test fast
        cfg.workload.raw_w = 8;
        let wl = build_workload(&cfg);
        assert_eq!(wl.tasks.len(), 625);
        assert!(wl.per_satellite.iter().all(|&c| c == 25));
    }

    #[test]
    fn raw_bytes_counts_every_pixel() {
        let wl = build_workload(&small_cfg());
        // 45 tasks × 16×16×3 f32 pixels
        assert_eq!(wl.raw_bytes(), 45 * 16 * 16 * 3 * 4);
    }

    #[test]
    fn ids_match_positions() {
        let wl = build_workload(&small_cfg());
        for (i, t) in wl.tasks.iter().enumerate() {
            assert_eq!(t.id, i);
        }
    }

    #[test]
    fn deterministic() {
        let a = build_workload(&small_cfg());
        let b = build_workload(&small_cfg());
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.scene, y.scene);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.raw, y.raw);
        }
    }

    #[test]
    fn seed_changes_stream() {
        let a = build_workload(&small_cfg());
        let mut cfg = small_cfg();
        cfg.workload.seed += 1;
        let b = build_workload(&cfg);
        assert!(a
            .tasks
            .iter()
            .zip(&b.tasks)
            .any(|(x, y)| x.scene != y.scene || x.arrival != y.arrival));
    }

    #[test]
    fn arrivals_sorted_and_positive() {
        let wl = build_workload(&small_cfg());
        let mut prev = 0.0;
        for t in &wl.tasks {
            assert!(t.arrival > 0.0);
            assert!(t.arrival >= prev);
            prev = t.arrival;
        }
    }

    #[test]
    fn scenes_repeat_along_track() {
        let wl = build_workload(&small_cfg());
        let mut repeats = 0;
        let mut total = 0;
        for sat in 0..9 {
            let scenes: Vec<u32> = wl.tasks_for(sat).map(|t| t.scene).collect();
            for w in scenes.windows(2) {
                total += 1;
                if w[0] == w[1] {
                    repeats += 1;
                }
            }
        }
        assert!(total > 0);
        assert!(
            repeats as f64 / total as f64 > 0.15,
            "repeat rate {repeats}/{total}"
        );
    }

    #[test]
    fn orbit_mates_share_scenes_with_lag() {
        let wl = build_workload(&small_cfg());
        use std::collections::HashSet;
        // leaders see what followers will see: sat 0 (slot 0) and sat 2
        // (slot 2) of orbit 0 draw from the same stream window
        let s0: HashSet<u32> = wl.tasks_for(0).map(|t| t.scene).collect();
        let s1: HashSet<u32> = wl.tasks_for(1).map(|t| t.scene).collect();
        let s2: HashSet<u32> = wl.tasks_for(2).map(|t| t.scene).collect();
        let overlap01 = s0.intersection(&s1).count();
        let overlap02 = s0.intersection(&s2).count();
        assert!(
            overlap01 + overlap02 > 0,
            "orbit-mates share no scenes at all"
        );
    }

    #[test]
    fn leader_sees_shared_scene_before_follower() {
        // Statistically, the first occurrence of a shared scene should come
        // earlier at the leading slot than at the trailing slot.
        let mut cfg = SimConfig::paper_default(5);
        cfg.workload.total_tasks = 625;
        cfg.workload.raw_h = 8;
        cfg.workload.raw_w = 8;
        let wl = build_workload(&cfg);
        let mut leads = 0i64;
        for orbit in 0..5 {
            let a = orbit * 5 + 4; // slot 4 (leader: deepest stream offset)
            let b = orbit * 5 + 3; // slot 3 (follower)
            use std::collections::HashMap;
            let mut first_a: HashMap<u32, f64> = HashMap::new();
            for t in wl.tasks_for(a) {
                first_a.entry(t.scene).or_insert(t.arrival);
            }
            for t in wl.tasks_for(b) {
                if let Some(&ta) = first_a.get(&t.scene) {
                    if ta < t.arrival {
                        leads += 1;
                    } else {
                        leads -= 1;
                    }
                }
            }
        }
        assert!(leads >= 0, "leaders should not systematically trail: {leads}");
    }

    #[test]
    fn adjacent_orbits_share_scenes() {
        let wl = build_workload(&small_cfg());
        use std::collections::HashSet;
        let orbit0: HashSet<u32> = (0..3).flat_map(|s| wl.tasks_for(s).map(|t| t.scene).collect::<Vec<_>>()).collect();
        let orbit1: HashSet<u32> = (3..6).flat_map(|s| wl.tasks_for(s).map(|t| t.scene).collect::<Vec<_>>()).collect();
        assert!(
            !orbit0.is_disjoint(&orbit1),
            "adjacent orbits must inherit scenes"
        );
    }

    #[test]
    fn orbit_classes_overlap_for_adjacent_orbits() {
        let a = orbit_classes(0, 21);
        let b = orbit_classes(1, 21);
        let overlap = a.iter().filter(|c| b.contains(c)).count();
        assert!(overlap >= a.len() / 2, "adjacent orbits overlap {overlap}");
        // distant orbits diverge
        let far = orbit_classes(8, 21);
        let overlap_far = a.iter().filter(|c| far.contains(c)).count();
        assert!(overlap_far < overlap);
    }

    #[test]
    fn class_ids_in_range() {
        let wl = build_workload(&small_cfg());
        assert!(wl.tasks.iter().all(|t| (t.class_id as usize) < 21));
    }

    #[test]
    fn private_scenes_exist() {
        let mut cfg = small_cfg();
        cfg.workload.shared_pool_prob = 0.5;
        let wl = build_workload(&cfg);
        // one-off scenes appear exactly once
        use std::collections::HashMap;
        let mut counts: HashMap<u32, usize> = HashMap::new();
        for t in &wl.tasks {
            *counts.entry(t.scene).or_default() += 1;
        }
        assert!(counts.values().any(|&c| c == 1), "no private scenes");
    }
}
