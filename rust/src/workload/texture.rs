//! Procedural land-use texture synthesis.
//!
//! Each of the 21 classes renders a distinct parametric pattern family
//! (gratings = agricultural fields, checkers = urban blocks, blobs =
//! forest/chaparral, smooth gradients = water, stripes = runways/roads...).
//! A [`SceneSpec`] instantiates a class with a concrete phase / scale /
//! palette; repeated captures of the same scene differ only by additive
//! sensor noise, so intra-scene SSIM is high while inter-class SSIM is low —
//! the similarity structure computation reuse feeds on.

use crate::util::rng::Rng;
use crate::workload::ImageData;

/// A concrete scene: one class rendered at one location/illumination.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SceneSpec {
    /// Stable scene id.
    pub id: u32,
    /// Land-use class in `[0, num_classes)`.
    pub class_id: u16,
    /// Spatial phase offsets in `[0, 1)`.
    pub phase_x: f32,
    pub phase_y: f32,
    /// Frequency scale in `[0.9, 1.1]`.
    pub scale: f32,
    /// Per-scene illumination shift in `[-12, 12]` (pixel value units).
    pub illum: f32,
}

impl SceneSpec {
    /// Draw a fresh scene of a given class. Scenes of one class spread over
    /// a wide phase/scale/illumination range so *cross-scene* SSIM falls
    /// below `th_sim` while captures of the *same* scene stay above it.
    pub fn sample(id: u32, class_id: u16, rng: &mut Rng) -> Self {
        SceneSpec {
            id,
            class_id,
            phase_x: rng.f32(),
            phase_y: rng.f32(),
            scale: 0.8 + 0.4 * rng.f32(),
            illum: (rng.f32() - 0.5) * 60.0,
        }
    }
}

/// Pattern family. Derived from the class id; several classes share a
/// family but differ in frequency/orientation/palette, mirroring how UC
/// Merced classes (e.g. *agricultural* vs *crops*) share visual statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Family {
    Grating,
    Checker,
    Blobs,
    Gradient,
    Stripes,
}

fn family(class_id: u16) -> Family {
    match class_id % 5 {
        0 => Family::Grating,
        1 => Family::Checker,
        2 => Family::Blobs,
        3 => Family::Gradient,
        _ => Family::Stripes,
    }
}

/// Deterministic per-class constants.
struct ClassParams {
    freq: f32,
    angle: f32,
    base: [f32; 3],
    alt: [f32; 3],
}

fn class_params(class_id: u16) -> ClassParams {
    // Spread classes over frequency/orientation/palette space via a hash.
    let h = (class_id as u64).wrapping_mul(0x9E3779B97F4A7C15);
    let freq = 2.0 + ((h >> 8) % 9) as f32; // 2..10 cycles per tile
    let angle = (((h >> 16) % 180) as f32).to_radians();
    // Dark and bright palette anchors kept ≥ 60 apart so every class has
    // enough contrast for intra-scene SSIM to survive sensor noise.
    let lo = |shift: u32| 25.0 + ((h >> shift) % 90) as f32; // 25..115
    let hi = |shift: u32| 175.0 + ((h >> shift) % 70) as f32; // 175..245
    ClassParams {
        freq,
        angle,
        base: [lo(24), lo(32), lo(40)],
        alt: [hi(26), hi(34), hi(42)],
    }
}

/// Smooth pseudo-noise in [0,1] from integer lattice coordinates.
fn value_noise(ix: i64, iy: i64, seed: u64) -> f32 {
    let mut z = (ix as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((iy as u64).wrapping_mul(0xC2B2AE3D27D4EB4F))
        .wrapping_add(seed.wrapping_mul(0x165667B19E3779F9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    ((z ^ (z >> 31)) >> 11) as f32 * (1.0 / (1u64 << 53) as f32)
}

/// Bilinear-interpolated value noise at a fractional coordinate.
fn smooth_noise(x: f32, y: f32, seed: u64) -> f32 {
    let x0 = x.floor();
    let y0 = y.floor();
    let fx = x - x0;
    let fy = y - y0;
    // smoothstep weights
    let sx = fx * fx * (3.0 - 2.0 * fx);
    let sy = fy * fy * (3.0 - 2.0 * fy);
    let (ix, iy) = (x0 as i64, y0 as i64);
    let n00 = value_noise(ix, iy, seed);
    let n10 = value_noise(ix + 1, iy, seed);
    let n01 = value_noise(ix, iy + 1, seed);
    let n11 = value_noise(ix + 1, iy + 1, seed);
    let a = n00 + sx * (n10 - n00);
    let b = n01 + sx * (n11 - n01);
    a + sy * (b - a)
}

/// Texture renderer.
pub struct TextureSynth {
    h: usize,
    w: usize,
    /// Additive sensor-noise σ in pixel-value units.
    noise_sigma: f32,
}

impl TextureSynth {
    pub fn new(h: usize, w: usize, jitter: f64) -> Self {
        TextureSynth {
            h,
            w,
            noise_sigma: (jitter * 255.0) as f32,
        }
    }

    /// Render one capture of a scene. `rng` drives the per-capture sensor
    /// noise only — two captures of the same scene differ just by noise.
    ///
    /// All per-scene work (the class-parameter hash, `sin_cos` of the
    /// rotation angle, derived frequencies) is hoisted out of the pixel
    /// loop; the loop itself writes into a preallocated row-major buffer
    /// and evaluates only the rotated-coordinate pattern plus the sensor
    /// noise per pixel.
    pub fn render(&self, scene: &SceneSpec, rng: &mut Rng) -> ImageData {
        let p = class_params(scene.class_id);
        let fam = family(scene.class_id);
        let freq = p.freq * scene.scale;
        let (s, c) = p.angle.sin_cos();
        let two_pi_freq = 2.0 * std::f32::consts::PI * freq;
        let ripple_freq = 2.0 * std::f32::consts::PI * freq * 1.7;
        let noise_seed = scene.class_id as u64 + 11;
        let inv_w = 1.0 / self.w as f32;
        let inv_h = 1.0 / self.h as f32;
        // per-channel affine shade: shade = lo[ch] + span[ch] * t + illum
        let lo = [
            p.base[0] + scene.illum,
            p.base[1] + scene.illum,
            p.base[2] + scene.illum,
        ];
        let span = [
            p.alt[0] - p.base[0],
            p.alt[1] - p.base[1],
            p.alt[2] - p.base[2],
        ];
        let mut pixels = vec![0f32; self.h * self.w * 3];
        for (y, row) in pixels.chunks_exact_mut(self.w * 3).enumerate() {
            let v = y as f32 * inv_h;
            // rotate, then phase-shift: ru = c·u − s·v + φx, rv = s·u + c·v + φy
            let ru0 = scene.phase_x - s * v;
            let rv0 = scene.phase_y + c * v;
            for (x, out) in row.chunks_exact_mut(3).enumerate() {
                let u = x as f32 * inv_w;
                let ru = c * u + ru0;
                let rv = s * u + rv0;
                let t = match fam {
                    Family::Grating => 0.5 + 0.5 * (two_pi_freq * ru).sin(),
                    Family::Checker => {
                        let a =
                            ((ru * freq).floor() as i64 + (rv * freq).floor() as i64) & 1;
                        a as f32
                    }
                    Family::Blobs => smooth_noise(ru * freq, rv * freq, noise_seed),
                    Family::Gradient => {
                        // slow large-scale gradient + gentle ripple (water)
                        let g = (ru + rv) * 0.5;
                        let ripple = 0.12 * (ripple_freq * rv).sin();
                        (g.fract() + ripple).clamp(0.0, 1.0)
                    }
                    Family::Stripes => {
                        if (ru * freq).fract() < 0.25 {
                            1.0
                        } else {
                            0.15
                        }
                    }
                };
                for ch in 0..3 {
                    let noisy =
                        lo[ch] + span[ch] * t + self.noise_sigma * rng.normal() as f32;
                    out[ch] = noisy.clamp(0.0, 255.0);
                }
            }
        }
        ImageData::new(self.h, self.w, pixels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth() -> TextureSynth {
        TextureSynth::new(64, 64, 0.06)
    }

    /// Plain global SSIM on grayscale — a test-local oracle.
    fn ssim_gray(a: &ImageData, b: &ImageData) -> f64 {
        let lum = |img: &ImageData| -> Vec<f64> {
            (0..img.h * img.w)
                .map(|i| {
                    (0.299 * img.pixels[i * 3]
                        + 0.587 * img.pixels[i * 3 + 1]
                        + 0.114 * img.pixels[i * 3 + 2]) as f64
                        / 255.0
                })
                .collect()
        };
        let xa = lum(a);
        let xb = lum(b);
        let n = xa.len() as f64;
        let ma = xa.iter().sum::<f64>() / n;
        let mb = xb.iter().sum::<f64>() / n;
        let va = xa.iter().map(|x| (x - ma).powi(2)).sum::<f64>() / n;
        let vb = xb.iter().map(|x| (x - mb).powi(2)).sum::<f64>() / n;
        let cov = xa
            .iter()
            .zip(&xb)
            .map(|(x, y)| (x - ma) * (y - mb))
            .sum::<f64>()
            / n;
        let (c1, c2) = (0.01f64.powi(2), 0.03f64.powi(2));
        let c3 = c2 / 2.0;
        ((2.0 * ma * mb + c1) / (ma * ma + mb * mb + c1))
            * ((2.0 * va.sqrt() * vb.sqrt() + c2) / (va + vb + c2))
            * ((cov + c3) / (va.sqrt() * vb.sqrt() + c3))
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let s = SceneSpec::sample(0, 3, &mut Rng::new(1));
        let a = synth().render(&s, &mut Rng::new(9));
        let b = synth().render(&s, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn same_scene_high_ssim() {
        let synth = synth();
        for class in [0u16, 4, 9, 13, 20] {
            let s = SceneSpec::sample(0, class, &mut Rng::new(class as u64));
            let a = synth.render(&s, &mut Rng::new(1));
            let b = synth.render(&s, &mut Rng::new(2));
            let v = ssim_gray(&a, &b);
            assert!(v > 0.75, "class {class}: intra-scene ssim {v}");
        }
    }

    #[test]
    fn different_class_low_ssim() {
        let synth = synth();
        let mut below = 0;
        let mut total = 0;
        for ca in 0u16..7 {
            for cb in (ca + 1)..7 {
                let sa = SceneSpec::sample(0, ca, &mut Rng::new(5));
                let sb = SceneSpec::sample(1, cb, &mut Rng::new(6));
                let a = synth.render(&sa, &mut Rng::new(1));
                let b = synth.render(&sb, &mut Rng::new(2));
                let v = ssim_gray(&a, &b);
                total += 1;
                if v < 0.7 {
                    below += 1;
                }
            }
        }
        // the overwhelming majority of cross-class pairs must fail th_sim
        assert!(
            below * 10 >= total * 9,
            "only {below}/{total} cross-class pairs below th_sim"
        );
    }

    #[test]
    fn pixels_in_range() {
        let s = SceneSpec::sample(2, 7, &mut Rng::new(3));
        let img = synth().render(&s, &mut Rng::new(4));
        assert!(img
            .pixels
            .iter()
            .all(|&p| (0.0..=255.0).contains(&p)));
    }

    #[test]
    fn all_classes_render_distinct_images() {
        let synth = synth();
        let mut means = Vec::new();
        for class in 0..21u16 {
            let s = SceneSpec::sample(class as u32, class, &mut Rng::new(8));
            let img = synth.render(&s, &mut Rng::new(1));
            let mean: f32 =
                img.pixels.iter().sum::<f32>() / img.pixels.len() as f32;
            means.push(mean);
        }
        // not all identical
        let first = means[0];
        assert!(means.iter().any(|m| (m - first).abs() > 1.0));
    }

    #[test]
    fn noise_free_renders_identical() {
        let synth = TextureSynth::new(32, 32, 0.0);
        let s = SceneSpec::sample(0, 1, &mut Rng::new(2));
        let a = synth.render(&s, &mut Rng::new(1));
        let b = synth.render(&s, &mut Rng::new(99));
        assert_eq!(a, b, "zero jitter must be capture-independent");
    }
}
