//! Synthetic remote-sensing workload — the UC Merced Land Use stand-in.
//!
//! The reuse dynamics the paper measures depend only on the *similarity
//! structure* of the task stream: images of the same scene are near
//! duplicates, scenes repeat along a satellite's ground track, and
//! neighbouring satellites observe overlapping scene pools. The procedural
//! generator reproduces exactly that structure with controllable knobs
//! ([`crate::config::WorkloadConfig`]), while the per-record *payload
//! size* used by the communication model stays at the paper's 20.5 MB per
//! image.
//!
//! Module map:
//!
//! * [`generator`] — who sees which scene, when: regional ground-track
//!   streams with slot lag, inter-orbit inheritance and Poisson arrivals,
//!   assembled by [`build_workload`] into a [`Workload`] of [`Task`]s;
//! * [`texture`] — procedural land-use texture synthesis: each
//!   [`SceneSpec`] renders a class-specific parametric pattern, and
//!   repeated captures differ only by sensor noise, giving the
//!   high-intra / low-inter scene similarity the SSIM gate relies on.

pub mod generator;
pub mod texture;

pub use generator::{build_workload, Workload};
pub use texture::{SceneSpec, TextureSynth};

/// Satellite index inside the N×N grid (row-major: orbit * n + slot).
pub type SatId = usize;

/// A raw sensor tile: row-major `[h, w, 3]`, values in `[0, 255]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ImageData {
    pub h: usize,
    pub w: usize,
    pub pixels: Vec<f32>,
}

impl ImageData {
    pub fn new(h: usize, w: usize, pixels: Vec<f32>) -> Self {
        assert_eq!(pixels.len(), h * w * 3, "pixel buffer size mismatch");
        ImageData { h, w, pixels }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, c: usize) -> f32 {
        self.pixels[(y * self.w + x) * 3 + c]
    }
}

/// One data-processing subtask `t ∈ Γ^s` (a remote-sensing image to label).
#[derive(Clone, Debug)]
pub struct Task {
    /// Globally unique, dense id.
    pub id: usize,
    /// Satellite this task arrives at.
    pub satellite: SatId,
    /// Virtual arrival time, seconds (Poisson process per satellite).
    pub arrival: f64,
    /// Scene identity (generator ground truth; never shown to algorithms).
    pub scene: u32,
    /// Land-use class of the scene (generator ground truth; diagnostics).
    pub class_id: u16,
    /// Task type `P_t` — all tasks here are land-use classification.
    pub task_type: u16,
    /// The raw image `D_t`.
    pub raw: ImageData,
}
