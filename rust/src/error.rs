//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every CCRSat layer.
#[derive(Error, Debug)]
pub enum Error {
    /// PJRT / XLA runtime failure (compile, execute, literal conversion).
    #[error("xla runtime: {0}")]
    Xla(#[from] xla::Error),

    /// Artifact or manifest problem (missing file, shape mismatch, ...).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Configuration parse/validation failure.
    #[error("config: {0}")]
    Config(String),

    /// JSON parse failure (manifest, reports).
    #[error("json: {0}")]
    Json(String),

    /// Simulation-level invariant violation.
    #[error("simulation: {0}")]
    Simulation(String),

    /// Anything I/O.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand for [`Error::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }

    /// Shorthand for [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Shorthand for [`Error::Simulation`].
    pub fn simulation(msg: impl Into<String>) -> Self {
        Error::Simulation(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
