//! Crate-wide error type.
//!
//! Hand-rolled `Display` / `std::error::Error` implementations: the offline
//! build image has no crates.io access, so `thiserror` is not available.

use std::fmt;

/// Unified error for every CCRSat layer.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failure (compile, execute, literal conversion).
    Xla(String),

    /// Artifact or manifest problem (missing file, shape mismatch, ...).
    Artifact(String),

    /// Configuration parse/validation failure.
    Config(String),

    /// JSON parse failure (manifest, reports).
    Json(String),

    /// Simulation-level invariant violation.
    Simulation(String),

    /// Anything I/O.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Simulation(m) => write!(f, "simulation: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Shorthand for [`Error::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }

    /// Shorthand for [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Shorthand for [`Error::Simulation`].
    pub fn simulation(msg: impl Into<String>) -> Self {
        Error::Simulation(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_layer() {
        assert_eq!(Error::config("bad n").to_string(), "config: bad n");
        assert_eq!(
            Error::simulation("oops").to_string(),
            "simulation: oops"
        );
        assert_eq!(Error::artifact("gone").to_string(), "artifact: gone");
        assert_eq!(Error::Json("eof".into()).to_string(), "json: eof");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io: "));
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::config("x")).is_none());
    }
}
