//! Benchmark + experiment harness.
//!
//! [`bench`] is a small criterion-style measurement utility (criterion is
//! not available in this offline image); [`experiments`] hosts the runners
//! that regenerate every table and figure of the paper's evaluation —
//! shared by `benches/*.rs`, `examples/` and the `ccrsat reproduce` CLI.
//! [`hotpath`] is the per-task-path benchmark suite behind `ccrsat bench`,
//! `benches/hotpath.rs` and the CI perf-regression budget.

pub mod bench;
pub mod experiments;
pub mod hotpath;
