//! Benchmark + experiment harness.
//!
//! [`bench`] is a small criterion-style measurement utility (criterion is
//! not available in this offline image); [`experiments`] hosts the runners
//! that regenerate every table and figure of the paper's evaluation —
//! shared by `benches/*.rs`, `examples/` and the `ccrsat reproduce` CLI.

pub mod bench;
pub mod experiments;
