//! Minimal benchmarking utility (criterion-style, offline-friendly).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use ccrsat::harness::bench::Bencher;
//! let mut b = Bencher::new("scrt");
//! b.bench("insert", || { /* hot path */ });
//! b.report();
//! b.write_json("BENCH_scrt.json").unwrap();
//! ```
//!
//! Besides the stdout report, a [`Bencher`] serializes its measurements to
//! the machine-readable `BENCH_*.json` schema (`ccrsat-bench-v1`) that the
//! CI perf budget consumes — see [`crate::harness::hotpath`].

use std::time::{Duration, Instant};

use crate::error::Result;
use crate::util::json::Json;

/// Schema marker every `BENCH_*.json` artifact carries; consumers
/// ([`crate::harness::hotpath`], the CI perf budget) key on it.
pub const SCHEMA: &str = "ccrsat-bench-v1";

/// Defeat the optimizer without `std::hint::black_box` availability issues.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iterations: u64,
    pub total: Duration,
    pub per_iter_ns: f64,
    pub throughput_per_s: f64,
}

impl Measurement {
    /// Serialize one measurement (`ccrsat-bench-v1` entry).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iterations", Json::num(self.iterations as f64)),
            ("total_ns", Json::num(self.total.as_nanos() as f64)),
            ("per_iter_ns", Json::num(self.per_iter_ns)),
            ("throughput_per_s", Json::num(self.throughput_per_s)),
        ])
    }
}

/// Bench runner: warms up, then measures for a wall-clock budget.
pub struct Bencher {
    group: String,
    warmup: Duration,
    budget: Duration,
    results: Vec<Measurement>,
}

impl Bencher {
    pub fn new(group: impl Into<String>) -> Self {
        Bencher {
            group: group.into(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(1),
            results: Vec::new(),
        }
    }

    /// Override the measurement budget (long-running end-to-end benches).
    pub fn with_budget(mut self, warmup: Duration, budget: Duration) -> Self {
        self.warmup = warmup;
        self.budget = budget;
        self
    }

    /// Measure a closure repeatedly until the budget is spent.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        // warmup
        let w_end = Instant::now() + self.warmup;
        while Instant::now() < w_end {
            f();
        }
        // measure
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.budget {
            f();
            iters += 1;
        }
        let total = start.elapsed();
        let per_iter_ns = total.as_nanos() as f64 / iters.max(1) as f64;
        let m = Measurement {
            name: name.to_string(),
            iterations: iters,
            total,
            per_iter_ns,
            throughput_per_s: 1e9 / per_iter_ns,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Measure a closure exactly once (end-to-end scenario runs).
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &Measurement {
        let start = Instant::now();
        f();
        let total = start.elapsed();
        let m = Measurement {
            name: name.to_string(),
            iterations: 1,
            total,
            per_iter_ns: total.as_nanos() as f64,
            throughput_per_s: 1e9 / total.as_nanos().max(1) as f64,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print the group report.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        for m in &self.results {
            println!(
                "{:<44} {:>12} iters   {}",
                m.name,
                m.iterations,
                format_ns(m.per_iter_ns)
            );
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn group(&self) -> &str {
        &self.group
    }

    /// Serialize the whole group to the `ccrsat-bench-v1` schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("group", Json::str(self.group.clone())),
            ("warmup_ms", Json::num(self.warmup.as_secs_f64() * 1e3)),
            ("budget_ms", Json::num(self.budget.as_secs_f64() * 1e3)),
            (
                "measurements",
                Json::Arr(self.results.iter().map(Measurement::to_json).collect()),
            ),
        ])
    }

    /// Write the group report as pretty-printed JSON (`BENCH_*.json`).
    pub fn write_json(&self, path: &str) -> Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(())
    }
}

/// Pretty-print nanoseconds per iteration.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns/iter")
    } else if ns < 1e6 {
        format!("{:8.2} µs/iter", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms/iter", ns / 1e6)
    } else {
        format!("{:8.3}  s/iter", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test").with_budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let m = b.bench("noop-ish", || {
            black_box(42u64.wrapping_mul(7));
        });
        assert!(m.iterations > 100);
        assert!(m.per_iter_ns > 0.0);
    }

    #[test]
    fn bench_once_single_iteration() {
        let mut b = Bencher::new("test");
        let m = b.bench_once("one", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(m.iterations, 1);
        assert!(m.per_iter_ns >= 2e6);
    }

    #[test]
    fn json_roundtrip() {
        let mut b = Bencher::new("grp").with_budget(
            Duration::from_millis(1),
            Duration::from_millis(5),
        );
        b.bench("op", || {
            black_box(1u64.wrapping_add(2));
        });
        let text = b.to_json().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.at(&["schema"]).unwrap().as_str().unwrap(),
            "ccrsat-bench-v1"
        );
        assert_eq!(back.at(&["group"]).unwrap().as_str().unwrap(), "grp");
        let ms = back.at(&["measurements"]).unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].at(&["name"]).unwrap().as_str().unwrap(), "op");
        assert!(ms[0].at(&["per_iter_ns"]).unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn format_ns_units() {
        assert!(format_ns(500.0).contains("ns"));
        assert!(format_ns(5e4).contains("µs"));
        assert!(format_ns(5e7).contains("ms"));
        assert!(format_ns(5e9).contains("s/iter"));
    }
}
