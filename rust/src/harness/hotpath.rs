//! The hot-path benchmark suite and the perf-regression budget.
//!
//! One suite, three consumers: `cargo bench --bench hotpath`, the
//! `ccrsat bench` CLI subcommand, and the CI perf job. All of them run
//! [`run_suite`], write the machine-readable `BENCH_hotpath.json`
//! artifact (schema `ccrsat-bench-v1`, see [`crate::harness::bench`]) and
//! can compare it against the committed `benches/baseline.json` via
//! [`check_against_baseline`] — which is how "measurably faster" claims
//! stay enforceable instead of anecdotal.
//!
//! The SCRT microbenches run at the paper-sized table (~32 records, the
//! Table I cache budget) and — in `--scale` mode — at production-scale
//! table sizes (512/2048 records) plus the extended 11×11 / 15×15 grids
//! of [`crate::harness::experiments::EXTENDED_SCALES`].

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::compute::kernels::{gemm_nt, gemv};
use crate::compute::{native::ssim_global, ComputeBackend, NativeBackend, Preprocessed};
use crate::config::{SimConfig, TopologyMode};
use crate::coordinator::scrt::{Record, Scrt};
use crate::coordinator::Scenario;
use crate::error::{Error, Result};
use crate::harness::bench::{black_box, format_ns, Bencher, Measurement};
use crate::harness::experiments::{run_scale_suite_timed, EXTENDED_SCALES};
use crate::satellite::SatelliteState;
use crate::simulator::events::{EventKind, EventQueue};
use crate::simulator::srs_index::SrsIndex;
use crate::simulator::{prepare, ShardPartition, Simulation};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::build_workload;
use crate::workload::texture::{SceneSpec, TextureSynth};

/// Default output artifact of the suite.
pub const DEFAULT_OUT: &str = "BENCH_hotpath.json";

/// Committed perf baseline the CI budget compares against. Refresh with
/// `ccrsat bench --scale --out benches/baseline.json` on a quiet machine.
pub const BASELINE_PATH: &str = "benches/baseline.json";

/// Default regression factor: fail when a tracked per-iteration time is
/// more than 2× its baseline.
pub const DEFAULT_FACTOR: f64 = 2.0;

/// Paper-sized SCRT table (Table I cache budget ≈ 31 records).
const SCRT_PAPER: usize = 32;

/// Production-scale SCRT tables exercised in `--scale` mode.
const SCRT_SCALE: [usize; 2] = [512, 2048];

/// Options for one suite run.
#[derive(Clone, Copy, Debug)]
pub struct HotpathOpts {
    pub warmup: Duration,
    pub budget: Duration,
    /// Also run the production-scale SCRT sizes and the 11×11 / 15×15
    /// end-to-end scale suites (minutes, not milliseconds).
    pub scale: bool,
}

impl Default for HotpathOpts {
    fn default() -> Self {
        HotpathOpts {
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(700),
            scale: false,
        }
    }
}

fn fake_pre(rng: &mut Rng) -> Preprocessed {
    let pd: Vec<f32> = (0..3072).map(|_| rng.f32()).collect();
    let gray: Vec<f32> = (0..1024).map(|_| rng.f32()).collect();
    Preprocessed {
        h: 32,
        w: 32,
        pd,
        gray,
    }
}

fn fake_record(id: usize, rng: &mut Rng) -> Record {
    Record {
        id,
        pre: Arc::new(fake_pre(rng)),
        task_type: 0,
        result: (id % 21) as u32,
        reuse_count: (id % 7) as u32,
        last_used: id as f64,
        origin: id % 25,
    }
}

/// SCRT microbenches at one table size: NN scan, identity probe, top-τ
/// selection and the insert-at-capacity eviction path.
fn scrt_benches(b: &mut Bencher, cap: usize, rng: &mut Rng) {
    let mut scrt = Scrt::new(4, cap);
    // Keep one mid-table record's features around as the reuse-hit probe
    // below (the index is ≡ 1 mod 4 for every suite cap, so it lands in
    // the probed bucket).
    let hit_idx = cap / 2 + 1;
    let mut hit_probe = None;
    for i in 0..cap - 1 {
        let rec = fake_record(i, rng);
        if i == hit_idx {
            hit_probe = Some(rec.pre.clone());
        }
        scrt.insert((i % 4) as u32, rec);
    }
    let hit_probe = hit_probe.expect("hit probe captured");
    debug_assert_eq!(hit_idx % 4, 1, "hit probe must land in bucket 1");
    let probe = fake_pre(rng);
    b.bench(&format!("scrt_nearest_{cap}"), || {
        black_box(scrt.nearest(1, 0, &probe));
    });
    // The quantized-coarse-scan regime: the probe *is* a stored record,
    // so the coarse winner re-ranks at distance ~0 and nearly every other
    // slot is excluded by its quantized lower bound — the reuse-hit fast
    // path the per-bucket quantized mirror targets. (At the paper-sized
    // table the bucket is below the coarse-scan gate and this measures
    // the exact-scan fallback instead.)
    b.bench(&format!("scrt_nearest_quant_{cap}"), || {
        black_box(scrt.nearest(1, 0, &hit_probe));
    });
    let present = cap / 2;
    b.bench(&format!("scrt_contains_{cap}"), || {
        black_box(scrt.contains(present) | scrt.contains(usize::MAX));
    });
    b.bench(&format!("scrt_top_tau_11_{cap}"), || {
        black_box(scrt.top_tau(11));
    });
    // Insert from a small clone pool so record construction stays cheap
    // and the eviction path dominates the measurement.
    let pool: Vec<Record> = (0..8).map(|k| fake_record(k, rng)).collect();
    let mut next_id = 1_000_000usize;
    b.bench(&format!("scrt_insert_evict_{cap}"), || {
        let mut r = pool[next_id % 8].clone();
        r.id = next_id;
        r.reuse_count = (next_id % 7) as u32;
        r.last_used = next_id as f64;
        black_box(scrt.insert((next_id % 4) as u32, r));
        next_id += 1;
    });
}

/// Run the hot-path suite and return the populated [`Bencher`].
pub fn run_suite(opts: &HotpathOpts) -> Result<Bencher> {
    let mut b = Bencher::new("hotpath").with_budget(opts.warmup, opts.budget);
    let mut rng = Rng::new(42);

    // ---- SCRT operations (paper-sized, then production-scale) ----------
    scrt_benches(&mut b, SCRT_PAPER, &mut rng);
    if opts.scale {
        for &cap in &SCRT_SCALE {
            scrt_benches(&mut b, cap, &mut rng);
        }
    }

    // ---- native kernels -------------------------------------------------
    let a = fake_pre(&mut rng);
    let c = fake_pre(&mut rng);
    b.bench("ssim_global_1024", || {
        black_box(ssim_global(&a.gray, &c.gray).unwrap());
    });
    let cfg = SimConfig::paper_default(5);
    let native = NativeBackend::new(&cfg);
    b.bench("lsh_bucket_3072", || {
        black_box(native.lsh_bucket(&a).unwrap());
    });
    b.bench("classify_3072", || {
        black_box(native.classify(&a).unwrap());
    });
    // Batched classify (GEMM path): per-iteration time covers the whole
    // 64-task batch, so per-task cost is per_iter / 64.
    let batch_pres: Vec<Preprocessed> = (0..64).map(|_| fake_pre(&mut rng)).collect();
    let batch_refs: Vec<&Preprocessed> = batch_pres.iter().collect();
    b.bench("classify_batch64_3072", || {
        black_box(native.classify_many(&batch_refs).unwrap());
    });
    b.bench("lsh_bucket_batch64_3072", || {
        black_box(native.lsh_bucket_many(&batch_refs).unwrap());
    });

    // ---- raw kernels (shapes of the classifier / LSH paths) -------------
    let wmat: Vec<f32> = (0..21 * 3072).map(|_| rng.f32() - 0.5).collect();
    let xvec: Vec<f32> = (0..3072).map(|_| rng.f32()).collect();
    let mut gemv_out = vec![0f32; 21];
    b.bench("gemv_21x3072", || {
        gemv(&wmat, 21, 3072, &xvec, &mut gemv_out);
        black_box(gemv_out[0]);
    });
    let xmat: Vec<f32> = (0..64 * 3072).map(|_| rng.f32()).collect();
    let mut gemm_out = vec![0f32; 64 * 21];
    b.bench("gemm_64x21x3072", || {
        gemm_nt(&xmat, 64, &wmat, 21, 3072, &mut gemm_out);
        black_box(gemm_out[0]);
    });

    // ---- event queue churn (bucketed calendar queue) --------------------
    // Steady-state hold of 64k pending events: each iteration pops the
    // global minimum and pushes a replacement a short random offset past
    // it — the near-future calendar regime both engines' loops live in.
    // The old binary heap paid an O(log 64k) sift on both sides of this
    // pair; the calendar queue's budget prices the bucketed path.
    let mut q = EventQueue::new();
    for i in 0..65_536 {
        q.push(rng.f64() * 1000.0, EventKind::Arrival(i));
    }
    b.bench("event_queue_churn_64k", || {
        let ev = q.pop().expect("churn keeps the queue at 64k events");
        q.push(ev.time + rng.f64() * 2.0, EventKind::Arrival(0));
        black_box(ev.time);
    });

    // ---- collaboration fan-out (SoA snapshot + zero-copy top-τ) ---------
    // The Alg. 2 per-trigger core at a 15×15 constellation: one
    // contiguous SRS snapshot over 225 SoA lanes, the best-source scan,
    // and the τ-record fan-out, which must hand out the stored payload
    // `Arc`s — the old path re-cloned pd + gray (~16 KB) per record per
    // trigger, and the budget is set so that path cannot return.
    let mut fan_scrt = Scrt::new(4, 32);
    for i in 0..31 {
        fan_scrt.insert((i % 4) as u32, fake_record(i, &mut rng));
    }
    let mut fan_idx = SrsIndex::new(225);
    for s in 0..225 {
        let mut st = SatelliteState::new(s);
        for k in 0..1 + s % 7 {
            st.serve(k as f64, 0.5 + (s % 5) as f64 * 0.1);
        }
        st.tasks_reused = s % 3;
        fan_idx.sync(s, &st);
    }
    let mut fan_snap: Vec<f64> = Vec::new();
    b.bench("collab_fanout_15x15", || {
        fan_idx.snapshot_into(0.5, 1000.0, &mut fan_snap);
        let best = fan_snap
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(s, _)| s)
            .unwrap();
        let shared: Vec<(u32, std::sync::Arc<Record>)> = fan_scrt
            .top_tau(11)
            .into_iter()
            .map(|(bkt, r)| (bkt, std::sync::Arc::new(r)))
            .collect();
        black_box((best, shared.len()));
    });

    // ---- workload generation + preprocessing ----------------------------
    let synth = TextureSynth::new(cfg.workload.raw_h, cfg.workload.raw_w, 0.05);
    let scene = SceneSpec::sample(0, 3, &mut Rng::new(7));
    let mut render_rng = Rng::new(99);
    b.bench("render_64x64", || {
        black_box(synth.render(&scene, &mut render_rng));
    });
    let img = synth.render(&scene, &mut Rng::new(100));
    b.bench("preprocess_64x64", || {
        black_box(native.preprocess(&img).unwrap());
    });

    // ---- PJRT dispatch (only when artifacts are usable) -----------------
    // An unusable engine (missing feature, stale artifacts, failed
    // warmup) skips these three benches but never aborts the suite: the
    // native measurements the perf budget tracks must always land.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match crate::compute::PjrtBackend::from_dir("artifacts") {
            Ok(pjrt) => match pjrt.engine().warmup() {
                Ok(()) => {
                    b.bench("pjrt_ssim_dispatch", || {
                        black_box(pjrt.ssim(&a, &c).unwrap());
                    });
                    b.bench("pjrt_lsh_dispatch", || {
                        black_box(pjrt.lsh_bucket(&a).unwrap());
                    });
                    b.bench("pjrt_classify_dispatch", || {
                        black_box(pjrt.classify(&a).unwrap());
                    });
                }
                Err(e) => eprintln!(
                    "note: skipping pjrt dispatch benches (warmup failed: {e})"
                ),
            },
            Err(e) => eprintln!("note: skipping pjrt dispatch benches ({e})"),
        }
    }

    // ---- end-to-end scenarios (native backend, 3×3 / 45 tasks) ----------
    let mut small = SimConfig::paper_default(3);
    small.workload.total_tasks = 45;
    let backend = NativeBackend::new(&small);
    let wl = build_workload(&small);
    let prep = prepare(&backend, &wl)?;
    b.bench("simulate_slcr_3x3_45", || {
        let r = Simulation::new(&small, &backend, Scenario::Slcr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        black_box(r.reused_tasks);
    });
    b.bench("simulate_sccr_3x3_45", || {
        let r = Simulation::new(&small, &backend, Scenario::Sccr)
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        black_box(r.reused_tasks);
    });

    // ---- engine event loop, per grid size (preparation excluded) --------
    // Unlike the simulate_* cases above, these run aggregate-only so the
    // measurement isolates the engine's event dispatch + per-task reuse
    // path without TaskLog retention. 3×3 reuses the simulate fixtures.
    b.bench("event_loop_3x3_45", || {
        let r = Simulation::new(&small, &backend, Scenario::Sccr)
            .aggregate_only()
            .with_workload(&wl)
            .with_prepared(&prep)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });
    let mut mid = SimConfig::paper_default(5);
    mid.workload.total_tasks = 125;
    let backend5 = NativeBackend::new(&mid);
    let wl5 = build_workload(&mid);
    let prep5 = prepare(&backend5, &wl5)?;
    b.bench("event_loop_5x5_125", || {
        let r = Simulation::new(&mid, &backend5, Scenario::Sccr)
            .aggregate_only()
            .with_workload(&wl5)
            .with_prepared(&prep5)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });
    // Sharded conservative engine over the same fixture (4 worker
    // shards, bit-identical report): at this small scale it mostly
    // tracks window/barrier overhead — the CI-visible canary for the
    // sharded path; the real speedup lives in the `--scale` cases.
    b.bench("event_loop_5x5_125_t4", || {
        let r = Simulation::new(&mid, &backend5, Scenario::Sccr)
            .aggregate_only()
            .threads(4)
            .with_workload(&wl5)
            .with_prepared(&prep5)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });
    // Same fixture over lossy, chunked ISLs (20% chunk loss, ~5 MB
    // chunks): the cost of plan-time fault resolution — per-chunk fate
    // draws, retransmission scheduling, possession-cache dedup — on top
    // of the ideal-link event loop above.
    let mut lossy = mid.clone();
    lossy.comm.loss_prob = 0.2;
    lossy.comm.chunk_bytes = 5e6;
    b.bench("event_loop_5x5_125_lossy", || {
        let r = Simulation::new(&lossy, &backend5, Scenario::Sccr)
            .aggregate_only()
            .with_workload(&wl5)
            .with_prepared(&prep5)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });
    // Same fixture under aggressive node faults (random crashes roughly
    // once per satellite over the ~17 s horizon, 2 s reboots that wipe
    // the SCRT, short collaboration timeouts): the cost of crash/reboot
    // event churn, liveness-filtered source selection and the failover
    // retry cascade on top of the ideal-link event loop above.
    let mut crashy = mid.clone();
    crashy.faults.mtbf_s = 15.0;
    crashy.faults.downtime_s = 2.0;
    crashy.faults.collab_timeout_s = 1.5;
    b.bench("event_loop_5x5_125_crashy", || {
        let r = Simulation::new(&crashy, &backend5, Scenario::Sccr)
            .aggregate_only()
            .with_workload(&wl5)
            .with_prepared(&prep5)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });
    // Same fixture under a time-varying Walker contact plan on the
    // 4-shard conservative engine: every broadcast goes through the
    // contact-gated chunk planner and every window boundary re-queries
    // `lookahead_at`, so this is the canary for both the `next_fit`
    // fixpoint and the per-window lookahead machinery.
    let mut walker = mid.clone();
    walker.topology.mode = TopologyMode::Walker;
    walker.topology.duty = 0.7;
    walker.topology.period_s = 120.0;
    b.bench("event_loop_walker_t4", || {
        let r = Simulation::new(&walker, &backend5, Scenario::Sccr)
            .aggregate_only()
            .threads(4)
            .with_workload(&wl5)
            .with_prepared(&prep5)
            .run()
            .unwrap();
        black_box(r.total_tasks);
    });

    // ---- extended grids (11×11, 15×15), one timed pass each -------------
    if opts.scale {
        let base = SimConfig::paper_default(5);
        let backend = NativeBackend::new(&base);
        for &n in &EXTENDED_SCALES {
            b.bench_once(&format!("scale_suite_{n}x{n}"), || {
                let (reports, _timing) =
                    run_scale_suite_timed(&base, &backend, &[n], &Scenario::ALL)
                        .expect("extended scale suite");
                black_box(reports.len());
            });
        }
        // Engine event loop at the extended grids: prepare once outside
        // the timed region, measure one aggregate-only SCCR pass — then
        // the same pass on the sharded engine with 4 worker shards. The
        // headline number of the sharded rework is
        // `event_loop_15x15_625_t4` vs `event_loop_15x15_625`.
        for &n in &EXTENDED_SCALES {
            let mut big = SimConfig::paper_default(n);
            big.workload.total_tasks = 625;
            let backend_n = NativeBackend::new(&big);
            let wl_n = build_workload(&big);
            let prep_n = prepare(&backend_n, &wl_n)?;
            b.bench_once(&format!("event_loop_{n}x{n}_625"), || {
                let r = Simulation::new(&big, &backend_n, Scenario::Sccr)
                    .aggregate_only()
                    .with_workload(&wl_n)
                    .with_prepared(&prep_n)
                    .run()
                    .unwrap();
                black_box(r.total_tasks);
            });
            b.bench_once(&format!("event_loop_{n}x{n}_625_t4"), || {
                let r = Simulation::new(&big, &backend_n, Scenario::Sccr)
                    .aggregate_only()
                    .threads(4)
                    .with_workload(&wl_n)
                    .with_prepared(&prep_n)
                    .run()
                    .unwrap();
                black_box(r.total_tasks);
            });
            // The same headline case with the blocked partition pinned
            // explicitly (the `_t4` twin above rides the engine default,
            // so this entry keeps a tracked number for the explicit
            // `--partition blocks` path even if the default ever moves).
            if n == 15 {
                b.bench_once(&format!("event_loop_{n}x{n}_625_t4_blocks"), || {
                    let r = Simulation::new(&big, &backend_n, Scenario::Sccr)
                        .aggregate_only()
                        .threads(4)
                        .partition(ShardPartition::Blocks)
                        .with_workload(&wl_n)
                        .with_prepared(&prep_n)
                        .run()
                        .unwrap();
                    black_box(r.total_tasks);
                });
            }
        }
        // Constellation-scale sharded case: the 21×21 grid (441
        // satellites) with the CI smoke workload, 4 worker shards.
        let mut huge = SimConfig::paper_default(21);
        huge.workload.total_tasks = 882;
        let backend21 = NativeBackend::new(&huge);
        let wl21 = build_workload(&huge);
        let prep21 = prepare(&backend21, &wl21)?;
        b.bench_once("event_loop_21x21_882_t4", || {
            let r = Simulation::new(&huge, &backend21, Scenario::Sccr)
                .aggregate_only()
                .threads(4)
                .with_workload(&wl21)
                .with_prepared(&prep21)
                .run()
                .unwrap();
            black_box(r.total_tasks);
        });
        // Collaboration-heavy 15×15: a near-unreachable SRS threshold and
        // a short cooldown make most completions fire the Alg. 2 trigger,
        // so this case prices the collaboration machinery itself — the
        // all-satellite SRS snapshot, source selection and the τ-record
        // broadcast fan-out — rather than the service path the plain
        // `event_loop_15x15_625` case tracks.
        let mut collab_cfg = SimConfig::paper_default(15);
        collab_cfg.workload.total_tasks = 625;
        collab_cfg.reuse.th_co = 0.95;
        collab_cfg.reuse.collab_cooldown_s = 1.0;
        let backend_c = NativeBackend::new(&collab_cfg);
        let wl_c = build_workload(&collab_cfg);
        let prep_c = prepare(&backend_c, &wl_c)?;
        b.bench_once("event_loop_15x15_625_collab", || {
            let r = Simulation::new(&collab_cfg, &backend_c, Scenario::Sccr)
                .aggregate_only()
                .with_workload(&wl_c)
                .with_prepared(&prep_c)
                .run()
                .unwrap();
            black_box(r.total_tasks);
        });
    }

    Ok(b)
}

/// One tracked perf regression against the committed baseline.
#[derive(Clone, Debug)]
pub struct Regression {
    pub name: String,
    pub measured_ns: f64,
    pub baseline_ns: f64,
}

impl Regression {
    pub fn ratio(&self) -> f64 {
        self.measured_ns / self.baseline_ns
    }
}

/// Load a `ccrsat-bench-v1` document from disk.
pub fn load_bench_json(path: &str) -> Result<Json> {
    Json::parse(&std::fs::read_to_string(path)?)
}

/// Extract `name → per_iter_ns` from a `ccrsat-bench-v1` document,
/// preserving document order.
fn measurement_entries(doc: &Json) -> Result<Vec<(String, f64)>> {
    let entries = doc.at(&["measurements"])?.as_arr()?;
    let mut out = Vec::with_capacity(entries.len());
    for e in entries {
        out.push((
            e.at(&["name"])?.as_str()?.to_string(),
            e.at(&["per_iter_ns"])?.as_f64()?,
        ));
    }
    Ok(out)
}

/// Render a markdown before/after table of a measured `ccrsat-bench-v1`
/// document against the committed baseline — what the CI `bench` job
/// appends to the workflow summary. Baseline rows the reduced-budget run
/// skipped show `—`; measured benches absent from the baseline are listed
/// at the bottom (they need a baseline refresh).
pub fn comparison_markdown(measured: &Json, baseline: &Json) -> Result<String> {
    comparison_markdown_with_snapshot(measured, baseline, None)
}

/// [`comparison_markdown`] plus an optional per-case Δ column against a
/// previously committed snapshot of the same artifact (the repo-root
/// `BENCH_hotpath.json`): `ccrsat bench-report --snapshot
/// BENCH_hotpath.json` reproduces locally the before/after delta CI only
/// showed in its workflow summary. Cases missing from the snapshot show
/// `—` (they are new since the snapshot was committed).
pub fn comparison_markdown_with_snapshot(
    measured: &Json,
    baseline: &Json,
    snapshot: Option<&Json>,
) -> Result<String> {
    let base = measurement_entries(baseline)?;
    let meas = measurement_entries(measured)?;
    let meas_map: BTreeMap<&str, f64> =
        meas.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    let snap_map: Option<BTreeMap<String, f64>> = match snapshot {
        Some(doc) => Some(measurement_entries(doc)?.into_iter().collect()),
        None => None,
    };
    // The Δ column: measured vs the snapshot's value for the same case.
    let snap_cell = |name: &str, measured_ns: Option<f64>| -> String {
        let Some(snap) = &snap_map else {
            return String::new();
        };
        match (snap.get(name), measured_ns) {
            (Some(&s_ns), Some(m_ns)) => format!(
                " {} | {:+.1}% |",
                format_ns(s_ns).trim(),
                (m_ns - s_ns) / s_ns * 100.0
            ),
            (Some(&s_ns), None) => format!(" {} | — |", format_ns(s_ns).trim()),
            (None, _) => " — | — |".to_string(),
        }
    };
    let mut out = String::from("## Hot-path bench vs committed baseline\n\n");
    if snap_map.is_some() {
        out.push_str(
            "| bench | baseline | measured | measured/baseline | snapshot | Δ vs snapshot |\n",
        );
        out.push_str("|---|---:|---:|---:|---:|---:|\n");
    } else {
        out.push_str("| bench | baseline | measured | measured/baseline |\n");
        out.push_str("|---|---:|---:|---:|\n");
    }
    for (name, base_ns) in &base {
        match meas_map.get(name.as_str()) {
            Some(&m_ns) => out.push_str(&format!(
                "| {} | {} | {} | {:.2}x |{}\n",
                name,
                format_ns(*base_ns).trim(),
                format_ns(m_ns).trim(),
                m_ns / base_ns,
                snap_cell(name, Some(m_ns))
            )),
            None => out.push_str(&format!(
                "| {} | {} | — | — |{}\n",
                name,
                format_ns(*base_ns).trim(),
                snap_cell(name, None)
            )),
        }
    }
    let base_names: std::collections::BTreeSet<&str> =
        base.iter().map(|(n, _)| n.as_str()).collect();
    for (name, m_ns) in &meas {
        if !base_names.contains(name.as_str()) {
            out.push_str(&format!(
                "| {} (no baseline) | — | {} | — |{}\n",
                name,
                format_ns(*m_ns).trim(),
                snap_cell(name, Some(*m_ns))
            ));
        }
    }
    Ok(out)
}

/// Validate a committed full-suite snapshot (the repo-root
/// `BENCH_hotpath.json`) against the committed baseline: the snapshot
/// must carry the `ccrsat-bench-v1` schema marker, well-formed
/// measurement entries, and **every** case the baseline tracks (unlike a
/// reduced-budget CI run, the committed snapshot is the full `--scale`
/// artifact, so a missing case means it is stale). The CI lint job runs
/// this via `ccrsat bench-report --validate`, so a malformed or stale
/// snapshot fails fast instead of silently degrading the workflow-summary
/// diff to `—` cells.
pub fn validate_snapshot(snapshot: &Json, baseline: &Json) -> Result<()> {
    let schema = snapshot.at(&["schema"])?.as_str()?;
    if schema != crate::harness::bench::SCHEMA {
        return Err(Error::simulation(format!(
            "snapshot schema is '{schema}', expected '{}'",
            crate::harness::bench::SCHEMA
        )));
    }
    let snap_names: std::collections::BTreeSet<String> = measurement_entries(snapshot)?
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    let missing: Vec<String> = measurement_entries(baseline)?
        .into_iter()
        .map(|(n, _)| n)
        .filter(|n| !snap_names.contains(n))
        .collect();
    if !missing.is_empty() {
        return Err(Error::simulation(format!(
            "snapshot is stale: {} baseline case(s) missing ({})",
            missing.len(),
            missing.join(", ")
        )));
    }
    Ok(())
}

/// Compare measurements against a `ccrsat-bench-v1` baseline document: a
/// measurement regresses when `per_iter_ns > factor × baseline`.
///
/// Measured names absent from the baseline are ignored (new benches need
/// a baseline refresh, not a CI failure); baseline names that were not
/// measured are fine too (reduced-budget CI runs skip `--scale` entries).
pub fn check_against_baseline(
    measurements: &[Measurement],
    baseline: &Json,
    factor: f64,
) -> Result<Vec<Regression>> {
    let entries = baseline.at(&["measurements"])?.as_arr()?;
    let mut base = BTreeMap::new();
    for e in entries {
        base.insert(
            e.at(&["name"])?.as_str()?.to_string(),
            e.at(&["per_iter_ns"])?.as_f64()?,
        );
    }
    let mut regressions = Vec::new();
    for m in measurements {
        if let Some(&baseline_ns) = base.get(&m.name) {
            if m.per_iter_ns > factor * baseline_ns {
                regressions.push(Regression {
                    name: m.name.clone(),
                    measured_ns: m.per_iter_ns,
                    baseline_ns,
                });
            }
        }
    }
    Ok(regressions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduced_suite_measures_the_hot_path() {
        let opts = HotpathOpts {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(5),
            scale: false,
        };
        let b = run_suite(&opts).unwrap();
        let names: Vec<&str> = b.results().iter().map(|m| m.name.as_str()).collect();
        for expect in [
            "scrt_nearest_32",
            "scrt_nearest_quant_32",
            "scrt_contains_32",
            "scrt_top_tau_11_32",
            "scrt_insert_evict_32",
            "ssim_global_1024",
            "lsh_bucket_3072",
            "classify_3072",
            "classify_batch64_3072",
            "lsh_bucket_batch64_3072",
            "gemv_21x3072",
            "gemm_64x21x3072",
            "event_queue_churn_64k",
            "collab_fanout_15x15",
            "render_64x64",
            "preprocess_64x64",
            "simulate_slcr_3x3_45",
            "simulate_sccr_3x3_45",
            "event_loop_3x3_45",
            "event_loop_5x5_125",
            "event_loop_5x5_125_t4",
            "event_loop_5x5_125_lossy",
            "event_loop_5x5_125_crashy",
            "event_loop_walker_t4",
        ] {
            assert!(names.contains(&expect), "missing bench '{expect}'");
        }
        for m in b.results() {
            assert!(m.per_iter_ns > 0.0, "{} measured nothing", m.name);
        }
    }

    #[test]
    fn baseline_check_flags_only_regressions() {
        let baseline = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "fast", "per_iter_ns": 100.0},
                {"name": "slow", "per_iter_ns": 100.0},
                {"name": "unmeasured", "per_iter_ns": 1.0}
            ]}"#,
        )
        .unwrap();
        let mk = |name: &str, ns: f64| Measurement {
            name: name.to_string(),
            iterations: 1,
            total: Duration::from_nanos(ns as u64),
            per_iter_ns: ns,
            throughput_per_s: 1e9 / ns,
        };
        let ms = vec![
            mk("fast", 150.0),    // within 2x: fine
            mk("slow", 250.0),    // over 2x: regression
            mk("untracked", 1e9), // not in baseline: ignored
        ];
        let regs = check_against_baseline(&ms, &baseline, 2.0).unwrap();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "slow");
        assert!((regs[0].ratio() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn baseline_check_rejects_malformed_documents() {
        let bad = Json::parse(r#"{"schema": "x"}"#).unwrap();
        assert!(check_against_baseline(&[], &bad, 2.0).is_err());
    }

    #[test]
    fn snapshot_validation_catches_stale_and_malformed_artifacts() {
        let baseline = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "a", "per_iter_ns": 100.0},
                {"name": "b", "per_iter_ns": 200.0}
            ]}"#,
        )
        .unwrap();
        let complete = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "b", "per_iter_ns": 190.0},
                {"name": "a", "per_iter_ns": 90.0},
                {"name": "extra", "per_iter_ns": 1.0}
            ]}"#,
        )
        .unwrap();
        validate_snapshot(&complete, &baseline).unwrap();

        let wrong_schema = Json::parse(
            r#"{"schema": "not-a-bench", "measurements": []}"#,
        )
        .unwrap();
        let err = validate_snapshot(&wrong_schema, &baseline).unwrap_err();
        assert!(err.to_string().contains("schema"), "{err}");

        let stale = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "a", "per_iter_ns": 90.0}
            ]}"#,
        )
        .unwrap();
        let err = validate_snapshot(&stale, &baseline).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
        assert!(err.to_string().contains('b'), "{err}");
    }

    #[test]
    fn comparison_markdown_covers_all_rows() {
        let baseline = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "tracked", "per_iter_ns": 1000.0},
                {"name": "skipped", "per_iter_ns": 2000.0}
            ]}"#,
        )
        .unwrap();
        let measured = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "tracked", "per_iter_ns": 500.0},
                {"name": "brand_new", "per_iter_ns": 42.0}
            ]}"#,
        )
        .unwrap();
        let md = comparison_markdown(&measured, &baseline).unwrap();
        assert!(md.contains("| tracked |"), "{md}");
        assert!(md.contains("0.50x"), "ratio missing:\n{md}");
        assert!(md.contains("| skipped |") && md.contains("| — | — |"), "{md}");
        assert!(md.contains("brand_new (no baseline)"), "{md}");
        assert!(comparison_markdown(&measured, &Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn snapshot_column_reports_per_case_delta() {
        let baseline = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "tracked", "per_iter_ns": 1000.0},
                {"name": "skipped", "per_iter_ns": 2000.0}
            ]}"#,
        )
        .unwrap();
        let measured = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "tracked", "per_iter_ns": 500.0},
                {"name": "brand_new", "per_iter_ns": 42.0}
            ]}"#,
        )
        .unwrap();
        let snapshot = Json::parse(
            r#"{"schema": "ccrsat-bench-v1", "measurements": [
                {"name": "tracked", "per_iter_ns": 800.0},
                {"name": "skipped", "per_iter_ns": 1900.0}
            ]}"#,
        )
        .unwrap();
        let md = comparison_markdown_with_snapshot(&measured, &baseline, Some(&snapshot))
            .unwrap();
        assert!(md.contains("Δ vs snapshot"), "{md}");
        // tracked: 500 measured vs 800 snapshot → -37.5%
        assert!(md.contains("-37.5%"), "delta missing:\n{md}");
        // skipped: in the snapshot but unmeasured → snapshot value, dash delta
        assert!(md.contains("1.90 µs/iter | — |"), "{md}");
        // brand_new: not in the snapshot → both cells dashed
        assert!(md.contains("brand_new (no baseline)"), "{md}");
        // Without a snapshot the classic 4-column table is unchanged.
        let classic = comparison_markdown(&measured, &baseline).unwrap();
        assert!(!classic.contains("snapshot"), "{classic}");
    }
}
