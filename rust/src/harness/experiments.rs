//! Experiment runners for every table and figure in the paper (Sec. V).
//!
//! | id       | paper artifact                  | runner              |
//! |----------|---------------------------------|---------------------|
//! | table2   | Table II  — reuse accuracy      | [`run_scale_suite`] |
//! | table3   | Table III — data transfer (MB)  | [`run_scale_suite`] |
//! | fig3     | Fig. 3a/b/c — time/rr/CPU       | [`run_scale_suite`] |
//! | fig4     | Fig. 4 — τ sweep                | [`tau_sweep`]       |
//! | fig5     | Fig. 5 — th_co sweep            | [`thco_sweep`]      |
//!
//! All runners share one workload per network scale so every scenario sees
//! the identical task stream (as the paper's comparative setup requires).

use crate::compute::{ComputeBackend, NativeBackend, PjrtBackend};
use crate::config::SimConfig;
use crate::coordinator::Scenario;
use crate::error::Result;
use crate::metrics::{
    reports_to_csv, scale_scenario_table, sweep_table, RunReport,
};
use crate::simulator::{prepare, Prepared, Simulation};
use crate::workload::{build_workload, Workload};

/// Paper network scales.
pub const PAPER_SCALES: [usize; 3] = [5, 7, 9];
/// Fig. 4 sweep values.
pub const TAU_SWEEP: [usize; 8] = [1, 3, 5, 7, 9, 11, 13, 15];
/// Fig. 5 sweep values.
pub const THCO_SWEEP: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Default backend policy shared by benches/examples: the PJRT artifacts
/// when present (the real three-layer path), else the native reference.
pub fn default_backend(cfg: &SimConfig) -> Result<Box<dyn ComputeBackend>> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Ok(Box::new(PjrtBackend::from_dir("artifacts")?))
    } else {
        eprintln!("note: artifacts/ missing — falling back to the native backend");
        Ok(Box::new(NativeBackend::new(cfg)))
    }
}

/// A workload + prepared inputs, cached per scale.
pub struct PreparedScale {
    pub cfg: SimConfig,
    pub workload: Workload,
    pub prepared: Prepared,
}

/// Build (workload, oracle) once for a scale.
pub fn prepare_scale(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
) -> Result<PreparedScale> {
    let mut cfg = base.clone();
    cfg.network.n = n;
    cfg.validate()?;
    let workload = build_workload(&cfg);
    let prepared = prepare(backend, &workload)?;
    Ok(PreparedScale {
        cfg,
        workload,
        prepared,
    })
}

/// Run one scenario on a prepared scale.
pub fn run_scenario(
    ps: &PreparedScale,
    backend: &dyn ComputeBackend,
    scenario: Scenario,
) -> Result<RunReport> {
    Simulation::new(&ps.cfg, backend, scenario)
        .with_workload(&ps.workload)
        .with_prepared(&ps.prepared)
        .run()
}

/// Run one scenario with config tweaks (sweeps) on a prepared scale.
pub fn run_scenario_with(
    ps: &PreparedScale,
    backend: &dyn ComputeBackend,
    scenario: Scenario,
    tweak: impl Fn(&mut SimConfig),
) -> Result<RunReport> {
    let mut cfg = ps.cfg.clone();
    tweak(&mut cfg);
    cfg.validate()?;
    Simulation::new(&cfg, backend, scenario)
        .with_workload(&ps.workload)
        .with_prepared(&ps.prepared)
        .run()
}

/// Tables II & III + Fig. 3: all scenarios × the requested scales.
pub fn run_scale_suite(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    scales: &[usize],
    scenarios: &[Scenario],
) -> Result<Vec<RunReport>> {
    let mut out = Vec::with_capacity(scales.len() * scenarios.len());
    for &n in scales {
        let ps = prepare_scale(base, backend, n)?;
        for &sc in scenarios {
            out.push(run_scenario(&ps, backend, sc)?);
        }
    }
    Ok(out)
}

/// Fig. 4: τ sweep for SCCR-INIT and SCCR on one scale (default 5×5).
/// Returns `(τ, [t_sccr_init, t_sccr])` rows.
pub fn tau_sweep(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
    taus: &[usize],
) -> Result<Vec<(f64, Vec<f64>)>> {
    let ps = prepare_scale(base, backend, n)?;
    let mut rows = Vec::with_capacity(taus.len());
    for &tau in taus {
        let init = run_scenario_with(&ps, backend, Scenario::SccrInit, |c| {
            c.reuse.tau = tau
        })?;
        let full =
            run_scenario_with(&ps, backend, Scenario::Sccr, |c| c.reuse.tau = tau)?;
        rows.push((
            tau as f64,
            vec![init.completion_time, full.completion_time],
        ));
    }
    Ok(rows)
}

/// Fig. 5: th_co sweep for SCCR-INIT and SCCR plus the SLCR reference line.
/// Returns `(th_co, [t_sccr_init, t_sccr, t_slcr])` rows.
pub fn thco_sweep(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
    thcos: &[f64],
) -> Result<Vec<(f64, Vec<f64>)>> {
    let ps = prepare_scale(base, backend, n)?;
    let slcr = run_scenario(&ps, backend, Scenario::Slcr)?;
    let mut rows = Vec::with_capacity(thcos.len());
    for &th in thcos {
        let init = run_scenario_with(&ps, backend, Scenario::SccrInit, |c| {
            c.reuse.th_co = th
        })?;
        let full =
            run_scenario_with(&ps, backend, Scenario::Sccr, |c| c.reuse.th_co = th)?;
        rows.push((
            th,
            vec![
                init.completion_time,
                full.completion_time,
                slcr.completion_time,
            ],
        ));
    }
    Ok(rows)
}

/// Render the Table II markdown from suite reports.
pub fn table2_markdown(reports: &[RunReport]) -> String {
    scale_scenario_table("Table II: reuse accuracy", reports, |r| {
        format!("{:.4}", r.reuse_accuracy)
    })
}

/// Render the Table III markdown from suite reports.
pub fn table3_markdown(reports: &[RunReport]) -> String {
    scale_scenario_table("Table III: data transfer volume (MB)", reports, |r| {
        format!("{:.2}", r.data_transfer_mb)
    })
}

/// Render the three Fig. 3 panels from suite reports.
pub fn fig3_markdown(reports: &[RunReport]) -> String {
    let mut out = scale_scenario_table("Fig. 3a: task completion time (s)", reports, |r| {
        format!("{:.2}", r.completion_time)
    });
    out.push('\n');
    out.push_str(&scale_scenario_table("Fig. 3b: reuse rate", reports, |r| {
        format!("{:.3}", r.reuse_rate)
    }));
    out.push('\n');
    out.push_str(&scale_scenario_table(
        "Fig. 3c: CPU occupancy",
        reports,
        |r| format!("{:.3}", r.cpu_occupancy),
    ));
    out
}

/// Render Fig. 4 markdown.
pub fn fig4_markdown(rows: &[(f64, Vec<f64>)]) -> String {
    sweep_table(
        "Fig. 4: impact of τ on task completion time (s), 5×5",
        "τ",
        &["SCCR-INIT", "SCCR"],
        rows,
    )
}

/// Render Fig. 5 markdown.
pub fn fig5_markdown(rows: &[(f64, Vec<f64>)]) -> String {
    sweep_table(
        "Fig. 5: impact of th_co on task completion time (s), 5×5",
        "th_co",
        &["SCCR-INIT", "SCCR", "SLCR"],
        rows,
    )
}

/// CSV for the suite (plotting pipelines).
pub fn suite_csv(reports: &[RunReport]) -> String {
    reports_to_csv(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeBackend;

    fn small_base() -> SimConfig {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 36;
        cfg
    }

    #[test]
    fn suite_runs_all_scenarios() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let reports =
            run_scale_suite(&base, &backend, &[3], &Scenario::ALL).unwrap();
        assert_eq!(reports.len(), 5);
        let t2 = table2_markdown(&reports);
        assert!(t2.contains("| 3x3 |"));
        let t3 = table3_markdown(&reports);
        assert!(t3.contains("0.00"), "w/o CR transfers nothing:\n{t3}");
        let f3 = fig3_markdown(&reports);
        assert!(f3.contains("Fig. 3a") && f3.contains("Fig. 3c"));
    }

    #[test]
    fn tau_sweep_rows_match_input() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let rows = tau_sweep(&base, &backend, 3, &[1, 5]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 1.0);
        assert_eq!(rows[1].1.len(), 2);
        let md = fig4_markdown(&rows);
        assert!(md.contains("SCCR-INIT"));
    }

    #[test]
    fn thco_sweep_includes_slcr_reference() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let rows = thco_sweep(&base, &backend, 3, &[0.3, 0.7]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].1.len(), 3);
        // SLCR reference identical across rows (it ignores th_co)
        assert_eq!(rows[0].1[2], rows[1].1[2]);
    }
}
