//! Experiment runners for every table and figure in the paper (Sec. V).
//!
//! | id       | paper artifact                  | runner              |
//! |----------|---------------------------------|---------------------|
//! | table2   | Table II  — reuse accuracy      | [`run_scale_suite`] |
//! | table3   | Table III — data transfer (MB)  | [`run_scale_suite`] |
//! | fig3     | Fig. 3a/b/c — time/rr/CPU       | [`run_scale_suite`] |
//! | fig4     | Fig. 4 — τ sweep                | [`tau_sweep`]       |
//! | fig5     | Fig. 5 — th_co sweep            | [`thco_sweep`]      |
//!
//! All runners share one workload per network scale so every scenario sees
//! the identical task stream (as the paper's comparative setup requires).
//!
//! ## Parallel execution model
//!
//! Preparing a scale (rendering images, preprocessing, oracle labels) is
//! done **once**; the scenario runs that consume it are then fanned out
//! across OS threads ([`run_jobs_parallel`], one thread per scenario) via
//! `std::thread::scope`. This is safe and deterministic because:
//!
//! * [`PreparedScale`] is immutable after construction and only shared by
//!   reference (`Sync` holds structurally — plain data, no cells);
//! * [`ComputeBackend`] requires `Send + Sync`, so one backend serves all
//!   threads (the native backend is read-only; the PJRT engine's compile
//!   cache is a mutex);
//! * each [`Simulation::run`] keeps all mutable state — event queue,
//!   per-satellite nodes, and the `Arc`-shared broadcast records —
//!   strictly thread-local;
//! * every scenario run is a pure function of `(config, workload,
//!   prepared)`, so parallel results are bit-identical to sequential ones
//!   (asserted by the `parallel_matches_sequential` tests).
//!
//! [`run_scale_suite_timed`] additionally reports the wall-clock speedup
//! the fan-out achieved over the implied sequential run.

use crate::compute::{ComputeBackend, NativeBackend, PjrtBackend};
use crate::config::SimConfig;
use crate::coordinator::Scenario;
use crate::error::Result;
use crate::metrics::{
    reports_to_csv, scale_scenario_table, sweep_table, RunReport,
};
use crate::simulator::{
    prepare, Prepared, PreparedSource, Simulation, StreamConfig, StreamingSource,
};
use crate::workload::{build_workload, Workload};

/// Paper network scales.
pub const PAPER_SCALES: [usize; 3] = [5, 7, 9];
/// Extended grids beyond the paper's 9×9, toward the ROADMAP's
/// production-scale target. Consumed by `ccrsat bench --scale` and
/// available to `run_scale_suite_timed` like any other scale list.
pub const EXTENDED_SCALES: [usize; 2] = [11, 15];
/// Fig. 4 sweep values.
pub const TAU_SWEEP: [usize; 8] = [1, 3, 5, 7, 9, 11, 13, 15];
/// Fig. 5 sweep values.
pub const THCO_SWEEP: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Default backend policy shared by the CLI, benches and examples: the
/// PJRT artifacts when usable (the real three-layer path), else the
/// native reference. An unusable artifact dir — including builds without
/// the `pjrt` feature — falls back rather than failing.
pub fn default_backend_at(
    dir: &str,
    cfg: &SimConfig,
) -> Result<Box<dyn ComputeBackend>> {
    if std::path::Path::new(dir).join("manifest.json").exists() {
        match PjrtBackend::from_dir(dir) {
            Ok(b) => return Ok(Box::new(b)),
            Err(e) => eprintln!(
                "note: cannot use artifacts at '{dir}' ({e}); falling back to the native backend"
            ),
        }
    } else {
        eprintln!("note: no artifacts at '{dir}' — falling back to the native backend");
    }
    Ok(Box::new(NativeBackend::new(cfg)))
}

/// [`default_backend_at`] with the conventional `artifacts/` directory.
pub fn default_backend(cfg: &SimConfig) -> Result<Box<dyn ComputeBackend>> {
    default_backend_at("artifacts", cfg)
}

/// A workload + prepared inputs, cached per scale.
pub struct PreparedScale {
    pub cfg: SimConfig,
    pub workload: Workload,
    pub prepared: Prepared,
}

/// Build (workload, oracle) once for a scale.
pub fn prepare_scale(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
) -> Result<PreparedScale> {
    let mut cfg = base.clone();
    cfg.network.n = n;
    cfg.validate()?;
    let workload = build_workload(&cfg);
    let prepared = prepare(backend, &workload)?;
    Ok(PreparedScale {
        cfg,
        workload,
        prepared,
    })
}

/// Run one scenario on a prepared scale.
pub fn run_scenario(
    ps: &PreparedScale,
    backend: &dyn ComputeBackend,
    scenario: Scenario,
) -> Result<RunReport> {
    Simulation::new(&ps.cfg, backend, scenario)
        .with_workload(&ps.workload)
        .with_prepared(&ps.prepared)
        .run()
}

/// Run one scenario at scale `n` with *streaming* preparation: task
/// inputs are prepared in on-demand chunks whose residency is bounded by
/// `stream`'s window instead of the task count — the entry point for
/// grids/workloads too large to hold a full [`Prepared`] table. Returns
/// the report plus the source's peak resident prepared-task count. The
/// run is aggregate-only (no per-task logs held) and every aggregate
/// metric is bit-identical to the materialized [`run_scenario`] path
/// (asserted by tests and `tests/properties.rs`).
pub fn run_scenario_streaming(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
    scenario: Scenario,
    stream: StreamConfig,
) -> Result<(RunReport, usize)> {
    let mut cfg = base.clone();
    cfg.network.n = n;
    cfg.validate()?;
    let workload = build_workload(&cfg);
    let mut source = StreamingSource::new(backend, &workload, stream)?;
    let report = Simulation::new(&cfg, backend, scenario)
        .with_workload(&workload)
        .aggregate_only()
        .run_with_source(&mut source)?;
    Ok((report, source.peak_resident()))
}

/// Run `(scenario, config)` jobs concurrently against one prepared
/// workload, one OS thread per job. Results come back in job order, so the
/// output is deterministic regardless of thread scheduling; a failed job
/// surfaces its error after all threads have joined.
pub fn run_jobs_parallel(
    ps: &PreparedScale,
    backend: &dyn ComputeBackend,
    jobs: &[(Scenario, SimConfig)],
) -> Result<Vec<RunReport>> {
    let mut results: Vec<Option<Result<RunReport>>> =
        (0..jobs.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (slot, job) in results.iter_mut().zip(jobs) {
            scope.spawn(move || {
                let (scenario, cfg) = (job.0, &job.1);
                *slot = Some(
                    Simulation::new(cfg, backend, scenario)
                        .with_workload(&ps.workload)
                        .with_prepared(&ps.prepared)
                        .run(),
                );
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("scenario worker completed"))
        .collect()
}

/// Run several scenarios of one prepared scale concurrently (the shared
/// `Prepared` workload guarantees every scenario sees the identical task
/// stream, exactly as in the sequential path).
pub fn run_scenarios_parallel(
    ps: &PreparedScale,
    backend: &dyn ComputeBackend,
    scenarios: &[Scenario],
) -> Result<Vec<RunReport>> {
    let jobs: Vec<(Scenario, SimConfig)> =
        scenarios.iter().map(|&s| (s, ps.cfg.clone())).collect();
    run_jobs_parallel(ps, backend, &jobs)
}

/// Wall-clock accounting of a parallel suite run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SuiteTiming {
    /// Sum of the per-scenario wall-clock seconds, as measured inside the
    /// concurrent runs. On an oversubscribed host this includes time the
    /// threads spent descheduled, so it is an *upper bound* on what a
    /// true sequential run would have cost (excluding preparation).
    pub sequential_s: f64,
    /// Observed wall-clock seconds of the parallel fan-out.
    pub parallel_s: f64,
}

impl SuiteTiming {
    /// Speedup of the fan-out over the implied sequential run (an upper
    /// bound when scenario threads contend for cores — see
    /// [`SuiteTiming::sequential_s`]).
    pub fn speedup(&self) -> f64 {
        if self.parallel_s > 0.0 {
            self.sequential_s / self.parallel_s
        } else {
            1.0
        }
    }

    /// One-line human summary for run reports.
    pub fn summary(&self) -> String {
        format!(
            "parallel harness: {:.2}s wall for {:.2}s of in-thread scenario work (speedup ≤ {:.2}x)",
            self.parallel_s,
            self.sequential_s,
            self.speedup()
        )
    }
}

/// Tables II & III + Fig. 3: all scenarios × the requested scales, with
/// scenario runs fanned out across threads per scale. Also returns the
/// wall-clock speedup achieved over the implied sequential run.
pub fn run_scale_suite_timed(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    scales: &[usize],
    scenarios: &[Scenario],
) -> Result<(Vec<RunReport>, SuiteTiming)> {
    let mut out = Vec::with_capacity(scales.len() * scenarios.len());
    let mut parallel_s = 0.0;
    for &n in scales {
        let ps = prepare_scale(base, backend, n)?;
        let t0 = std::time::Instant::now();
        out.extend(run_scenarios_parallel(&ps, backend, scenarios)?);
        parallel_s += t0.elapsed().as_secs_f64();
    }
    let sequential_s = out.iter().map(|r| r.wallclock_s).sum();
    Ok((
        out,
        SuiteTiming {
            sequential_s,
            parallel_s,
        },
    ))
}

/// Tables II & III + Fig. 3: all scenarios × the requested scales.
pub fn run_scale_suite(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    scales: &[usize],
    scenarios: &[Scenario],
) -> Result<Vec<RunReport>> {
    Ok(run_scale_suite_timed(base, backend, scales, scenarios)?.0)
}

/// Sequential reference path of [`run_scale_suite`] — kept for determinism
/// cross-checks and single-core environments.
pub fn run_scale_suite_sequential(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    scales: &[usize],
    scenarios: &[Scenario],
) -> Result<Vec<RunReport>> {
    let mut out = Vec::with_capacity(scales.len() * scenarios.len());
    for &n in scales {
        let ps = prepare_scale(base, backend, n)?;
        for &sc in scenarios {
            out.push(run_scenario(&ps, backend, sc)?);
        }
    }
    Ok(out)
}

/// Fig. 4: τ sweep for SCCR-INIT and SCCR on one scale (default 5×5).
/// Returns `(τ, [t_sccr_init, t_sccr])` rows.
pub fn tau_sweep(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
    taus: &[usize],
) -> Result<Vec<(f64, Vec<f64>)>> {
    let ps = prepare_scale(base, backend, n)?;
    let mut rows = Vec::with_capacity(taus.len());
    for &tau in taus {
        let mut cfg = ps.cfg.clone();
        cfg.reuse.tau = tau;
        cfg.validate()?;
        // Both series of one sweep point run concurrently.
        let jobs = [
            (Scenario::SccrInit, cfg.clone()),
            (Scenario::Sccr, cfg),
        ];
        let reports = run_jobs_parallel(&ps, backend, &jobs)?;
        rows.push((
            tau as f64,
            reports.iter().map(|r| r.completion_time).collect(),
        ));
    }
    Ok(rows)
}

/// Fig. 5: th_co sweep for SCCR-INIT and SCCR plus the SLCR reference line.
/// Returns `(th_co, [t_sccr_init, t_sccr, t_slcr])` rows.
pub fn thco_sweep(
    base: &SimConfig,
    backend: &dyn ComputeBackend,
    n: usize,
    thcos: &[f64],
) -> Result<Vec<(f64, Vec<f64>)>> {
    let ps = prepare_scale(base, backend, n)?;
    let slcr = run_scenario(&ps, backend, Scenario::Slcr)?;
    let mut rows = Vec::with_capacity(thcos.len());
    for &th in thcos {
        let mut cfg = ps.cfg.clone();
        cfg.reuse.th_co = th;
        cfg.validate()?;
        // Both series of one sweep point run concurrently.
        let jobs = [
            (Scenario::SccrInit, cfg.clone()),
            (Scenario::Sccr, cfg),
        ];
        let reports = run_jobs_parallel(&ps, backend, &jobs)?;
        rows.push((
            th,
            vec![
                reports[0].completion_time,
                reports[1].completion_time,
                slcr.completion_time,
            ],
        ));
    }
    Ok(rows)
}

/// Render the Table II markdown from suite reports.
pub fn table2_markdown(reports: &[RunReport]) -> String {
    scale_scenario_table("Table II: reuse accuracy", reports, |r| {
        format!("{:.4}", r.reuse_accuracy)
    })
}

/// Render the Table III markdown from suite reports.
pub fn table3_markdown(reports: &[RunReport]) -> String {
    scale_scenario_table("Table III: data transfer volume (MB)", reports, |r| {
        format!("{:.2}", r.data_transfer_mb)
    })
}

/// Render the three Fig. 3 panels from suite reports.
pub fn fig3_markdown(reports: &[RunReport]) -> String {
    let mut out = scale_scenario_table("Fig. 3a: task completion time (s)", reports, |r| {
        format!("{:.2}", r.completion_time)
    });
    out.push('\n');
    out.push_str(&scale_scenario_table("Fig. 3b: reuse rate", reports, |r| {
        format!("{:.3}", r.reuse_rate)
    }));
    out.push('\n');
    out.push_str(&scale_scenario_table(
        "Fig. 3c: CPU occupancy",
        reports,
        |r| format!("{:.3}", r.cpu_occupancy),
    ));
    out
}

/// Render Fig. 4 markdown.
pub fn fig4_markdown(rows: &[(f64, Vec<f64>)]) -> String {
    sweep_table(
        "Fig. 4: impact of τ on task completion time (s), 5×5",
        "τ",
        &["SCCR-INIT", "SCCR"],
        rows,
    )
}

/// Render Fig. 5 markdown.
pub fn fig5_markdown(rows: &[(f64, Vec<f64>)]) -> String {
    sweep_table(
        "Fig. 5: impact of th_co on task completion time (s), 5×5",
        "th_co",
        &["SCCR-INIT", "SCCR", "SLCR"],
        rows,
    )
}

/// CSV for the suite (plotting pipelines).
pub fn suite_csv(reports: &[RunReport]) -> String {
    reports_to_csv(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeBackend;

    fn small_base() -> SimConfig {
        let mut cfg = SimConfig::paper_default(3);
        cfg.workload.total_tasks = 36;
        cfg
    }

    #[test]
    fn suite_runs_all_scenarios() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let reports =
            run_scale_suite(&base, &backend, &[3], &Scenario::ALL).unwrap();
        assert_eq!(reports.len(), 5);
        let t2 = table2_markdown(&reports);
        assert!(t2.contains("| 3x3 |"));
        let t3 = table3_markdown(&reports);
        assert!(t3.contains("0.00"), "w/o CR transfers nothing:\n{t3}");
        let f3 = fig3_markdown(&reports);
        assert!(f3.contains("Fig. 3a") && f3.contains("Fig. 3c"));
    }

    #[test]
    fn tau_sweep_rows_match_input() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let rows = tau_sweep(&base, &backend, 3, &[1, 5]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 1.0);
        assert_eq!(rows[1].1.len(), 2);
        let md = fig4_markdown(&rows);
        assert!(md.contains("SCCR-INIT"));
    }

    #[test]
    fn thco_sweep_includes_slcr_reference() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let rows = thco_sweep(&base, &backend, 3, &[0.3, 0.7]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].1.len(), 3);
        // SLCR reference identical across rows (it ignores th_co)
        assert_eq!(rows[0].1[2], rows[1].1[2]);
    }

    /// All deterministic RunReport fields (everything but wallclock_s).
    fn assert_reports_identical(a: &RunReport, b: &RunReport) {
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.n, b.n);
        assert_eq!(a.completion_time, b.completion_time);
        assert_eq!(a.compute_seconds, b.compute_seconds);
        assert_eq!(a.comm_seconds, b.comm_seconds);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.reuse_rate, b.reuse_rate);
        assert_eq!(a.cpu_occupancy, b.cpu_occupancy);
        assert_eq!(a.reuse_accuracy, b.reuse_accuracy);
        assert_eq!(a.data_transfer_mb, b.data_transfer_mb);
        assert_eq!(a.total_tasks, b.total_tasks);
        assert_eq!(a.reused_tasks, b.reused_tasks);
        assert_eq!(a.collab_events, b.collab_events);
        assert_eq!(a.expanded_events, b.expanded_events);
        assert_eq!(a.aborted_collabs, b.aborted_collabs);
        assert_eq!(a.broadcast_records, b.broadcast_records);
        assert_eq!(a.mean_latency, b.mean_latency);
        assert_eq!(a.p95_latency, b.p95_latency);
    }

    #[test]
    fn parallel_matches_sequential() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let par = run_scale_suite(&base, &backend, &[3], &Scenario::ALL).unwrap();
        let seq =
            run_scale_suite_sequential(&base, &backend, &[3], &Scenario::ALL)
                .unwrap();
        assert_eq!(par.len(), seq.len());
        for (a, b) in par.iter().zip(&seq) {
            assert_reports_identical(a, b);
        }
    }

    #[test]
    fn parallel_preserves_scenario_order() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let ps = prepare_scale(&base, &backend, 3).unwrap();
        let reports =
            run_scenarios_parallel(&ps, &backend, &Scenario::ALL).unwrap();
        assert_eq!(reports.len(), Scenario::ALL.len());
        for (r, &s) in reports.iter().zip(Scenario::ALL.iter()) {
            assert_eq!(r.scenario, s);
        }
    }

    #[test]
    fn suite_timing_accounts_for_all_scenarios() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let (reports, timing) =
            run_scale_suite_timed(&base, &backend, &[3], &Scenario::ALL).unwrap();
        assert_eq!(reports.len(), 5);
        let sum: f64 = reports.iter().map(|r| r.wallclock_s).sum();
        assert_eq!(timing.sequential_s, sum);
        assert!(timing.parallel_s > 0.0);
        assert!(timing.speedup() > 0.0);
        assert!(timing.summary().contains("speedup"));
    }

    #[test]
    fn streaming_suite_matches_materialized_with_bounded_residency() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let ps = prepare_scale(&base, &backend, 3).unwrap();
        let materialized = run_scenario(&ps, &backend, Scenario::Sccr).unwrap();
        let stream = StreamConfig {
            chunk_tasks: 6,
            window_chunks: 2,
        };
        let (streamed, peak) = run_scenario_streaming(
            &base,
            &backend,
            3,
            Scenario::Sccr,
            stream,
        )
        .unwrap();
        assert_eq!(streamed.completion_time, materialized.completion_time);
        assert_eq!(streamed.reuse_rate, materialized.reuse_rate);
        assert_eq!(streamed.reuse_accuracy, materialized.reuse_accuracy);
        assert_eq!(streamed.data_transfer_mb, materialized.data_transfer_mb);
        assert_eq!(streamed.collab_events, materialized.collab_events);
        assert!(peak <= stream.window_tasks(), "residency {peak} over budget");
        assert!(peak < ps.workload.tasks.len());
        assert!(streamed.tasks.is_empty(), "streaming helper is aggregate-only");
    }

    #[test]
    fn run_jobs_parallel_propagates_config_errors() {
        let base = small_base();
        let backend = NativeBackend::new(&base);
        let ps = prepare_scale(&base, &backend, 3).unwrap();
        let mut bad = ps.cfg.clone();
        bad.reuse.tau = 0; // invalid: rejected at the run boundary
        let jobs = [
            (Scenario::Slcr, ps.cfg.clone()),
            (Scenario::Sccr, bad),
        ];
        assert!(run_jobs_parallel(&ps, &backend, &jobs).is_err());
    }
}
