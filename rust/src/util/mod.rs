//! Support substrates built in-repo (the image has no network access for
//! crates.io, so RNG / JSON / statistics helpers are implemented here).

pub mod json;
pub mod rng;
pub mod stats;
