//! Minimal JSON parser + writer.
//!
//! Consumes `artifacts/manifest.json` and emits experiment reports. Built
//! in-repo because serde_json is unavailable offline; covers the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access that errors with the path on miss.
    pub fn at(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| {
                Error::Json(format!("missing key '{}'", path[..=i].join(".")))
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(Error::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Json(format!("expected unsigned integer, got {x}")));
        }
        Ok(x as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Json(format!("expected object, got {other:?}"))),
        }
    }

    // ----------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----------------------------------------------------------------- parse
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing garbage at byte {} of {}",
                p.pos,
                p.bytes.len()
            )));
        }
        Ok(v)
    }

    // ----------------------------------------------------------------- write
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
        let pad_close = if pretty { "  ".repeat(indent) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                out.push_str(nl);
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad);
                    v.write(out, indent + 1, pretty);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&pad_close);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                out.push_str(nl);
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&pad_close);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(self.err(&format!("bad escape '\\{}'", c as char))),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Re-assemble multibyte UTF-8.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "format": "hlo-text",
            "entries": {"ssim": {"file": "s.txt", "inputs": [{"shape": [32, 32], "dtype": "float32"}]}},
            "constants": {"p_k": 2, "flops": 11460608, "ratio": 0.5}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["constants", "p_k"]).unwrap().as_u64().unwrap(), 2);
        assert_eq!(
            v.at(&["entries", "ssim", "file"]).unwrap().as_str().unwrap(),
            "s.txt"
        );
        let shape = v.at(&["entries", "ssim", "inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
        // reparse what we print
        let printed = v.to_string_pretty();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse(r#""é\t\"x\"""#).unwrap();
        assert_eq!(v, Json::Str("é\t\"x\"".into()));
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v, Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn compact_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1.0), Json::Bool(false)])),
            ("b", Json::str("x")),
        ]);
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
        assert!(!s.contains('\n'));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn at_reports_path() {
        let v = Json::parse(r#"{"a": {"b": 1}}"#).unwrap();
        let err = v.at(&["a", "c"]).unwrap_err();
        assert!(err.to_string().contains("a.c"), "{err}");
    }
}
