//! Deterministic pseudo-random number generation.
//!
//! Xoshiro256++ seeded through SplitMix64 — the standard pairing; every
//! simulator component derives an independent, reproducible stream from a
//! single experiment seed so runs are bit-identical across machines.

/// Order-independent uniform draw in `[0, 1)` keyed by a counter tuple.
///
/// The lossy link layer needs a fate draw per `(transfer, destination,
/// chunk, attempt)` that every engine — single-threaded, sharded at any
/// K, resumed mid-run — computes identically *without sharing a mutable
/// generator*. A stateful `Rng` would make the draw depend on global
/// event order; hashing the coordinates instead makes it a pure function
/// of the experiment seed and the draw's identity. The mix is the same
/// SplitMix64 finalizer used for seeding, applied over the chained key
/// words, and the mapping to `[0, 1)` matches [`Rng::f64`] (53 high
/// bits), so the output quality and range semantics are shared.
#[inline]
pub fn hash_unit(seed: u64, a: u64, b: u64, c: u64, d: u64) -> f64 {
    #[inline]
    fn mix(x: u64) -> u64 {
        let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    let mut h = mix(seed);
    h = mix(h ^ a);
    h = mix(h ^ b);
    h = mix(h ^ c);
    h = mix(h ^ d);
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// SplitMix64: seeds the main generator and provides stream splitting.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Spare normal from the last Box–Muller pair (see [`Rng::normal`]).
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (e.g. per satellite, per class).
    pub fn split(&mut self, tag: u64) -> Rng {
        // Mix the tag into a fresh seed drawn from this stream.
        let base = self.next_u64();
        Rng::new(base ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free-enough for simulation use.
        (self.f64() * n as f64).min(n as f64 - 1.0) as usize
    }

    /// Standard normal via Box–Muller.
    ///
    /// Each Box–Muller transform yields an independent *pair* of normals
    /// from one `(u1, u2)` draw; the seed implementation discarded the
    /// sine half and paid the `ln`/`sqrt`/trig cost on every call. The
    /// spare is now cached in the generator state and returned by the next
    /// call, halving the transcendental work per normal. The stream stays
    /// fully deterministic (the spare is part of `Clone`d state), but its
    /// *values* differ from the seed from the second draw of each pair
    /// onward — goldens that depended on the old draw order were
    /// re-baselined (see CHANGES.md, PR 3).
    pub fn normal(&mut self) -> f64 {
        if let Some(spare) = self.spare_normal.take() {
            return spare;
        }
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                let theta = 2.0 * std::f64::consts::PI * u2;
                self.spare_normal = Some(r * theta.sin());
                return r * theta.cos();
            }
        }
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`) — M/M/1 inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "exponential rate must be positive");
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_pair_caching_is_deterministic() {
        // Two generators with the same seed must produce the same normal
        // stream, and cloning mid-pair must carry the cached spare along.
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for _ in 0..101 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
        let mut c = a.clone(); // a holds a cached spare here (odd draw count)
        assert_eq!(a.normal().to_bits(), c.normal().to_bits());
        assert_eq!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn normal_spare_does_not_disturb_other_streams() {
        // After an odd number of normal() calls the uniform stream picks
        // up exactly where the Box–Muller draws left it.
        let mut a = Rng::new(5150);
        let mut b = Rng::new(5150);
        let _ = a.normal(); // consumes (u1, u2), caches the spare
        let _ = b.f64();
        let _ = b.f64();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let lambda = 0.5;
        let mean = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(8);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..5_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > counts[0] + counts[2]);
    }

    #[test]
    fn hash_unit_is_pure_and_in_range() {
        // Same coordinates -> same value, regardless of call order.
        let x = hash_unit(2025, 3, 7, 11, 0);
        let _ = hash_unit(999, 0, 0, 0, 0);
        assert_eq!(x.to_bits(), hash_unit(2025, 3, 7, 11, 0).to_bits());
        for t in 0..50u64 {
            for a in 0..4u64 {
                let u = hash_unit(42, t, 5, 2, a);
                assert!((0.0..1.0).contains(&u), "{u}");
            }
        }
    }

    #[test]
    fn hash_unit_separates_coordinates() {
        // Changing any single coordinate must change the draw — the fate
        // of chunk 3 attempt 1 cannot alias chunk 1 attempt 3.
        let base = hash_unit(7, 1, 2, 3, 4);
        assert_ne!(base.to_bits(), hash_unit(8, 1, 2, 3, 4).to_bits());
        assert_ne!(base.to_bits(), hash_unit(7, 2, 2, 3, 4).to_bits());
        assert_ne!(base.to_bits(), hash_unit(7, 1, 3, 3, 4).to_bits());
        assert_ne!(base.to_bits(), hash_unit(7, 1, 2, 4, 4).to_bits());
        assert_ne!(base.to_bits(), hash_unit(7, 1, 2, 3, 5).to_bits());
        assert_ne!(
            hash_unit(7, 1, 2, 3, 1).to_bits(),
            hash_unit(7, 3, 2, 1, 1).to_bits()
        );
    }

    #[test]
    fn hash_unit_roughly_uniform() {
        let n = 20_000u64;
        let mean = (0..n).map(|i| hash_unit(1, i, 0, 0, 0)).sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
