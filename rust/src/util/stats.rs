//! Small descriptive-statistics helpers used by metrics and benches.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    // IEEE total order: a NaN sample (a poisoned latency upstream) sorts
    // to the extremes instead of panicking the whole report.
    sorted.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Max, or 0 for an empty slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Min, or 0 for an empty slice.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn std_dev_basic() {
        assert_eq!(std_dev(&[2.0, 2.0, 2.0]), 0.0);
        assert!((std_dev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_orders_nan_samples_without_panicking() {
        // Regression: the sort used `partial_cmp().unwrap()`, so one NaN
        // sample panicked the whole metrics report. Under the total order
        // a +NaN sorts above +inf, so finite percentiles stay sensible.
        let xs = [2.0, f64::NAN.copysign(1.0), 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn min_max() {
        assert_eq!(max(&[1.0, 5.0, 3.0]), 5.0);
        assert_eq!(min(&[1.0, 5.0, 3.0]), 1.0);
        assert_eq!(max(&[]), 0.0);
    }
}
