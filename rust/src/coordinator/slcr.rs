//! Algorithm 1 — Satellite Local Computation Reuse (SLCR).
//!
//! ```text
//! PD_t ← Preprocess(D_t)
//! match ← FindNearestNeighbor(P_t, PD_t)          (LSH bucket + L2 scan)
//! if match = ∅:
//!     R_t ← PreTrainedModel(PD_t, P_t); SCRT ← record
//! else:
//!     if SSIM(PD_t, match) > th_sim: R_t ← match.R; match.N += 1
//!     else: R_t ← PreTrainedModel(PD_t, P_t); SCRT ← record
//! ```
//!
//! The function is pure coordination: every data-dependent step (hash,
//! SSIM, model) goes through the [`ComputeBackend`], i.e. through the AOT
//! Pallas/JAX artifacts on the production path.

use crate::compute::{ComputeBackend, Preprocessed};
use crate::coordinator::scrt::{Record, Scrt};
use crate::error::Result;
use crate::workload::SatId;

/// What happened while serving one subtask.
#[derive(Clone, Debug, PartialEq)]
pub struct SlcrOutcome {
    /// LSH bucket the input hashed into.
    pub bucket: u32,
    /// SSIM against the nearest neighbour, when one existed.
    pub ssim: Option<f32>,
    /// Did the task reuse a cached result?
    pub reused: bool,
    /// Identity of the reused record (for provenance metrics).
    pub reused_from: Option<usize>,
    /// The result label `R_t` returned to the requester.
    pub result: u32,
    /// Was a fresh record inserted into the SCRT?
    pub inserted: bool,
}

/// Run Alg. 1 for one subtask on one satellite's SCRT.
///
/// `pre` is the already-pre-processed input (the simulator pre-computes it
/// once per task; the preprocessing *cost* is charged separately in W).
#[allow(clippy::too_many_arguments)]
pub fn process_task(
    scrt: &mut Scrt,
    backend: &dyn ComputeBackend,
    sat: SatId,
    task_id: usize,
    task_type: u16,
    pre: &Preprocessed,
    th_sim: f64,
    now: f64,
) -> Result<SlcrOutcome> {
    let bucket = backend.lsh_bucket(pre)?;

    if let Some((slot, _dist)) = scrt.nearest(bucket, task_type, pre) {
        // The stored candidate exposes its gray plane for the gate; its
        // feature vector stays in the SCRT's SoA storage.
        let ssim = backend.ssim(pre, scrt.candidate_pre(bucket, slot))?;
        if f64::from(ssim) > th_sim {
            // Alg. 1 lines 10–11: reuse the cached outcome.
            let hit = scrt.view(bucket, slot);
            let (result, reused_from) = (hit.result, hit.id);
            scrt.mark_reused(bucket, slot, now);
            return Ok(SlcrOutcome {
                bucket,
                ssim: Some(ssim),
                reused: true,
                reused_from: Some(reused_from),
                result,
                inserted: false,
            });
        }
        // Alg. 1 lines 13–15: similarity too low — compute and cache.
        let result = backend.classify(pre)?;
        scrt.insert(
            bucket,
            Record {
                id: task_id,
                pre: std::sync::Arc::new(pre.clone()),
                task_type,
                result,
                reuse_count: 0,
                last_used: now,
                origin: sat,
            },
        );
        return Ok(SlcrOutcome {
            bucket,
            ssim: Some(ssim),
            reused: false,
            reused_from: None,
            result,
            inserted: true,
        });
    }

    // Alg. 1 lines 4–6: no candidate at all.
    let result = backend.classify(pre)?;
    scrt.insert(
        bucket,
        Record {
            id: task_id,
            pre: std::sync::Arc::new(pre.clone()),
            task_type,
            result,
            reuse_count: 0,
            last_used: now,
            origin: sat,
        },
    );
    Ok(SlcrOutcome {
        bucket,
        ssim: None,
        reused: false,
        reused_from: None,
        result,
        inserted: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeBackend;
    use crate::config::SimConfig;
    use crate::util::rng::Rng;
    use crate::workload::texture::{SceneSpec, TextureSynth};

    fn setup() -> (NativeBackend, TextureSynth, Scrt) {
        let cfg = SimConfig::paper_default(5);
        let backend = NativeBackend::new(&cfg);
        let synth = TextureSynth::new(64, 64, 0.05);
        let scrt = Scrt::new(backend.num_buckets(), 32);
        (backend, synth, scrt)
    }

    #[test]
    fn first_task_computes_and_caches() {
        let (backend, synth, mut scrt) = setup();
        let scene = SceneSpec::sample(0, 1, &mut Rng::new(1));
        let img = synth.render(&scene, &mut Rng::new(2));
        let pre = backend.preprocess(&img).unwrap();
        let out =
            process_task(&mut scrt, &backend, 0, 0, 0, &pre, 0.7, 0.0).unwrap();
        assert!(!out.reused);
        assert!(out.inserted);
        assert!(out.ssim.is_none());
        assert_eq!(scrt.len(), 1);
    }

    #[test]
    fn second_capture_of_same_scene_reuses() {
        let (backend, synth, mut scrt) = setup();
        let scene = SceneSpec::sample(0, 2, &mut Rng::new(3));
        let img1 = synth.render(&scene, &mut Rng::new(10));
        let img2 = synth.render(&scene, &mut Rng::new(11));
        let pre1 = backend.preprocess(&img1).unwrap();
        let pre2 = backend.preprocess(&img2).unwrap();
        let out1 =
            process_task(&mut scrt, &backend, 0, 0, 0, &pre1, 0.7, 0.0).unwrap();
        let out2 =
            process_task(&mut scrt, &backend, 0, 1, 0, &pre2, 0.7, 1.0).unwrap();
        assert!(out2.reused, "ssim was {:?}", out2.ssim);
        assert_eq!(out2.result, out1.result);
        assert_eq!(out2.reused_from, Some(0));
        assert_eq!(scrt.len(), 1, "reuse must not insert");
        let (_, rec) = scrt.iter().next().unwrap();
        assert_eq!(rec.reuse_count, 1);
    }

    #[test]
    fn dissimilar_scene_not_reused() {
        let (backend, synth, mut scrt) = setup();
        // two different classes with different pattern families
        let s1 = SceneSpec::sample(0, 0, &mut Rng::new(4));
        let s2 = SceneSpec::sample(1, 8, &mut Rng::new(5));
        let pre1 = backend
            .preprocess(&synth.render(&s1, &mut Rng::new(1)))
            .unwrap();
        let pre2 = backend
            .preprocess(&synth.render(&s2, &mut Rng::new(2)))
            .unwrap();
        process_task(&mut scrt, &backend, 0, 0, 0, &pre1, 0.7, 0.0).unwrap();
        let out =
            process_task(&mut scrt, &backend, 0, 1, 0, &pre2, 0.7, 1.0).unwrap();
        // Either it hashed elsewhere (no candidate) or the SSIM gate failed;
        // both must end in fresh computation.
        assert!(!out.reused);
        assert!(out.inserted);
        assert_eq!(scrt.len(), 2);
    }

    #[test]
    fn th_sim_one_disables_reuse() {
        let (backend, synth, mut scrt) = setup();
        let scene = SceneSpec::sample(0, 2, &mut Rng::new(6));
        let pre1 = backend
            .preprocess(&synth.render(&scene, &mut Rng::new(1)))
            .unwrap();
        let pre2 = backend
            .preprocess(&synth.render(&scene, &mut Rng::new(2)))
            .unwrap();
        process_task(&mut scrt, &backend, 0, 0, 0, &pre1, 1.1, 0.0).unwrap();
        let out =
            process_task(&mut scrt, &backend, 0, 1, 0, &pre2, 1.1, 1.0).unwrap();
        assert!(!out.reused, "th_sim > 1 must never reuse");
    }

    #[test]
    fn identical_input_always_reuses_at_any_threshold_below_one() {
        let (backend, synth, mut scrt) = setup();
        let scene = SceneSpec::sample(0, 5, &mut Rng::new(7));
        let img = synth.render(&scene, &mut Rng::new(1));
        let pre = backend.preprocess(&img).unwrap();
        process_task(&mut scrt, &backend, 0, 0, 0, &pre, 0.999, 0.0).unwrap();
        let out =
            process_task(&mut scrt, &backend, 0, 1, 0, &pre, 0.999, 1.0).unwrap();
        assert!(out.reused);
        assert_eq!(out.ssim.map(|s| s > 0.999), Some(true));
    }
}
