//! The CCRSat coordination layer — the paper's contribution.
//!
//! * [`scrt`] — the Satellite Computation Reuse Table (LSH-bucketed record
//!   cache with value-aware eviction, Sec. III-A);
//! * [`srs`] — the Satellite Reuse Status metric (eq. 11);
//! * [`slcr`] — Algorithm 1, local computation reuse;
//! * [`sccr`] — Algorithm 2, collaborative source selection + area
//!   expansion;
//! * [`policy`] — the [`CollabPolicy`] trait: per-scenario Alg. 2
//!   triggering, damping and source selection behind one seam;
//! * [`scenarios`] — the five evaluation scenarios of Sec. V.

pub mod policy;
pub mod scenarios;
pub mod scrt;
pub mod slcr;
pub mod sccr;
pub mod srs;

pub use policy::CollabPolicy;
pub use scenarios::Scenario;
pub use scrt::{Record, RecordId, Scrt};
pub use sccr::{select_source, CollabDecision};
pub use slcr::{process_task, SlcrOutcome};
pub use srs::srs;
