//! The five evaluation scenarios of Sec. V-A.

use crate::coordinator::policy::{
    CollabPolicy, SCCR_INIT_POLICY, SCCR_POLICY, SRS_PRIORITY_POLICY,
};
use crate::coordinator::sccr::AreaPolicy;

/// Scenario under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// `w/o CR` — every task computed from scratch, no lookup, no cache.
    WithoutCr,
    /// `SRS Priority` — collaborate with the global-best SRS satellite and
    /// broadcast across the entire network.
    SrsPriority,
    /// `SLCR` — local computation reuse only (Alg. 1).
    Slcr,
    /// `SCCR-INIT` — collaborative reuse without area expansion.
    SccrInit,
    /// `SCCR` — the full proposed algorithm (Alg. 2).
    Sccr,
}

impl Scenario {
    /// All scenarios, in the paper's table/figure column order.
    pub const ALL: [Scenario; 5] = [
        Scenario::WithoutCr,
        Scenario::SrsPriority,
        Scenario::Slcr,
        Scenario::SccrInit,
        Scenario::Sccr,
    ];

    /// Does the scenario perform any computation reuse?
    pub fn uses_reuse(&self) -> bool {
        !matches!(self, Scenario::WithoutCr)
    }

    /// Does the scenario collaborate between satellites?
    pub fn collaborates(&self) -> bool {
        matches!(
            self,
            Scenario::SrsPriority | Scenario::SccrInit | Scenario::Sccr
        )
    }

    /// The collaboration behaviour of this scenario — `None` for the
    /// non-collaborating scenarios. The engine drives Alg. 2 triggering,
    /// damping and source selection entirely through this trait handle.
    pub fn collab_policy(&self) -> Option<&'static dyn CollabPolicy> {
        match self {
            Scenario::SrsPriority => Some(&SRS_PRIORITY_POLICY),
            Scenario::SccrInit => Some(&SCCR_INIT_POLICY),
            Scenario::Sccr => Some(&SCCR_POLICY),
            _ => None,
        }
    }

    /// The Alg. 2 area policy, for collaborating scenarios.
    pub fn area_policy(&self) -> Option<AreaPolicy> {
        self.collab_policy().map(|p| p.area_policy())
    }

    /// Column label used in the paper's tables.
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::WithoutCr => "w/o CR",
            Scenario::SrsPriority => "SRS Priority",
            Scenario::Slcr => "SLCR",
            Scenario::SccrInit => "SCCR-INIT",
            Scenario::Sccr => "SCCR",
        }
    }

    /// Parse a CLI name (case-insensitive, several aliases).
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "wo-cr" | "w/o-cr" | "wocr" | "without-cr" | "scratch" => {
                Some(Scenario::WithoutCr)
            }
            "srs-priority" | "srs" => Some(Scenario::SrsPriority),
            "slcr" | "local" => Some(Scenario::Slcr),
            "sccr-init" | "init" => Some(Scenario::SccrInit),
            "sccr" => Some(Scenario::Sccr),
            _ => None,
        }
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn properties_match_paper() {
        assert!(!Scenario::WithoutCr.uses_reuse());
        assert!(!Scenario::Slcr.collaborates());
        assert!(Scenario::Sccr.collaborates());
        assert_eq!(
            Scenario::Sccr.area_policy(),
            Some(AreaPolicy::WithExpansion)
        );
        assert_eq!(
            Scenario::SccrInit.area_policy(),
            Some(AreaPolicy::InitialOnly)
        );
        assert_eq!(Scenario::WithoutCr.area_policy(), None);
        assert_eq!(Scenario::Slcr.area_policy(), None);
    }

    #[test]
    fn collab_policies_map_to_scenarios() {
        assert!(Scenario::WithoutCr.collab_policy().is_none());
        assert!(Scenario::Slcr.collab_policy().is_none());
        assert!(Scenario::Sccr.collab_policy().unwrap().damped());
        assert!(Scenario::SccrInit.collab_policy().unwrap().damped());
        assert!(
            !Scenario::SrsPriority.collab_policy().unwrap().damped(),
            "the SRS Priority baseline floods"
        );
    }

    #[test]
    fn parse_roundtrip() {
        for s in Scenario::ALL {
            let label = s.label().to_ascii_lowercase().replace(' ', "-").replace("w/o", "wo");
            assert_eq!(Scenario::parse(&label), Some(s), "label {label}");
        }
        assert_eq!(Scenario::parse("nonsense"), None);
    }

    #[test]
    fn all_has_paper_order() {
        assert_eq!(Scenario::ALL[0].label(), "w/o CR");
        assert_eq!(Scenario::ALL[4].label(), "SCCR");
    }
}
