//! Satellite Reuse Status — eq. (11):
//!
//! ```text
//! SRS_S = β · rr_S + (1 − β) · (1 − C_S)
//! ```
//!
//! `rr_S` is the satellite's reuse rate, `C_S` its CPU occupancy. High SRS
//! ⇒ the satellite benefits from reuse and can serve as a data source;
//! SRS < `th_co` ⇒ the satellite requests collaboration (Alg. 2 trigger).

/// Compute SRS from the two indicators. Inputs are clamped to [0, 1] so a
/// transiently out-of-range occupancy estimate cannot produce SRS > 1.
pub fn srs(beta: f64, reuse_rate: f64, cpu_occupancy: f64) -> f64 {
    let rr = reuse_rate.clamp(0.0, 1.0);
    let c = cpu_occupancy.clamp(0.0, 1.0);
    beta * rr + (1.0 - beta) * (1.0 - c)
}

#[cfg(test)]
mod tests {
    use super::srs;

    #[test]
    fn eq11_reference_points() {
        // β = 0.5 (Table I)
        assert_eq!(srs(0.5, 0.0, 0.0), 0.5); // fresh satellite
        assert_eq!(srs(0.5, 1.0, 0.0), 1.0); // perfect reuse, idle CPU
        assert_eq!(srs(0.5, 0.0, 1.0), 0.0); // no reuse, saturated CPU
        assert_eq!(srs(0.5, 0.6, 0.4), 0.6);
    }

    #[test]
    fn monotonicity() {
        // increasing reuse rate raises SRS
        assert!(srs(0.5, 0.8, 0.5) > srs(0.5, 0.2, 0.5));
        // increasing occupancy lowers SRS
        assert!(srs(0.5, 0.5, 0.9) < srs(0.5, 0.5, 0.1));
    }

    #[test]
    fn beta_extremes() {
        // β = 1: SRS is the reuse rate alone
        assert_eq!(srs(1.0, 0.3, 0.9), 0.3);
        // β = 0: SRS is CPU headroom alone
        assert!((srs(0.0, 0.3, 0.9) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn clamps_out_of_range_inputs() {
        assert_eq!(srs(0.5, 2.0, -1.0), 1.0);
        assert_eq!(srs(0.5, -0.5, 2.0), 0.0);
    }

    #[test]
    fn bounded_in_unit_interval() {
        for i in 0..=10 {
            for j in 0..=10 {
                let v = srs(0.5, i as f64 / 10.0, j as f64 / 10.0);
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }
}
