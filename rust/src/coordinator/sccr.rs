//! Algorithm 2 — Satellite Collaborative Computation Reuse (SCCR).
//!
//! When a satellite's SRS (eq. 11) drops below `th_co` it becomes the
//! requesting satellite `S_req` and searches for a data-source satellite
//! `S_src`:
//!
//! 1. build the initial collaboration area (S_req + surrounding, a 3×3
//!    Chebyshev neighbourhood clamped at the grid edge);
//! 2. take `S_max = argmax SRS` over the area; if `SRS(S_max) > th_co`,
//!    it is the source;
//! 3. otherwise expand the area by one ring (surrounding satellites of all
//!    members) and retry once;
//! 4. if still no satellite clears `th_co`, the collaboration terminates.
//!
//! The variants used by the evaluation baselines:
//! * **SCCR-INIT** — skips step 3 (no expansion);
//! * **SRS Priority** — ignores areas entirely: the source is the global
//!   SRS maximum and the broadcast floods the whole network.

use crate::network::topology::GridTopology;
use crate::workload::SatId;

/// Outcome of a source search.
#[derive(Clone, Debug, PartialEq)]
pub struct CollabDecision {
    /// The chosen data-source satellite.
    pub source: SatId,
    /// The collaboration area the broadcast will cover (includes `S_req`
    /// and `source`).
    pub area: Vec<SatId>,
    /// Whether the expanded area was needed.
    pub expanded: bool,
}

/// Which area policy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AreaPolicy {
    /// Initial area only (SCCR-INIT).
    InitialOnly,
    /// Initial, then one expansion (full SCCR, Alg. 2).
    WithExpansion,
    /// Whole network, no threshold on the source (SRS Priority baseline).
    GlobalSrsPriority,
}

/// `find_SRS_max` over a candidate set, excluding the requester (a
/// satellite cannot be its own data source).
fn srs_max(area: &[SatId], req: SatId, srs: &[f64]) -> Option<SatId> {
    area.iter()
        .copied()
        .filter(|&s| s != req)
        .max_by(|&a, &b| srs[a].partial_cmp(&srs[b]).unwrap())
}

/// Algorithm 2. `srs` holds the current SRS value of every satellite.
/// Returns `None` when the collaboration terminates without a source.
pub fn select_source(
    topo: &GridTopology,
    req: SatId,
    srs: &[f64],
    th_co: f64,
    policy: AreaPolicy,
) -> Option<CollabDecision> {
    debug_assert_eq!(srs.len(), topo.len());

    if policy == AreaPolicy::GlobalSrsPriority {
        let area: Vec<SatId> = topo.all().collect();
        let source = srs_max(&area, req, srs)?;
        return Some(CollabDecision {
            source,
            area,
            expanded: false,
        });
    }

    // lines 1–3: initial area + its SRS maximum
    let area = topo.area(req, 1);
    if let Some(s_max) = srs_max(&area, req, srs) {
        if srs[s_max] > th_co {
            // lines 4–5
            return Some(CollabDecision {
                source: s_max,
                area,
                expanded: false,
            });
        }
    }

    if policy == AreaPolicy::InitialOnly {
        return None;
    }

    // lines 6–10: expand once and retry
    let expanded = topo.expand_area(&area);
    if let Some(s_max) = srs_max(&expanded, req, srs) {
        if srs[s_max] > th_co {
            return Some(CollabDecision {
                source: s_max,
                area: expanded,
                expanded: true,
            });
        }
    }

    // lines 11–13: terminate
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> GridTopology {
        GridTopology::new(5)
    }

    fn uniform(n: usize, v: f64) -> Vec<f64> {
        vec![v; n]
    }

    #[test]
    fn picks_best_in_initial_area() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        let req = t.sat_at(2, 2);
        let good = t.sat_at(1, 2); // inside initial area
        srs[good] = 0.9;
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.source, good);
        assert!(!d.expanded);
        assert_eq!(d.area.len(), 9);
        assert!(d.area.contains(&req));
    }

    #[test]
    fn expands_when_initial_area_is_poor() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        let req = t.sat_at(2, 2);
        let far = t.sat_at(0, 0); // Chebyshev distance 2: only in expanded
        srs[far] = 0.9;
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.source, far);
        assert!(d.expanded);
        assert_eq!(d.area.len(), 25); // radius-2 around the grid centre

        // SCCR-INIT must give up instead
        assert_eq!(
            select_source(&t, req, &srs, 0.5, AreaPolicy::InitialOnly),
            None
        );
    }

    #[test]
    fn terminates_when_nobody_clears_threshold() {
        let t = topo();
        let srs = uniform(25, 0.4);
        let d = select_source(&t, 12, &srs, 0.5, AreaPolicy::WithExpansion);
        assert_eq!(d, None);
    }

    #[test]
    fn threshold_is_strict() {
        let t = topo();
        let srs = uniform(25, 0.5); // exactly th_co: NOT > th_co
        assert_eq!(
            select_source(&t, 12, &srs, 0.5, AreaPolicy::WithExpansion),
            None
        );
    }

    #[test]
    fn requester_never_chosen_as_source() {
        let t = topo();
        let mut srs = uniform(25, 0.1);
        let req = t.sat_at(2, 2);
        srs[req] = 1.0; // the requester itself has the max
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion);
        assert!(d.is_none(), "requester must not self-serve");
    }

    #[test]
    fn srs_priority_spans_network_without_threshold() {
        let t = topo();
        let mut srs = uniform(25, 0.1); // all below th_co
        let far = t.sat_at(4, 4);
        srs[far] = 0.3; // still below th_co, but the global max
        let d =
            select_source(&t, 0, &srs, 0.5, AreaPolicy::GlobalSrsPriority).unwrap();
        assert_eq!(d.source, far);
        assert_eq!(d.area.len(), 25, "broadcast area is the whole network");
    }

    #[test]
    fn corner_requester_gets_clamped_area() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        srs[t.sat_at(0, 1)] = 0.8;
        let d = select_source(&t, 0, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.area.len(), 4); // 2x2 corner area
        assert_eq!(d.source, t.sat_at(0, 1));
    }
}
