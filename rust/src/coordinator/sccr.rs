//! Algorithm 2 — Satellite Collaborative Computation Reuse (SCCR).
//!
//! When a satellite's SRS (eq. 11) drops below `th_co` it becomes the
//! requesting satellite `S_req` and searches for a data-source satellite
//! `S_src`:
//!
//! 1. build the initial collaboration area (S_req + surrounding, a 3×3
//!    Chebyshev neighbourhood clamped at the grid edge);
//! 2. take `S_max = argmax SRS` over the area; if `SRS(S_max) > th_co`,
//!    it is the source;
//! 3. otherwise expand the area by one ring (surrounding satellites of all
//!    members) and retry once;
//! 4. if still no satellite clears `th_co`, the collaboration terminates.
//!
//! The variants used by the evaluation baselines:
//! * **SCCR-INIT** — skips step 3 (no expansion);
//! * **SRS Priority** — ignores areas entirely: the source is the global
//!   SRS maximum and the broadcast floods the whole network.

use crate::network::topology::GridTopology;
use crate::workload::SatId;

/// Outcome of a source search.
#[derive(Clone, Debug, PartialEq)]
pub struct CollabDecision {
    /// The chosen data-source satellite.
    pub source: SatId,
    /// The collaboration area the broadcast will cover (includes `S_req`
    /// and `source`).
    pub area: Vec<SatId>,
    /// Whether the expanded area was needed.
    pub expanded: bool,
}

/// Which area policy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AreaPolicy {
    /// Initial area only (SCCR-INIT).
    InitialOnly,
    /// Initial, then one expansion (full SCCR, Alg. 2).
    WithExpansion,
    /// Whole network, no threshold on the source (SRS Priority baseline).
    GlobalSrsPriority,
}

/// `find_SRS_max` over a candidate set, excluding the requester (a
/// satellite cannot be its own data source) and anything `eligible`
/// rejects (failover excludes satellites that are down).
///
/// Keyed through [`f64::total_cmp`], not `partial_cmp().unwrap()`: an SRS
/// lane can go NaN under adversarial workloads (0/0 in eq. 11 feeds), and
/// a comparator panic inside `max_by` would take the whole run down. Ties
/// break toward the **highest id**, which is exactly what the old
/// comparator produced on the (always id-ascending) area lists — `max_by`
/// keeps the last of equal maxima — so fault-free goldens are unchanged.
fn srs_max<F: Fn(SatId) -> bool>(
    area: &[SatId],
    req: SatId,
    srs: &[f64],
    eligible: &F,
) -> Option<SatId> {
    area.iter()
        .copied()
        .filter(|&s| s != req && eligible(s))
        .max_by(|&a, &b| srs[a].total_cmp(&srs[b]).then(a.cmp(&b)))
}

/// Algorithm 2. `srs` holds the current SRS value of every satellite.
/// Returns `None` when the collaboration terminates without a source.
pub fn select_source(
    topo: &GridTopology,
    req: SatId,
    srs: &[f64],
    th_co: f64,
    policy: AreaPolicy,
) -> Option<CollabDecision> {
    select_source_where(topo, req, srs, th_co, policy, |_| true)
}

/// Algorithm 2 restricted to an eligibility predicate — the failover path
/// of the node-fault model re-runs the search with crashed satellites
/// excluded. `select_source` is the `|_| true` specialisation, so the
/// fault-free path runs byte-identical logic.
pub fn select_source_where<F: Fn(SatId) -> bool>(
    topo: &GridTopology,
    req: SatId,
    srs: &[f64],
    th_co: f64,
    policy: AreaPolicy,
    eligible: F,
) -> Option<CollabDecision> {
    debug_assert_eq!(srs.len(), topo.len());

    if policy == AreaPolicy::GlobalSrsPriority {
        let area: Vec<SatId> = topo.all().collect();
        let source = srs_max(&area, req, srs, &eligible)?;
        return Some(CollabDecision {
            source,
            area,
            expanded: false,
        });
    }

    // lines 1–3: initial area + its SRS maximum
    let area = topo.area(req, 1);
    if let Some(s_max) = srs_max(&area, req, srs, &eligible) {
        if srs[s_max] > th_co {
            // lines 4–5
            return Some(CollabDecision {
                source: s_max,
                area,
                expanded: false,
            });
        }
    }

    if policy == AreaPolicy::InitialOnly {
        return None;
    }

    // lines 6–10: expand once and retry
    let expanded = topo.expand_area(&area);
    if let Some(s_max) = srs_max(&expanded, req, srs, &eligible) {
        if srs[s_max] > th_co {
            return Some(CollabDecision {
                source: s_max,
                area: expanded,
                expanded: true,
            });
        }
    }

    // lines 11–13: terminate
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> GridTopology {
        GridTopology::new(5)
    }

    fn uniform(n: usize, v: f64) -> Vec<f64> {
        vec![v; n]
    }

    #[test]
    fn picks_best_in_initial_area() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        let req = t.sat_at(2, 2);
        let good = t.sat_at(1, 2); // inside initial area
        srs[good] = 0.9;
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.source, good);
        assert!(!d.expanded);
        assert_eq!(d.area.len(), 9);
        assert!(d.area.contains(&req));
    }

    #[test]
    fn expands_when_initial_area_is_poor() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        let req = t.sat_at(2, 2);
        let far = t.sat_at(0, 0); // Chebyshev distance 2: only in expanded
        srs[far] = 0.9;
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.source, far);
        assert!(d.expanded);
        assert_eq!(d.area.len(), 25); // radius-2 around the grid centre

        // SCCR-INIT must give up instead
        assert_eq!(
            select_source(&t, req, &srs, 0.5, AreaPolicy::InitialOnly),
            None
        );
    }

    #[test]
    fn terminates_when_nobody_clears_threshold() {
        let t = topo();
        let srs = uniform(25, 0.4);
        let d = select_source(&t, 12, &srs, 0.5, AreaPolicy::WithExpansion);
        assert_eq!(d, None);
    }

    #[test]
    fn threshold_is_strict() {
        let t = topo();
        let srs = uniform(25, 0.5); // exactly th_co: NOT > th_co
        assert_eq!(
            select_source(&t, 12, &srs, 0.5, AreaPolicy::WithExpansion),
            None
        );
    }

    #[test]
    fn requester_never_chosen_as_source() {
        let t = topo();
        let mut srs = uniform(25, 0.1);
        let req = t.sat_at(2, 2);
        srs[req] = 1.0; // the requester itself has the max
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion);
        assert!(d.is_none(), "requester must not self-serve");
    }

    #[test]
    fn srs_priority_spans_network_without_threshold() {
        let t = topo();
        let mut srs = uniform(25, 0.1); // all below th_co
        let far = t.sat_at(4, 4);
        srs[far] = 0.3; // still below th_co, but the global max
        let d =
            select_source(&t, 0, &srs, 0.5, AreaPolicy::GlobalSrsPriority).unwrap();
        assert_eq!(d.source, far);
        assert_eq!(d.area.len(), 25, "broadcast area is the whole network");
    }

    #[test]
    fn nan_srs_lanes_do_not_panic_and_never_yield_a_source() {
        // The old comparator was `partial_cmp().unwrap()`: any NaN SRS
        // lane (0/0 in an eq. 11 feed) panicked inside `max_by`. With
        // total_cmp a positive NaN ranks above every finite value, wins
        // the argmax, and then fails the strict `srs > th_co` gate — the
        // collaboration terminates deterministically instead of crashing.
        let t = topo();
        let req = t.sat_at(2, 2);
        let all_nan = uniform(25, f64::NAN);
        assert_eq!(
            select_source(&t, req, &all_nan, 0.5, AreaPolicy::WithExpansion),
            None,
            "all-NaN SRS must terminate, not panic"
        );
        let mut mixed = uniform(25, f64::NAN);
        mixed[t.sat_at(1, 2)] = 0.9;
        assert_eq!(
            select_source(&t, req, &mixed, 0.5, AreaPolicy::WithExpansion),
            None,
            "a NaN argmax never clears the threshold"
        );
        // GlobalSrsPriority has no threshold, so there a NaN lane *can*
        // be picked — but deterministically (highest NaN id), not a panic.
        let g = select_source(&t, req, &all_nan, 0.5, AreaPolicy::GlobalSrsPriority)
            .unwrap();
        assert_eq!(g.source, 24);
    }

    #[test]
    fn equal_srs_ties_break_toward_the_highest_id() {
        let t = topo();
        let srs = uniform(25, 0.9); // everyone equally attractive
        let req = t.sat_at(2, 2);
        let d = select_source(&t, req, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        // Initial area of (2,2) is rows 1..=3 × cols 1..=3; the old
        // `max_by` kept the last of equal maxima on the id-ascending area
        // list, i.e. sat_at(3,3). The explicit tie-break must match.
        assert_eq!(d.source, t.sat_at(3, 3));
        let g = select_source(&t, req, &srs, 0.5, AreaPolicy::GlobalSrsPriority)
            .unwrap();
        assert_eq!(g.source, 24, "global tie goes to the highest id");
    }

    #[test]
    fn eligibility_filter_excludes_down_sources() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        let req = t.sat_at(2, 2);
        let best = t.sat_at(1, 2);
        let second = t.sat_at(3, 2);
        srs[best] = 0.9;
        srs[second] = 0.8;
        let d = select_source_where(&t, req, &srs, 0.5, AreaPolicy::WithExpansion, |s| {
            s != best // `best` crashed
        })
        .unwrap();
        assert_eq!(d.source, second, "failover picks the best live source");
        // Everyone in reach down: the collaboration terminates.
        assert_eq!(
            select_source_where(&t, req, &srs, 0.5, AreaPolicy::WithExpansion, |_| false),
            None
        );
    }

    #[test]
    fn corner_requester_gets_clamped_area() {
        let t = topo();
        let mut srs = uniform(25, 0.2);
        srs[t.sat_at(0, 1)] = 0.8;
        let d = select_source(&t, 0, &srs, 0.5, AreaPolicy::WithExpansion).unwrap();
        assert_eq!(d.area.len(), 4); // 2x2 corner area
        assert_eq!(d.source, t.sat_at(0, 1));
    }
}
