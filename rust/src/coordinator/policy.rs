//! Scenario behaviour behind the engine: *when* a satellite requests
//! collaboration and *how* the data source is chosen.
//!
//! The engine ([`crate::simulator::engine`]) is scenario-agnostic: at every
//! task completion it asks the active [`CollabPolicy`] whether the Alg. 2
//! trigger fires, and — when it does — delegates source selection to the
//! policy. The damping/hysteresis special-casing that used to live as
//! `if self.scenario != Scenario::SrsPriority` branches inside the event
//! loop is a trait method here, so new scenarios plug in as new impls
//! instead of new branches.
//!
//! Three built-in policies mirror the paper's collaborating scenarios:
//!
//! * [`SccrPolicy`] — full SCCR (Alg. 2): damped, one area expansion;
//! * [`SccrInitPolicy`] — SCCR-INIT: damped, initial area only;
//! * [`SrsPriorityPolicy`] — the SRS-Priority baseline: global source,
//!   whole-network flood, **no damping** — exactly the "redundant
//!   cooperation" behaviour the paper blames for its poor performance.

use crate::coordinator::sccr::{
    select_source, select_source_where, AreaPolicy, CollabDecision,
};
use crate::network::topology::GridTopology;
use crate::workload::SatId;

/// Per-scenario collaboration behaviour (Alg. 2 trigger + source search).
///
/// `Sync` is a supertrait so the engine's `&'static dyn CollabPolicy`
/// handle is `Send` — one policy instance serves all scenario threads.
pub trait CollabPolicy: Sync {
    /// The Alg. 2 area policy driving source selection.
    fn area_policy(&self) -> AreaPolicy;

    /// Do the damping mechanisms apply — request hysteresis, receiver
    /// suppression after a delivery, and the network quiet period while a
    /// broadcast is in flight? The proposed designs damp; the naive SRS
    /// Priority baseline floods whenever its cooldown allows.
    fn damped(&self) -> bool {
        true
    }

    /// Should a satellite whose SRS is `my_srs` issue a collaboration
    /// request now? `armed` is the requester's hysteresis state, `cooled`
    /// whether its cooldown window has elapsed, and `quiet_until` the
    /// virtual time until which the inter-satellite links are saturated
    /// with a previous broadcast's payloads.
    ///
    /// **Contract for the sharded engine:** the answer must be monotone
    /// *non-increasing* in `quiet_until` (a later quiet horizon may only
    /// suppress, never admit, a request). Shard workers evaluate the gate
    /// against a possibly-stale — i.e. never-later — horizon and pause on
    /// a pass; the coordinator then re-checks against the authoritative
    /// horizon at resolution, which is exact precisely because staleness
    /// can only over-trigger. The default implementation satisfies this
    /// (`quiet_until` appears solely as `now >= quiet_until`).
    fn should_request(
        &self,
        armed: bool,
        my_srs: f64,
        th_co: f64,
        cooled: bool,
        now: f64,
        quiet_until: f64,
    ) -> bool {
        my_srs < th_co
            && cooled
            && (!self.damped() || (armed && now >= quiet_until))
    }

    /// Run source selection (Alg. 2 lines 1–13 / the baseline variants).
    fn select_source(
        &self,
        topo: &GridTopology,
        req: SatId,
        all_srs: &[f64],
        th_co: f64,
    ) -> Option<CollabDecision> {
        select_source(topo, req, all_srs, th_co, self.area_policy())
    }

    /// Failover source selection: the node-fault model re-runs Alg. 2 with
    /// crashed satellites filtered out (`alive` is the liveness predicate
    /// at the retry instant). The unfiltered [`Self::select_source`] stays
    /// the fault-free entry point so that path is byte-identical to the
    /// pre-fault code.
    fn select_source_alive(
        &self,
        topo: &GridTopology,
        req: SatId,
        all_srs: &[f64],
        th_co: f64,
        alive: &dyn Fn(SatId) -> bool,
    ) -> Option<CollabDecision> {
        select_source_where(topo, req, all_srs, th_co, self.area_policy(), alive)
    }
}

/// Full SCCR (Alg. 2): damped, with one area expansion.
pub struct SccrPolicy;

impl CollabPolicy for SccrPolicy {
    fn area_policy(&self) -> AreaPolicy {
        AreaPolicy::WithExpansion
    }
}

/// SCCR-INIT baseline: damped, initial collaboration area only.
pub struct SccrInitPolicy;

impl CollabPolicy for SccrInitPolicy {
    fn area_policy(&self) -> AreaPolicy {
        AreaPolicy::InitialOnly
    }
}

/// SRS-Priority baseline: global SRS maximum as the source, whole-network
/// broadcast, no damping.
pub struct SrsPriorityPolicy;

impl CollabPolicy for SrsPriorityPolicy {
    fn area_policy(&self) -> AreaPolicy {
        AreaPolicy::GlobalSrsPriority
    }

    fn damped(&self) -> bool {
        false
    }
}

/// Shared policy instances ([`crate::coordinator::Scenario::collab_policy`]
/// hands these out; the policies are stateless, so one of each serves the
/// whole process).
pub static SCCR_POLICY: SccrPolicy = SccrPolicy;
pub static SCCR_INIT_POLICY: SccrInitPolicy = SccrInitPolicy;
pub static SRS_PRIORITY_POLICY: SrsPriorityPolicy = SrsPriorityPolicy;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn damped_policies_gate_on_hysteresis_and_quiet_period() {
        let p = &SCCR_POLICY;
        // below threshold, cooled, armed, network quiet: request
        assert!(p.should_request(true, 0.2, 0.5, true, 10.0, 5.0));
        // disarmed: suppressed
        assert!(!p.should_request(false, 0.2, 0.5, true, 10.0, 5.0));
        // network still busy: suppressed
        assert!(!p.should_request(true, 0.2, 0.5, true, 10.0, 20.0));
        // not cooled: suppressed
        assert!(!p.should_request(true, 0.2, 0.5, false, 10.0, 5.0));
        // SRS fine: no need
        assert!(!p.should_request(true, 0.9, 0.5, true, 10.0, 5.0));
    }

    #[test]
    fn flooding_policy_ignores_damping() {
        let p = &SRS_PRIORITY_POLICY;
        assert!(!p.damped());
        // disarmed and network busy — SRS Priority floods anyway
        assert!(p.should_request(false, 0.2, 0.5, true, 10.0, 20.0));
        // ... but still respects its own cooldown and threshold
        assert!(!p.should_request(false, 0.2, 0.5, false, 10.0, 20.0));
        assert!(!p.should_request(false, 0.9, 0.5, true, 10.0, 20.0));
    }

    #[test]
    fn policies_carry_their_area_policies() {
        assert_eq!(SCCR_POLICY.area_policy(), AreaPolicy::WithExpansion);
        assert_eq!(SCCR_INIT_POLICY.area_policy(), AreaPolicy::InitialOnly);
        assert_eq!(
            SRS_PRIORITY_POLICY.area_policy(),
            AreaPolicy::GlobalSrsPriority
        );
    }

    #[test]
    fn select_source_delegates_to_area_policy() {
        let topo = GridTopology::new(5);
        let mut srs = vec![0.2; 25];
        let req = topo.sat_at(2, 2);
        let far = topo.sat_at(0, 0); // only reachable via expansion
        srs[far] = 0.9;
        let d = SCCR_POLICY.select_source(&topo, req, &srs, 0.5).unwrap();
        assert_eq!(d.source, far);
        assert!(d.expanded);
        assert!(SCCR_INIT_POLICY
            .select_source(&topo, req, &srs, 0.5)
            .is_none());
    }

    #[test]
    fn liveness_filtered_selection_skips_dead_sources() {
        let topo = GridTopology::new(5);
        let mut srs = vec![0.2; 25];
        let req = topo.sat_at(2, 2);
        let best = topo.sat_at(1, 2);
        let backup = topo.sat_at(2, 1);
        srs[best] = 0.9;
        srs[backup] = 0.7;
        let d = SCCR_POLICY
            .select_source_alive(&topo, req, &srs, 0.5, &|s| s != best)
            .unwrap();
        assert_eq!(d.source, backup, "failover must route around the crash");
        // With everyone dead the cascade's final reselection terminates.
        assert!(SCCR_POLICY
            .select_source_alive(&topo, req, &srs, 0.5, &|_| false)
            .is_none());
    }
}
