//! Satellite Computation Reuse Table (SCRT).
//!
//! Caches reuse records `⟨D_t, P_t, R_t, N_t⟩` (Sec. III-A), organised as a
//! hyperplane-LSH table (`p_l = 1` table, `2^p_k` buckets). The capacity
//! `C^stg` is enforced in records (every record carries the same 20.5 MB
//! payload); when full, the record with the lowest `(N_t, recency)` value is
//! evicted — reuse *value*, then LRU, mirroring how the paper reasons about
//! high-value records.
//!
//! Nearest-neighbour search inside a bucket is an exact L2 scan over the
//! pre-processed feature vectors (what FALCONN does after hashing); the
//! expensive SSIM gate (eq. 12) then runs on the single best candidate, via
//! the compute backend — exactly Alg. 1 lines 2 & 8.

use crate::compute::Preprocessed;
use crate::workload::SatId;

/// Globally unique record identity: the task that created it. Broadcast
/// copies keep the id so "already cached" (Sec. IV-A step 4) is decidable.
pub type RecordId = usize;

/// One reuse record.
#[derive(Clone, Debug)]
pub struct Record {
    pub id: RecordId,
    /// Pre-processed input (`D_t` after Alg. 1 line 1) — both the feature
    /// vector for NN search and the grayscale plane for SSIM.
    pub pre: Preprocessed,
    /// Task type `P_t`.
    pub task_type: u16,
    /// Cached result `R_t` (the class label).
    pub result: u32,
    /// Reuse count `N_t`.
    pub reuse_count: u32,
    /// Virtual time of creation/last reuse (eviction recency).
    pub last_used: f64,
    /// Satellite that computed the original result (diagnostics).
    pub origin: SatId,
}

/// The reuse table of one satellite.
#[derive(Clone, Debug)]
pub struct Scrt {
    buckets: Vec<Vec<Record>>,
    capacity: usize,
    len: usize,
    /// Total evictions (observability).
    pub evictions: u64,
}

impl Scrt {
    /// `num_buckets = 2^p_k`; `capacity` in records (`C^stg` / record size).
    pub fn new(num_buckets: usize, capacity: usize) -> Self {
        assert!(num_buckets.is_power_of_two(), "buckets must be 2^p_k");
        assert!(capacity > 0, "capacity must be positive");
        Scrt {
            buckets: vec![Vec::new(); num_buckets],
            capacity,
            len: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Is a record with this identity already cached?
    pub fn contains(&self, id: RecordId) -> bool {
        self.buckets.iter().any(|b| b.iter().any(|r| r.id == id))
    }

    /// Exact nearest neighbour (min L2 over `pd`) within a bucket, filtered
    /// by task type. Returns `(bucket_slot, distance²)`.
    pub fn nearest(
        &self,
        bucket: u32,
        task_type: u16,
        pre: &Preprocessed,
    ) -> Option<(usize, f32)> {
        let b = &self.buckets[bucket as usize];
        let mut best: Option<(usize, f32)> = None;
        for (slot, rec) in b.iter().enumerate() {
            if rec.task_type != task_type {
                continue;
            }
            let d = l2_sq(&rec.pre.pd, &pre.pd);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((slot, d));
            }
        }
        best
    }

    /// Borrow a record by (bucket, slot).
    pub fn record(&self, bucket: u32, slot: usize) -> &Record {
        &self.buckets[bucket as usize][slot]
    }

    /// Register a successful reuse of a record (Alg. 1 line 11).
    pub fn mark_reused(&mut self, bucket: u32, slot: usize, now: f64) {
        let rec = &mut self.buckets[bucket as usize][slot];
        rec.reuse_count += 1;
        rec.last_used = now;
    }

    /// Insert a record into a bucket, evicting the lowest-value record
    /// (min `(reuse_count, last_used)`, scanned across all buckets) if full.
    /// Returns the evicted record id, if any.
    pub fn insert(&mut self, bucket: u32, record: Record) -> Option<RecordId> {
        let mut evicted = None;
        if self.len >= self.capacity {
            evicted = self.evict_lowest_value();
        }
        self.buckets[bucket as usize].push(record);
        self.len += 1;
        evicted
    }

    /// Merge a broadcast record (Sec. IV-A step 4): skip when already
    /// cached; otherwise insert with `N_t` reset to zero. Returns true if
    /// the record was actually inserted.
    pub fn merge_broadcast(&mut self, bucket: u32, mut record: Record, now: f64) -> bool {
        if self.contains(record.id) {
            return false;
        }
        record.reuse_count = 0;
        record.last_used = now;
        self.insert(bucket, record);
        true
    }

    /// The `τ` records with the highest reuse counts (ties broken by
    /// recency), cloned for broadcast, with their bucket ids.
    pub fn top_tau(&self, tau: usize) -> Vec<(u32, Record)> {
        let mut all: Vec<(u32, &Record)> = Vec::with_capacity(self.len);
        for (b, bucket) in self.buckets.iter().enumerate() {
            for rec in bucket {
                all.push((b as u32, rec));
            }
        }
        all.sort_by(|(_, x), (_, y)| {
            y.reuse_count
                .cmp(&x.reuse_count)
                .then(y.last_used.partial_cmp(&x.last_used).unwrap())
        });
        all.truncate(tau);
        all.into_iter().map(|(b, r)| (b, r.clone())).collect()
    }

    /// All records (diagnostics / tests).
    pub fn iter(&self) -> impl Iterator<Item = (u32, &Record)> {
        self.buckets
            .iter()
            .enumerate()
            .flat_map(|(b, bucket)| bucket.iter().map(move |r| (b as u32, r)))
    }

    fn evict_lowest_value(&mut self) -> Option<RecordId> {
        let mut victim: Option<(usize, usize, u32, f64)> = None; // (bucket, slot, count, last)
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (si, rec) in bucket.iter().enumerate() {
                let worse = match victim {
                    None => true,
                    Some((_, _, c, l)) => {
                        rec.reuse_count < c || (rec.reuse_count == c && rec.last_used < l)
                    }
                };
                if worse {
                    victim = Some((bi, si, rec.reuse_count, rec.last_used));
                }
            }
        }
        victim.map(|(bi, si, _, _)| {
            let rec = self.buckets[bi].swap_remove(si);
            self.len -= 1;
            self.evictions += 1;
            rec.id
        })
    }
}

#[inline]
fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pre(fill: f32) -> Preprocessed {
        Preprocessed {
            h: 2,
            w: 2,
            pd: vec![fill; 12],
            gray: vec![fill; 4],
        }
    }

    fn rec(id: RecordId, fill: f32, count: u32, t: f64) -> Record {
        Record {
            id,
            pre: pre(fill),
            task_type: 0,
            result: id as u32,
            reuse_count: count,
            last_used: t,
            origin: 0,
        }
    }

    #[test]
    fn nearest_picks_min_l2() {
        let mut s = Scrt::new(4, 10);
        s.insert(1, rec(0, 0.1, 0, 0.0));
        s.insert(1, rec(1, 0.5, 0, 0.0));
        s.insert(1, rec(2, 0.9, 0, 0.0));
        let (slot, d) = s.nearest(1, 0, &pre(0.55)).unwrap();
        assert_eq!(s.record(1, slot).id, 1);
        assert!(d < 0.1);
        // other bucket is empty
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
    }

    #[test]
    fn nearest_filters_task_type() {
        let mut s = Scrt::new(2, 10);
        let mut r = rec(0, 0.5, 0, 0.0);
        r.task_type = 3;
        s.insert(0, r);
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
        assert!(s.nearest(0, 3, &pre(0.5)).is_some());
    }

    #[test]
    fn capacity_enforced_with_value_eviction() {
        let mut s = Scrt::new(2, 3);
        s.insert(0, rec(0, 0.0, 5, 0.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // lowest count -> victim
        s.insert(1, rec(2, 0.2, 3, 2.0));
        let evicted = s.insert(1, rec(3, 0.3, 0, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(s.len(), 3);
        assert!(!s.contains(1));
        assert!(s.contains(0) && s.contains(2) && s.contains(3));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn eviction_ties_broken_by_recency() {
        let mut s = Scrt::new(1, 2);
        s.insert(0, rec(0, 0.0, 1, 5.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // same count, older -> victim
        let evicted = s.insert(0, rec(2, 0.2, 0, 9.0));
        assert_eq!(evicted, Some(1));
    }

    #[test]
    fn top_tau_orders_by_reuse_count() {
        let mut s = Scrt::new(4, 10);
        s.insert(0, rec(0, 0.0, 2, 0.0));
        s.insert(1, rec(1, 0.1, 7, 1.0));
        s.insert(2, rec(2, 0.2, 4, 2.0));
        let top = s.top_tau(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].1.id, 1);
        assert_eq!(top[1].1.id, 2);
        assert_eq!(top[0].0, 1, "bucket id travels with the record");
        // tau larger than len -> everything
        assert_eq!(s.top_tau(99).len(), 3);
    }

    #[test]
    fn merge_broadcast_skips_duplicates_and_resets_count() {
        let mut s = Scrt::new(2, 10);
        s.insert(0, rec(7, 0.5, 3, 0.0));
        assert!(!s.merge_broadcast(0, rec(7, 0.5, 9, 1.0), 1.0));
        assert!(s.merge_broadcast(1, rec(8, 0.6, 9, 1.0), 1.0));
        let (_, r) = s.iter().find(|(_, r)| r.id == 8).unwrap();
        assert_eq!(r.reuse_count, 0, "broadcast count must reset (step 4)");
    }

    #[test]
    fn mark_reused_bumps_count_and_recency() {
        let mut s = Scrt::new(1, 4);
        s.insert(0, rec(0, 0.5, 0, 0.0));
        let (slot, _) = s.nearest(0, 0, &pre(0.5)).unwrap();
        s.mark_reused(0, slot, 9.0);
        assert_eq!(s.record(0, slot).reuse_count, 1);
        assert_eq!(s.record(0, slot).last_used, 9.0);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_buckets_rejected() {
        Scrt::new(3, 4);
    }
}
