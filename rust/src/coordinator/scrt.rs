//! Satellite Computation Reuse Table (SCRT).
//!
//! Caches reuse records `⟨D_t, P_t, R_t, N_t⟩` (Sec. III-A), organised as a
//! hyperplane-LSH table (`p_l = 1` table, `2^p_k` buckets). The capacity
//! `C^stg` is enforced in records (every record carries the same 20.5 MB
//! payload); when full, the record with the lowest `(N_t, recency)` value is
//! evicted — reuse *value*, then LRU, mirroring how the paper reasons about
//! high-value records.
//!
//! ## Indexed hot path
//!
//! Every per-task operation is backed by a maintained index instead of a
//! whole-table scan (the paper's gains depend on lookups staying far
//! cheaper than recomputation, so the table is a first-class data
//! structure, not a scan):
//!
//! * **identity** — an id → `(bucket, slot)` map makes [`Scrt::contains`]
//!   and the broadcast-merge dedup (Sec. IV-A step 4) O(1);
//! * **value order** — an ordered index over ascending
//!   `(N_t, last_used, id)` keys serves both ends of the value spectrum:
//!   eviction pops the minimum in O(log n) and [`Scrt::top_tau`] reads the
//!   τ maxima in O(τ + log n), replacing the old full-table victim scan
//!   and full sort. `last_used` is keyed through the IEEE-754 total order
//!   (`f64::total_cmp` semantics), so a NaN recency can never panic the
//!   comparator; ties break on the record id, deterministically;
//! * **features** — each bucket stores its feature vectors
//!   structure-of-arrays style in one contiguous `Vec<f32>` (stride = pd
//!   length), so the exact nearest-neighbour scan in [`Scrt::nearest`] is
//!   a cache-friendly chunked L2 pass (what FALCONN does after hashing)
//!   instead of a pointer chase through per-record heap allocations.
//!
//! The expensive SSIM gate (eq. 12) then runs on the single best
//! candidate, via the compute backend — exactly Alg. 1 lines 2 & 8.
//!
//! ## Quantized coarse scan
//!
//! On populous buckets [`Scrt::nearest`] does not run the exact f32 scan
//! over every record. Each bucket maintains a u8-quantized mirror of its
//! SoA feature array (per-record scale/zero-point, kept in lock-step by
//! insert/evict/merge): a widened-integer pass over the 1-byte codes —
//! 4× less memory traffic than the f32 scan, and an associative integer
//! reduction the autovectorizer is free to reorder — yields, per record,
//! a *provably safe lower bound* on the exact distance. The lower bound
//! combines the coarse distance with each record's **measured**
//! reconstruction error (`‖f − f̂‖₂`, computed at quantization time, so no
//! analytic model of the quantizer is trusted), an explicit f64
//! evaluation margin, and the f32 summation-error factor of `l2_sq`
//! itself. Records whose bound exceeds the coarse winner's exact distance
//! provably cannot win; the survivors are re-ranked in ascending slot
//! order by the *unchanged* `l2_sq`, so the returned `(slot, distance)` —
//! including the earliest-slot-wins tie rule — is bit-identical to the
//! full scan (property-tested against the naive reference model in
//! `tests/properties.rs`; the error-bound argument is spelled out in
//! `docs/ARCHITECTURE.md`). Small buckets, oversized dims and non-finite
//! probes fall back to the exact scan verbatim.
//!
//! ## Op journal (sharded engine support)
//!
//! With [`Scrt::enable_journal`] the table records every mutation as a
//! [`ScrtOp`] — including the full payload of eviction victims — so
//! [`Scrt::top_tau_at`] can answer "what would `top_tau` have returned at
//! an earlier virtual time `t`?" without cloning or rolling back the live
//! table. The sharded engine needs exactly that: a conservative window may
//! process a satellite past the instant another shard's Alg. 2 request
//! reads its SCRT, and the journal makes that read exact. Journaling is
//! off by default and costs the single-threaded hot path nothing beyond
//! one `Option` check per mutation.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::compute::Preprocessed;
use crate::workload::SatId;

/// Globally unique record identity: the task that created it. Broadcast
/// copies keep the id so "already cached" (Sec. IV-A step 4) is decidable.
pub type RecordId = usize;

/// One reuse record in exchange form — what callers insert and what
/// broadcasts carry. Inside the table the fields are split across the
/// bucket's SoA feature array and the per-slot metadata; [`Scrt::top_tau`]
/// reassembles full records for the wire.
#[derive(Clone, Debug)]
pub struct Record {
    pub id: RecordId,
    /// Pre-processed input (`D_t` after Alg. 1 line 1) — both the feature
    /// vector for NN search and the grayscale plane for SSIM. `Arc`-backed
    /// so broadcast fan-out ([`Scrt::top_tau`]) and merge clones share one
    /// payload allocation instead of duplicating `pd`/`gray` per copy.
    pub pre: Arc<Preprocessed>,
    /// Task type `P_t`.
    pub task_type: u16,
    /// Cached result `R_t` (the class label).
    pub result: u32,
    /// Reuse count `N_t`.
    pub reuse_count: u32,
    /// Virtual time of creation/last reuse (eviction recency).
    pub last_used: f64,
    /// Satellite that computed the original result (diagnostics).
    pub origin: SatId,
}

/// Borrowed view of one cached record, reassembled by reference from the
/// table's SoA storage. This is the read API for callers that previously
/// borrowed a whole `&Record`.
#[derive(Clone, Copy, Debug)]
pub struct RecordView<'a> {
    pub id: RecordId,
    pub task_type: u16,
    pub result: u32,
    pub reuse_count: u32,
    pub last_used: f64,
    pub origin: SatId,
    /// Feature vector `PD_t`, borrowed from the bucket's SoA array.
    pub pd: &'a [f32],
    /// Grayscale plane for the SSIM gate.
    pub gray: &'a [f32],
    pub h: usize,
    pub w: usize,
}

/// Per-slot metadata. The feature vector deliberately does *not* live
/// here: it sits in the owning bucket's contiguous `feats` array so the
/// NN scan never chases per-record heap pointers.
#[derive(Clone, Debug)]
struct Slot {
    id: RecordId,
    task_type: u16,
    result: u32,
    reuse_count: u32,
    last_used: f64,
    origin: SatId,
    /// The record's full shared payload, exactly as inserted. The SSIM
    /// gate reads `h`/`w`/`gray` through it ([`Scrt::candidate_pre`]),
    /// and [`Scrt::top_tau`] hands the `Arc` out verbatim — zero payload
    /// allocation on the collaboration fan-out path. `pd` is also mirrored
    /// into the bucket's contiguous `feats` for the NN scan; that copy is
    /// the price of keeping the broadcast path allocation-free.
    payload: Arc<Preprocessed>,
}

/// One LSH bucket: SoA feature storage plus parallel slot metadata.
/// Slot `i`'s feature vector occupies `feats[i * dim .. (i + 1) * dim]`,
/// and its quantized mirror occupies `qcodes[i * dim .. (i + 1) * dim]`
/// with per-record parameters in `qmeta[i]` — the three arrays move in
/// lock-step through insert and `swap_remove` eviction.
#[derive(Clone, Debug, Default)]
struct Bucket {
    feats: Vec<f32>,
    slots: Vec<Slot>,
    /// u8-quantized mirror of `feats` (same stride) for the coarse scan.
    qcodes: Vec<u8>,
    /// Per-slot quantization parameters, parallel to `slots`.
    qmeta: Vec<QuantMeta>,
}

/// Per-record quantization parameters of the coarse mirror. A code `q`
/// reconstructs as `zero + scale · q` (both promoted f32 values, so the
/// f64 reconstruction arithmetic below is exact to one rounding).
#[derive(Clone, Copy, Debug)]
struct QuantMeta {
    /// Zero-point: the record's minimum feature value.
    zero: f64,
    /// Step size: `(max − min) / 255` (0 for a constant record).
    scale: f64,
    /// `Σ qᵢ` — exact (< 2^53).
    sum_q: f64,
    /// `Σ qᵢ²` — exact (< 2^53).
    sum_q2: f64,
    /// **Measured** reconstruction error `‖f − f̂‖₂`, inflated by the
    /// measurement's own f64 rounding slack. `+∞` marks a record with
    /// non-finite features: its lower bound collapses to 0, so the exact
    /// re-rank always visits it.
    err_l2: f64,
}

/// Minimum bucket population before the coarse pass pays for itself;
/// below it [`Scrt::nearest`] runs the exact scan directly. Correctness
/// is threshold-independent (both paths return identical bits).
const QUANT_MIN_SLOTS: usize = 16;

/// Feature-dim ceiling for the coarse pass: keeps the widened-integer
/// lane accumulators provably overflow-free (`(dim/8) · 255² < 2^32`)
/// with a wide margin. Larger strides fall back to the exact scan.
const MAX_QUANT_DIM: usize = 1 << 18;

/// Relative slack covering the f64 rounding of the expanded coarse
/// distance (≈ 15 roundings ⇒ true error < 2e-15 of the term-magnitude
/// sum; 1e-12 leaves ~500× headroom).
const COARSE_EVAL_EPS: f64 = 1e-12;

/// Quantize a feature row to u8 codes (appended to `codes`) and return
/// its [`QuantMeta`]. The reconstruction-error bound is *measured* from
/// the codes actually produced, so the lower bound stays safe even for
/// pathological inputs (subnormal scales, saturating casts).
fn quantize_row(pd: &[f32], codes: &mut Vec<u8>) -> QuantMeta {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in pd {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let range = hi - lo;
    if !range.is_finite() {
        // Non-finite features (or a range overflowing f32): mirror with
        // all-zero codes and an infinite error bound — always re-ranked.
        codes.resize(codes.len() + pd.len(), 0);
        return QuantMeta {
            zero: 0.0,
            scale: 0.0,
            sum_q: 0.0,
            sum_q2: 0.0,
            err_l2: f64::INFINITY,
        };
    }
    let scale = range / 255.0;
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    let (z, s) = (f64::from(lo), f64::from(scale));
    let mut sum_q = 0.0f64;
    let mut sum_q2 = 0.0f64;
    let mut err2 = 0.0f64;
    let mut amax = 0.0f64;
    for &v in pd {
        // Saturating cast: ±∞ clamps, NaN → 0 — any code is *safe*
        // because the error bound below measures what was stored.
        let q = ((v - lo) * inv).round().clamp(0.0, 255.0) as u8;
        codes.push(q);
        let qd = f64::from(q);
        sum_q += qd;
        sum_q2 += qd * qd;
        let rec = z + s * qd;
        let e = f64::from(v) - rec;
        err2 += e * e;
        amax = amax.max(rec.abs()).max(f64::from(v).abs());
    }
    // Inflate the measured bound past the measurement's own rounding:
    // a relative factor for the O(dim) f64 summation plus an absolute
    // term for the one rounding in each reconstruction (≤ |f̂|·2⁻⁵³).
    let n = pd.len() as f64;
    let err_l2 = err2.sqrt() * (1.0 + 1e-9) + (amax + 1.0) * n.sqrt() * 1e-13;
    QuantMeta {
        zero: z,
        scale: s,
        sum_q,
        sum_q2,
        err_l2,
    }
}

/// Widened-integer dot product of two u8 code rows: `Σ aᵢ·bᵢ`, exact.
/// Eight u32 lanes autovectorize; integer addition is associative, so —
/// unlike the f32 kernels — lane layout cannot change the result.
#[inline]
fn dot_u8(a: &[u8], b: &[u8]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    const L: usize = 8;
    let split = a.len() - a.len() % L;
    let mut acc = [0u32; L];
    for (ca, cb) in a[..split].chunks_exact(L).zip(b[..split].chunks_exact(L)) {
        for l in 0..L {
            acc[l] += u32::from(ca[l]) * u32::from(cb[l]);
        }
    }
    let mut total: u64 = acc.iter().map(|&v| u64::from(v)).sum();
    for (&x, &y) in a[split..].iter().zip(b[split..].iter()) {
        total += u64::from(x) * u64::from(y);
    }
    total
}

/// One journaled table mutation (see [`Scrt::enable_journal`]). `time` is
/// the virtual time of the mutation: `mark_reused` stamps its `now`,
/// `insert` stamps the record's own `last_used` — the engines always
/// insert with `last_used == now`, so the two stamps share one clock.
#[derive(Clone, Debug)]
pub enum ScrtOp {
    /// `mark_reused` bumped a record's value key.
    Reused {
        id: RecordId,
        prev_count: u32,
        prev_last_used: f64,
        time: f64,
    },
    /// `insert` added a record, evicting at most one victim. The victim is
    /// retained in full (exchange form + its bucket) so a reconstruction
    /// at an earlier time can still broadcast it.
    Inserted {
        id: RecordId,
        time: f64,
        evicted: Option<(u32, Record)>,
    },
    /// `wipe` cleared the whole table (a crash cold start). Every victim
    /// is retained in full so a reconstruction at a pre-crash time still
    /// sees — and can broadcast — the pre-crash table.
    Wiped {
        victims: Vec<(u32, Record)>,
        time: f64,
    },
}

/// Ascending eviction/broadcast value key: `(N_t, recency, id)`.
type ValueKey = (u32, u64, RecordId);

/// Map an `f64` recency onto a `u64` whose unsigned order equals the
/// IEEE-754 total order (`f64::total_cmp`): NaN can never panic the value
/// index, it simply orders at the extremes (positive NaN above `+inf`,
/// negative NaN below `-inf`).
#[inline]
fn time_key(t: f64) -> u64 {
    let bits = t.to_bits();
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1u64 << 63)
    }
}

#[inline]
fn value_key(reuse_count: u32, last_used: f64, id: RecordId) -> ValueKey {
    (reuse_count, time_key(last_used), id)
}

/// The reuse table of one satellite.
#[derive(Clone, Debug)]
pub struct Scrt {
    buckets: Vec<Bucket>,
    /// Identity index: record id → (bucket, slot). Slots move on
    /// eviction (`swap_remove`), so the index is updated in lock-step.
    index: HashMap<RecordId, (u32, usize)>,
    /// Value index, ascending `(N_t, recency, id)`: the minimum end is
    /// the eviction victim, the maximum end feeds `top_tau`.
    order: BTreeSet<ValueKey>,
    /// Feature stride (pd length), fixed by the first insert. `0` means
    /// "no insert yet" — a record's `pd` is never empty (asserted on
    /// insert), so the sentinel is unambiguous and the hot-path accessors
    /// stay branch-free in release builds.
    dim: usize,
    capacity: usize,
    /// Mutation journal for retroactive reads ([`Scrt::top_tau_at`]);
    /// `None` (the default) records nothing.
    journal: Option<Vec<ScrtOp>>,
    /// Total evictions (observability).
    pub evictions: u64,
}

impl Scrt {
    /// `num_buckets = 2^p_k`; `capacity` in records (`C^stg` / record size).
    pub fn new(num_buckets: usize, capacity: usize) -> Self {
        assert!(num_buckets.is_power_of_two(), "buckets must be 2^p_k");
        assert!(capacity > 0, "capacity must be positive");
        Scrt {
            buckets: vec![Bucket::default(); num_buckets],
            index: HashMap::new(),
            order: BTreeSet::new(),
            dim: 0,
            capacity,
            journal: None,
            evictions: 0,
        }
    }

    /// Start journaling mutations (idempotent). Required by
    /// [`Scrt::top_tau_at`]; the sharded engine enables it per shard and
    /// clears the journal at every conservative-window boundary.
    pub fn enable_journal(&mut self) {
        if self.journal.is_none() {
            self.journal = Some(Vec::new());
        }
    }

    /// Drop the journaled ops (journaling stays enabled). Reads via
    /// [`Scrt::top_tau_at`] only reach back to the last clear.
    pub fn clear_journal(&mut self) {
        if let Some(journal) = &mut self.journal {
            journal.clear();
        }
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Is a record with this identity already cached? O(1).
    pub fn contains(&self, id: RecordId) -> bool {
        self.index.contains_key(&id)
    }

    /// Where a record currently lives, if cached. O(1).
    pub fn location(&self, id: RecordId) -> Option<(u32, usize)> {
        self.index.get(&id).copied()
    }

    /// Exact nearest neighbour (min L2 over `pd`) within a bucket, filtered
    /// by task type. Returns `(bucket_slot, distance²)`.
    ///
    /// On buckets of [`QUANT_MIN_SLOTS`]+ records the search runs the
    /// quantized coarse pass first (see the module docs): a
    /// widened-integer scan over the u8 mirror lower-bounds every
    /// record's distance, records that provably cannot beat the coarse
    /// winner's exact distance are pruned, and only the survivors pay the
    /// exact f32 L2. The result — slot, distance bits, earliest-slot tie
    /// wins — is **identical** to the full scan's, which smaller buckets
    /// (and non-finite probes, and dims past [`MAX_QUANT_DIM`]) still run
    /// verbatim.
    pub fn nearest(
        &self,
        bucket: u32,
        task_type: u16,
        pre: &Preprocessed,
    ) -> Option<(usize, f32)> {
        if self.dim == 0 {
            return None; // nothing inserted yet
        }
        let dim = self.dim;
        debug_assert_eq!(pre.pd.len(), dim, "probe stride mismatch");
        let b = &self.buckets[bucket as usize];
        if b.slots.len() >= QUANT_MIN_SLOTS && dim <= MAX_QUANT_DIM {
            if let Some(result) = Self::nearest_coarse(b, dim, task_type, pre) {
                return result;
            }
        }
        Self::nearest_scan(b, dim, task_type, pre)
    }

    /// The exact full scan: a chunked L2 pass over the bucket's
    /// contiguous SoA feature array in stride-`dim` chunks. This is the
    /// semantic reference the coarse path must reproduce bit for bit.
    fn nearest_scan(
        b: &Bucket,
        dim: usize,
        task_type: u16,
        pre: &Preprocessed,
    ) -> Option<(usize, f32)> {
        let mut best: Option<(usize, f32)> = None;
        for (slot, (s, feat)) in
            b.slots.iter().zip(b.feats.chunks_exact(dim)).enumerate()
        {
            if s.task_type != task_type {
                continue;
            }
            let d = l2_sq(feat, &pre.pd);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((slot, d));
            }
        }
        best
    }

    /// Quantized coarse scan + exact re-rank. Returns `None` when the
    /// probe cannot be coarse-bounded (non-finite features) — the caller
    /// then falls back to [`Scrt::nearest_scan`]; `Some(result)` is the
    /// final answer, bit-identical to the full scan's.
    ///
    /// Why pruning is exact (full argument in `docs/ARCHITECTURE.md`):
    /// for record `r` with true features `f` and probe `p`, the triangle
    /// inequality gives `‖f−p‖ ≥ ‖f̂−p̂‖ − ‖f−f̂‖ − ‖p−p̂‖` over the
    /// *reconstructions* `f̂`/`p̂`. The coarse pass computes `‖f̂−p̂‖²` in
    /// closed form from the integer code statistics (minus an explicit
    /// f64 rounding margin), and both reconstruction errors are measured
    /// bounds stored at quantization time. Deflating the squared result
    /// by `l2_sq`'s worst-case f32 summation factor yields `lb(r)` with
    /// `lb(r) ≤ l2_sq(f, p)` guaranteed. A record with
    /// `lb(r) > U := l2_sq(coarse winner, p)` therefore satisfies
    /// `l2_sq(r) > U ≥ min`, so it is neither the minimum nor a tie for
    /// it — pruning it cannot change the argmin or the earliest-slot tie
    /// rule. Every minimizer survives (its `lb ≤ its l2_sq = min ≤ U`),
    /// and the survivors are re-ranked in ascending slot order with the
    /// unchanged `l2_sq` and strict `<`, exactly as the full scan.
    fn nearest_coarse(
        b: &Bucket,
        dim: usize,
        task_type: u16,
        pre: &Preprocessed,
    ) -> Option<Option<(usize, f32)>> {
        let mut pcodes = Vec::with_capacity(dim);
        let pq = quantize_row(&pre.pd, &mut pcodes);
        if !pq.err_l2.is_finite() {
            return None; // non-finite probe: no usable bound, scan instead
        }
        // Worst-case relative shrink of l2_sq's f32 value vs the exact
        // distance: (dim + 3) roundings at u = 2⁻²⁴ each; doubled.
        let fudge = (2.0 * dim as f64 + 16.0) * (f64::from(f32::EPSILON) * 0.5);
        // Coarse pass: a lower bound per eligible slot, plus the
        // coarse-nearest candidate (earliest slot on equal coarse
        // distance — any eligible candidate keeps pruning correct).
        let mut bounds: Vec<(usize, f64)> = Vec::with_capacity(b.slots.len());
        let mut cand: Option<(usize, f64)> = None;
        for (slot, s) in b.slots.iter().enumerate() {
            if s.task_type != task_type {
                continue;
            }
            let qrow = &b.qcodes[slot * dim..(slot + 1) * dim];
            let m = &b.qmeta[slot];
            if !m.err_l2.is_finite() {
                // A non-finite record can carry a NaN distance, and the
                // full scan's fold is order-sensitive around NaN (the
                // first eligible slot wins unconditionally) — pruning
                // *other* slots could change which slot comes first. Only
                // the verbatim scan reproduces that, so use it.
                return None;
            }
            let dotv = dot_u8(qrow, &pcodes) as f64;
            // ‖f̂−p̂‖² expanded over the code statistics: with
            // c = z_r − z_p the exact algebra is
            //   dim·c² + 2c(s_r·Σq_r − s_p·Σq_p)
            //   + s_r²·Σq_r² + s_p²·Σq_p² − 2·s_r·s_p·Σq_r·q_p.
            let c = m.zero - pq.zero;
            let t1 = dim as f64 * c * c;
            let t2 = 2.0 * c * (m.scale * m.sum_q - pq.scale * pq.sum_q);
            let t3 = m.scale * m.scale * m.sum_q2;
            let t4 = pq.scale * pq.scale * pq.sum_q2;
            let t5 = -2.0 * m.scale * pq.scale * dotv;
            let dhat2 = ((t1 + t2) + (t3 + t4)) + t5;
            let tabs = t1.abs() + t2.abs() + t3.abs() + t4.abs() + t5.abs();
            let lb = (dhat2 - tabs * COARSE_EVAL_EPS).max(0.0).sqrt()
                - m.err_l2
                - pq.err_l2;
            let lb2 = if lb > 0.0 { lb * lb * (1.0 - fudge) } else { 0.0 };
            bounds.push((slot, lb2));
            if cand.map_or(true, |(_, cd)| dhat2 < cd) {
                cand = Some((slot, dhat2));
            }
        }
        let Some((cslot, _)) = cand else {
            return Some(None); // no record of this task type in the bucket
        };
        // Exact distance of the coarse winner upper-bounds the minimum.
        let u = f64::from(l2_sq(
            &b.feats[cslot * dim..(cslot + 1) * dim],
            &pre.pd,
        ));
        // Exact re-rank of the survivors, ascending slot order, the same
        // strict-< comparison as the full scan. (A NaN/∞ `u` disables
        // pruning — `lb2 > u` is then never true — degrading gracefully
        // to the full scan.)
        let mut best: Option<(usize, f32)> = None;
        for &(slot, lb2) in &bounds {
            if lb2 > u {
                continue; // provably cannot beat (or tie) the winner
            }
            let d = l2_sq(&b.feats[slot * dim..(slot + 1) * dim], &pre.pd);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((slot, d));
            }
        }
        Some(best)
    }

    /// Borrow a record view by (bucket, slot).
    ///
    /// **Invariant:** `(bucket, slot)` coordinates only exist in callers'
    /// hands after an insert put a record there ([`Scrt::nearest`],
    /// [`Scrt::location`], [`Scrt::iter`] are the only sources), so the
    /// feature stride is always set by the time a view is taken. Debug
    /// builds assert it; the release hot path stays branch-free (the old
    /// `expect` compiled to a check + panic call on every view).
    pub fn view(&self, bucket: u32, slot: usize) -> RecordView<'_> {
        debug_assert!(self.dim != 0, "viewing a slot implies a prior insert");
        let dim = self.dim;
        let b = &self.buckets[bucket as usize];
        let s = &b.slots[slot];
        RecordView {
            id: s.id,
            task_type: s.task_type,
            result: s.result,
            reuse_count: s.reuse_count,
            last_used: s.last_used,
            origin: s.origin,
            pd: &b.feats[slot * dim..(slot + 1) * dim],
            gray: &s.payload.gray,
            h: s.payload.h,
            w: s.payload.w,
        }
    }

    /// The stored input of a candidate, for the SSIM gate (Alg. 1 line 8).
    ///
    /// The returned [`Preprocessed`] is the record's full shared payload —
    /// grayscale plane, dims, *and* the feature vector (which is also
    /// mirrored in the bucket's SoA array for the NN scan). Both compute
    /// backends gate on the gray plane only, per eq. (12).
    pub fn candidate_pre(&self, bucket: u32, slot: usize) -> &Preprocessed {
        &self.buckets[bucket as usize].slots[slot].payload
    }

    /// Register a successful reuse of a record (Alg. 1 line 11).
    pub fn mark_reused(&mut self, bucket: u32, slot: usize, now: f64) {
        let s = &mut self.buckets[bucket as usize].slots[slot];
        let old = value_key(s.reuse_count, s.last_used, s.id);
        if let Some(journal) = &mut self.journal {
            journal.push(ScrtOp::Reused {
                id: s.id,
                prev_count: s.reuse_count,
                prev_last_used: s.last_used,
                time: now,
            });
        }
        s.reuse_count += 1;
        s.last_used = now;
        let new = value_key(s.reuse_count, s.last_used, s.id);
        let removed = self.order.remove(&old);
        debug_assert!(removed, "value index out of sync");
        self.order.insert(new);
    }

    /// Insert a record into a bucket, evicting the lowest-value record
    /// (min `(reuse_count, last_used, id)` across all buckets, read off
    /// the value index in O(log n)) if full. Returns the evicted record
    /// id, if any. Panics on an id that is already cached — a duplicate
    /// would desync the identity/value indexes, so the contract is
    /// enforced unconditionally ([`Scrt::merge_broadcast`] dedups
    /// broadcasts; the O(1) probe is negligible next to the insert).
    pub fn insert(&mut self, bucket: u32, record: Record) -> Option<RecordId> {
        assert!(!self.contains(record.id), "duplicate record id");
        if self.dim == 0 {
            assert!(!record.pre.pd.is_empty(), "pd must be non-empty");
            self.dim = record.pre.pd.len();
        }
        assert_eq!(record.pre.pd.len(), self.dim, "pd stride mismatch");
        let journaling = self.journal.is_some();
        let mut evicted = None;
        let mut evicted_full = None;
        if self.len() >= self.capacity {
            if let Some((victim, full)) = self.evict_lowest_value(journaling) {
                evicted = Some(victim);
                evicted_full = full;
            }
        }
        let Record {
            id,
            pre,
            task_type,
            result,
            reuse_count,
            last_used,
            origin,
        } = record;
        let b = &mut self.buckets[bucket as usize];
        let slot = b.slots.len();
        // Quantize into the coarse mirror and copy the feature vector into
        // the SoA array; the shared payload itself is stored untouched so
        // `top_tau` can re-broadcast it without allocating.
        let meta = quantize_row(&pre.pd, &mut b.qcodes);
        b.qmeta.push(meta);
        b.feats.extend_from_slice(&pre.pd);
        b.slots.push(Slot {
            id,
            task_type,
            result,
            reuse_count,
            last_used,
            origin,
            payload: pre,
        });
        self.index.insert(id, (bucket, slot));
        self.order.insert(value_key(reuse_count, last_used, id));
        if let Some(journal) = &mut self.journal {
            journal.push(ScrtOp::Inserted {
                id,
                time: last_used,
                evicted: evicted_full,
            });
        }
        evicted
    }

    /// Merge a broadcast record (Sec. IV-A step 4): skip when already
    /// cached (O(1) identity probe); otherwise insert a copy with `N_t`
    /// reset to zero. Returns true if the record was actually inserted.
    ///
    /// Takes the record by reference so the engines can pass the
    /// `Arc`-shared broadcast payload straight through: a duplicate
    /// delivery costs only the identity probe, and even an actual insert
    /// clones no payload — `Record::clone` bumps the shared `Arc`, and the
    /// metadata fields (`N_t` reset, recency) are plain copies.
    pub fn merge_broadcast(&mut self, bucket: u32, record: &Record, now: f64) -> bool {
        if self.contains(record.id) {
            return false;
        }
        let mut owned = record.clone();
        owned.reuse_count = 0;
        owned.last_used = now;
        self.insert(bucket, owned);
        true
    }

    /// The `τ` records with the highest reuse counts (ties broken by
    /// recency, then id), with their bucket ids. Reads the τ maxima
    /// straight off the value index — O(τ + log n) instead of collecting
    /// and fully sorting the table — and each returned [`Record`] shares
    /// the slot's stored payload `Arc`: zero per-record `pd`/`gray`
    /// allocation on the collaboration fan-out path.
    pub fn top_tau(&self, tau: usize) -> Vec<(u32, Record)> {
        self.order
            .iter()
            .rev()
            .take(tau)
            .map(|&(_, _, id)| {
                let (bucket, slot) = self.index[&id];
                (bucket, self.rebuild_record(bucket, slot))
            })
            .collect()
    }

    /// [`Scrt::top_tau`] as it would have answered at an earlier virtual
    /// time `t`, reconstructed from the op journal.
    ///
    /// Ops stamped after `t` are undone against a scratch key map — never
    /// against the live table: reuse bumps restore their previous
    /// `(N_t, recency)`, post-`t` inserts disappear, and their eviction
    /// victims (retained in full by the journal) come back. Payloads of
    /// still-live records are reassembled straight from the SoA storage.
    /// With no journaled op past `t` this degrades to exactly
    /// [`Scrt::top_tau`] (as it does when journaling is disabled).
    ///
    /// This is what lets the sharded engine's conservative windows serve
    /// an Alg. 2 source read at barrier time even when the source shard
    /// has already processed events past the requesting instant.
    pub fn top_tau_at(&self, tau: usize, t: f64) -> Vec<(u32, Record)> {
        let Some(journal) = &self.journal else {
            return self.top_tau(tau);
        };
        // (bucket, reuse_count, last_used) by id, as of "now"...
        let mut keys: HashMap<RecordId, (u32, u32, f64)> = HashMap::with_capacity(self.len());
        for (bucket, v) in self.iter() {
            keys.insert(v.id, (bucket, v.reuse_count, v.last_used));
        }
        // ... then undo everything newer than `t`, newest first.
        let mut stash: HashMap<RecordId, Record> = HashMap::new();
        for op in journal.iter().rev() {
            match op {
                ScrtOp::Reused {
                    id,
                    prev_count,
                    prev_last_used,
                    time,
                } if *time > t => {
                    if let Some(entry) = keys.get_mut(id) {
                        entry.1 = *prev_count;
                        entry.2 = *prev_last_used;
                    }
                }
                ScrtOp::Inserted { id, time, evicted } if *time > t => {
                    keys.remove(id);
                    // A post-`t` insert that was itself evicted later got
                    // stashed by the (already undone) newer eviction —
                    // drop it: the record did not exist at `t`.
                    stash.remove(id);
                    if let Some((victim_bucket, victim)) = evicted {
                        keys.insert(
                            victim.id,
                            (*victim_bucket, victim.reuse_count, victim.last_used),
                        );
                        stash.insert(victim.id, victim.clone());
                    }
                }
                ScrtOp::Wiped { victims, time } if *time > t => {
                    // Undoing a post-`t` crash wipe restores the whole
                    // pre-crash table. Victims that were themselves
                    // inserted after `t` are removed again by their own
                    // (older-than-the-wipe) `Inserted` undo later in this
                    // reverse walk.
                    for (bucket, victim) in victims {
                        keys.insert(
                            victim.id,
                            (*bucket, victim.reuse_count, victim.last_used),
                        );
                        stash.insert(victim.id, victim.clone());
                    }
                }
                _ => {}
            }
        }
        let mut entries: Vec<(RecordId, u32, u32, f64)> = keys
            .into_iter()
            .map(|(id, (bucket, count, last_used))| (id, bucket, count, last_used))
            .collect();
        // Same descending order as the live value index.
        entries
            .sort_by(|a, b| (b.2, time_key(b.3), b.0).cmp(&(a.2, time_key(a.3), a.0)));
        entries.truncate(tau);
        entries
            .into_iter()
            .map(|(id, bucket, count, last_used)| {
                let mut rec = match self.location(id) {
                    Some((b, slot)) => self.rebuild_record(b, slot),
                    None => stash
                        .get(&id)
                        .cloned()
                        .expect("evicted record retained in the journal"),
                };
                rec.reuse_count = count;
                rec.last_used = last_used;
                (bucket, rec)
            })
            .collect()
    }

    /// Remove every record: a crash under the cold-start (wipe) SCRT
    /// policy. Journaled as one [`ScrtOp::Wiped`] op retaining every
    /// victim in full, so retroactive reads ([`Scrt::top_tau_at`]) at a
    /// pre-crash time still reconstruct the pre-crash table — the sharded
    /// engine depends on that when a source shard processes a crash
    /// before a cross-shard Alg. 2 read resolves. Returns the number of
    /// records wiped. The eviction counter is cumulative across reboots
    /// (observability, not reuse state) and the feature stride survives —
    /// the workload's record shape does not change across a crash.
    pub fn wipe(&mut self, now: f64) -> usize {
        let n = self.len();
        if n == 0 {
            return 0;
        }
        if self.journal.is_some() {
            let mut victims = Vec::with_capacity(n);
            for b in 0..self.buckets.len() {
                for slot in 0..self.buckets[b].slots.len() {
                    victims.push((b as u32, self.rebuild_record(b as u32, slot)));
                }
            }
            if let Some(journal) = &mut self.journal {
                journal.push(ScrtOp::Wiped { victims, time: now });
            }
        }
        for b in &mut self.buckets {
            b.slots.clear();
            b.qmeta.clear();
            b.feats.clear();
            b.qcodes.clear();
        }
        self.index.clear();
        self.order.clear();
        n
    }

    /// All records (diagnostics / tests), as borrowed views.
    pub fn iter(&self) -> impl Iterator<Item = (u32, RecordView<'_>)> + '_ {
        self.buckets.iter().enumerate().flat_map(move |(b, bucket)| {
            (0..bucket.slots.len())
                .map(move |slot| (b as u32, self.view(b as u32, slot)))
        })
    }

    /// Reassemble a full exchange-form [`Record`]: metadata is copied from
    /// the slot, the payload is the slot's stored `Arc` shared by refcount
    /// bump — no `pd`/`gray` allocation.
    fn rebuild_record(&self, bucket: u32, slot: usize) -> Record {
        let s = &self.buckets[bucket as usize].slots[slot];
        Record {
            id: s.id,
            pre: Arc::clone(&s.payload),
            task_type: s.task_type,
            result: s.result,
            reuse_count: s.reuse_count,
            last_used: s.last_used,
            origin: s.origin,
        }
    }

    /// Pop the minimum of the value index and remove that record. With
    /// `take_record` the victim is reassembled in exchange form before
    /// removal (journaling needs its full payload); otherwise only the id
    /// survives and nothing is copied.
    fn evict_lowest_value(
        &mut self,
        take_record: bool,
    ) -> Option<(RecordId, Option<(u32, Record)>)> {
        let (_, _, id) = self.order.pop_first()?;
        let (bucket, slot) = self
            .index
            .remove(&id)
            .expect("value index entry is always indexed");
        let taken = if take_record {
            Some((bucket, self.rebuild_record(bucket, slot)))
        } else {
            None
        };
        self.remove_slot(bucket, slot);
        self.evictions += 1;
        Some((id, taken))
    }

    /// `swap_remove` a slot and mirror the swap in the SoA feature array
    /// *and* its quantized mirror, fixing up the identity index of the
    /// record that moved.
    fn remove_slot(&mut self, bucket: u32, slot: usize) {
        debug_assert!(self.dim != 0, "removing a slot implies a prior insert");
        let dim = self.dim;
        let b = &mut self.buckets[bucket as usize];
        let last = b.slots.len() - 1;
        b.slots.swap_remove(slot);
        b.qmeta.swap_remove(slot);
        if slot != last {
            let (head, tail) = b.feats.split_at_mut(last * dim);
            head[slot * dim..(slot + 1) * dim].copy_from_slice(&tail[..dim]);
            let (qhead, qtail) = b.qcodes.split_at_mut(last * dim);
            qhead[slot * dim..(slot + 1) * dim].copy_from_slice(&qtail[..dim]);
            let moved = b.slots[slot].id;
            self.index.insert(moved, (bucket, slot));
        }
        b.feats.truncate(last * dim);
        b.qcodes.truncate(last * dim);
    }
}

#[inline]
fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pre(fill: f32) -> Preprocessed {
        Preprocessed {
            h: 2,
            w: 2,
            pd: vec![fill; 12],
            gray: vec![fill; 4],
        }
    }

    fn rec(id: RecordId, fill: f32, count: u32, t: f64) -> Record {
        Record {
            id,
            pre: Arc::new(pre(fill)),
            task_type: 0,
            result: id as u32,
            reuse_count: count,
            last_used: t,
            origin: 0,
        }
    }

    #[test]
    fn nearest_picks_min_l2() {
        let mut s = Scrt::new(4, 10);
        s.insert(1, rec(0, 0.1, 0, 0.0));
        s.insert(1, rec(1, 0.5, 0, 0.0));
        s.insert(1, rec(2, 0.9, 0, 0.0));
        let (slot, d) = s.nearest(1, 0, &pre(0.55)).unwrap();
        assert_eq!(s.view(1, slot).id, 1);
        assert!(d < 0.1);
        // other bucket is empty
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
    }

    #[test]
    fn nearest_filters_task_type() {
        let mut s = Scrt::new(2, 10);
        let mut r = rec(0, 0.5, 0, 0.0);
        r.task_type = 3;
        s.insert(0, r);
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
        assert!(s.nearest(0, 3, &pre(0.5)).is_some());
    }

    #[test]
    fn capacity_enforced_with_value_eviction() {
        let mut s = Scrt::new(2, 3);
        s.insert(0, rec(0, 0.0, 5, 0.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // lowest count -> victim
        s.insert(1, rec(2, 0.2, 3, 2.0));
        let evicted = s.insert(1, rec(3, 0.3, 0, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(s.len(), 3);
        assert!(!s.contains(1));
        assert!(s.contains(0) && s.contains(2) && s.contains(3));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn eviction_ties_broken_by_recency() {
        let mut s = Scrt::new(1, 2);
        s.insert(0, rec(0, 0.0, 1, 5.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // same count, older -> victim
        let evicted = s.insert(0, rec(2, 0.2, 0, 9.0));
        assert_eq!(evicted, Some(1));
    }

    #[test]
    fn top_tau_orders_by_reuse_count() {
        let mut s = Scrt::new(4, 10);
        s.insert(0, rec(0, 0.0, 2, 0.0));
        s.insert(1, rec(1, 0.1, 7, 1.0));
        s.insert(2, rec(2, 0.2, 4, 2.0));
        let top = s.top_tau(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].1.id, 1);
        assert_eq!(top[1].1.id, 2);
        assert_eq!(top[0].0, 1, "bucket id travels with the record");
        // tau larger than len -> everything
        assert_eq!(s.top_tau(99).len(), 3);
    }

    #[test]
    fn top_tau_rebuilds_full_records() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(7, 0.25, 3, 1.0));
        let top = s.top_tau(1);
        let r = &top[0].1;
        assert_eq!(r.id, 7);
        assert_eq!(r.pre.pd, vec![0.25; 12], "full pd travels with the record");
        assert_eq!(r.pre.gray, vec![0.25; 4]);
        assert_eq!((r.pre.h, r.pre.w), (2, 2));
    }

    #[test]
    fn top_tau_shares_the_stored_payload_arc() {
        // The fan-out path must not allocate per record: the Record handed
        // out by top_tau points at the very payload the insert stored.
        let mut s = Scrt::new(2, 4);
        let payload = Arc::new(pre(0.25));
        s.insert(
            0,
            Record {
                id: 7,
                pre: Arc::clone(&payload),
                task_type: 0,
                result: 7,
                reuse_count: 3,
                last_used: 1.0,
                origin: 0,
            },
        );
        let top = s.top_tau(1);
        assert!(
            Arc::ptr_eq(&top[0].1.pre, &payload),
            "top_tau must share the slot payload, not copy it"
        );
    }

    #[test]
    fn top_tau_and_eviction_are_nan_proof() {
        // The old comparator called partial_cmp().unwrap() on last_used
        // and panicked on NaN; the keyed total order must not.
        let mut s = Scrt::new(2, 3);
        s.insert(0, rec(0, 0.1, 2, f64::NAN));
        s.insert(0, rec(1, 0.2, 2, 1.0));
        s.insert(1, rec(2, 0.3, 0, f64::NAN));
        let top = s.top_tau(3);
        assert_eq!(top.len(), 3);
        // total order: NaN sorts above every finite recency, so on the
        // count tie the NaN record ranks as most recent.
        assert_eq!(top[0].1.id, 0);
        assert_eq!(top[1].1.id, 1);
        assert_eq!(top[2].1.id, 2);
        // eviction keeps working: lowest count wins regardless of NaN
        let evicted = s.insert(1, rec(3, 0.4, 9, 2.0));
        assert_eq!(evicted, Some(2));
    }

    #[test]
    fn merge_broadcast_skips_duplicates_and_resets_count() {
        let mut s = Scrt::new(2, 10);
        s.insert(0, rec(7, 0.5, 3, 0.0));
        assert!(!s.merge_broadcast(0, &rec(7, 0.5, 9, 1.0), 1.0));
        assert!(s.merge_broadcast(1, &rec(8, 0.6, 9, 1.0), 1.0));
        let (_, r) = s.iter().find(|(_, r)| r.id == 8).unwrap();
        assert_eq!(r.reuse_count, 0, "broadcast count must reset (step 4)");
    }

    #[test]
    fn merge_broadcast_dedup_leaves_table_untouched() {
        let mut s = Scrt::new(2, 10);
        s.insert(0, rec(7, 0.5, 3, 0.0));
        // A dedup hit only borrows the broadcast payload: the cached copy
        // keeps its count and recency, and nothing is inserted.
        let dup = rec(7, 0.5, 9, 5.0);
        assert!(!s.merge_broadcast(0, &dup, 5.0));
        assert_eq!(s.len(), 1);
        let (_, r) = s.iter().find(|(_, r)| r.id == 7).unwrap();
        assert_eq!(r.reuse_count, 3);
        assert_eq!(r.last_used, 0.0);
    }

    #[test]
    fn mark_reused_bumps_count_and_recency() {
        let mut s = Scrt::new(1, 4);
        s.insert(0, rec(0, 0.5, 0, 0.0));
        let (slot, _) = s.nearest(0, 0, &pre(0.5)).unwrap();
        s.mark_reused(0, slot, 9.0);
        assert_eq!(s.view(0, slot).reuse_count, 1);
        assert_eq!(s.view(0, slot).last_used, 9.0);
    }

    #[test]
    fn index_tracks_slots_across_evictions() {
        let mut s = Scrt::new(1, 3);
        s.insert(0, rec(0, 0.0, 0, 0.0));
        s.insert(0, rec(1, 0.1, 5, 1.0));
        s.insert(0, rec(2, 0.2, 5, 2.0));
        // id 0 (count 0) is the victim; id 2 swaps into its slot 0
        let evicted = s.insert(0, rec(3, 0.3, 5, 3.0));
        assert_eq!(evicted, Some(0));
        let fills = [0.0f32, 0.1, 0.2, 0.3];
        for id in [1, 2, 3] {
            let (b, slot) = s.location(id).unwrap();
            assert_eq!(s.view(b, slot).id, id, "index stale for id {id}");
            assert_eq!(
                s.view(b, slot).pd,
                &vec![fills[id]; 12][..],
                "SoA features must move with the swapped slot"
            );
        }
        assert_eq!(s.location(0), None);
    }

    #[test]
    fn candidate_pre_carries_the_full_payload() {
        let mut s = Scrt::new(1, 2);
        s.insert(0, rec(4, 0.5, 0, 0.0));
        let p = s.candidate_pre(0, 0);
        assert_eq!(p.pd, vec![0.5; 12], "payload keeps pd (mirrored in SoA)");
        assert_eq!(p.gray, vec![0.5; 4]);
        assert_eq!((p.h, p.w), (2, 2));
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_buckets_rejected() {
        Scrt::new(3, 4);
    }

    #[test]
    #[should_panic]
    fn duplicate_id_insert_rejected() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(1, 0.1, 0, 0.0));
        s.insert(1, rec(1, 0.2, 0, 1.0)); // same id, different bucket
    }

    #[test]
    #[should_panic]
    fn mismatched_stride_rejected() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(0, 0.1, 0, 0.0));
        let mut bad = rec(1, 0.2, 0, 1.0);
        Arc::make_mut(&mut bad.pre).pd = vec![0.2; 9];
        s.insert(1, bad);
    }

    /// Ids of `top_tau_at` output, in order.
    fn top_ids(s: &Scrt, tau: usize, t: f64) -> Vec<RecordId> {
        s.top_tau_at(tau, t).iter().map(|(_, r)| r.id).collect()
    }

    #[test]
    fn top_tau_at_without_newer_ops_equals_top_tau() {
        let mut s = Scrt::new(4, 10);
        s.enable_journal();
        s.insert(0, rec(0, 0.0, 2, 0.0));
        s.insert(1, rec(1, 0.1, 7, 1.0));
        s.insert(2, rec(2, 0.2, 4, 2.0));
        let live: Vec<RecordId> = s.top_tau(3).iter().map(|(_, r)| r.id).collect();
        assert_eq!(top_ids(&s, 3, 10.0), live, "no op past t=10");
        // ... and so does a disabled-journal table at any t.
        let mut plain = Scrt::new(4, 10);
        plain.insert(0, rec(0, 0.0, 2, 0.0));
        plain.insert(1, rec(1, 0.1, 7, 1.0));
        assert_eq!(top_ids(&plain, 2, -1.0), vec![1, 0]);
    }

    #[test]
    fn top_tau_at_undoes_reuse_bumps() {
        let mut s = Scrt::new(2, 10);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 1, 0.0));
        s.insert(0, rec(1, 0.2, 2, 0.0));
        // at t=5 record 1 leads; the reuse bumps at t=6/7 flip the order
        s.mark_reused(0, 0, 6.0);
        s.mark_reused(0, 0, 7.0);
        assert_eq!(top_ids(&s, 2, 10.0), vec![0, 1], "after the bumps");
        assert_eq!(top_ids(&s, 2, 5.0), vec![1, 0], "as of t=5");
        let at5 = s.top_tau_at(2, 5.0);
        assert_eq!(at5[0].1.reuse_count, 2);
        assert_eq!(at5[1].1.reuse_count, 1, "pre-bump count restored");
        assert_eq!(at5[1].1.last_used, 0.0, "pre-bump recency restored");
    }

    #[test]
    fn top_tau_at_resurrects_evicted_victims() {
        let mut s = Scrt::new(1, 2);
        s.enable_journal();
        s.insert(0, rec(0, 0.25, 5, 0.0));
        s.insert(0, rec(1, 0.1, 1, 1.0));
        // t=2: table holds {0, 1}. The insert at t=3 evicts record 1.
        let evicted = s.insert(0, rec(2, 0.2, 3, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(top_ids(&s, 2, 10.0), vec![0, 2]);
        let at2 = s.top_tau_at(2, 2.0);
        assert_eq!(
            at2.iter().map(|(_, r)| r.id).collect::<Vec<_>>(),
            vec![0, 1],
            "the victim must come back as of t=2"
        );
        // the resurrected victim carries its full payload
        assert_eq!(at2[1].1.pre.pd, vec![0.1f32; 12]);
        assert_eq!(at2[1].1.pre.gray, vec![0.1f32; 4]);
        assert_eq!(at2[1].0, 0, "bucket travels with the victim");
    }

    #[test]
    fn top_tau_at_drops_post_t_inserts_even_when_later_evicted() {
        let mut s = Scrt::new(1, 2);
        s.enable_journal();
        s.insert(0, rec(0, 0.3, 9, 0.0));
        // both of these happen after t=1: record 1 arrives, then record 2
        // evicts it — neither may surface in the t=1 reconstruction.
        s.insert(0, rec(1, 0.1, 0, 2.0));
        let evicted = s.insert(0, rec(2, 0.2, 4, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(top_ids(&s, 3, 1.0), vec![0]);
    }

    #[test]
    fn wipe_clears_the_table_and_journals_the_victims() {
        let mut s = Scrt::new(2, 10);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 5, 0.0));
        s.insert(1, rec(1, 0.2, 2, 1.0));
        s.mark_reused(0, 0, 2.0);
        // Crash at t=4: the live table is empty, but the t=3 view must
        // reconstruct the whole pre-crash table (the sharded engine reads
        // source SCRTs retroactively across a crash wipe).
        assert_eq!(s.wipe(4.0), 2);
        assert!(s.is_empty());
        assert!(s.top_tau(3).is_empty());
        let at3 = s.top_tau_at(3, 3.0);
        assert_eq!(
            at3.iter().map(|(_, r)| r.id).collect::<Vec<_>>(),
            vec![0, 1]
        );
        assert_eq!(at3[0].1.reuse_count, 6, "the t=2 bump survives");
        assert_eq!(at3[0].1.pre.pd, vec![0.1f32; 12], "payload retained");
        // A pre-crash read before the bump also undoes the bump through
        // the restored victim.
        let at1 = s.top_tau_at(3, 1.0);
        assert_eq!(at1[0].1.reuse_count, 5);
        // Post-wipe inserts rebuild a cold table; a post-wipe read sees
        // only them.
        s.insert(0, rec(7, 0.3, 0, 5.0));
        assert_eq!(top_ids(&s, 3, 6.0), vec![7]);
        // ... and the t=3 view still excludes the post-crash record.
        assert_eq!(top_ids(&s, 3, 3.0), vec![0, 1]);
        // Wiping an empty table is a no-op (no journal entry).
        let mut empty = Scrt::new(2, 4);
        empty.enable_journal();
        assert_eq!(empty.wipe(1.0), 0);
    }

    #[test]
    fn wipe_then_reinsert_reconstructs_both_epochs() {
        // A record inserted, wiped, then re-merged: the pre-crash view
        // sees the old copy, the post-crash view the new one.
        let mut s = Scrt::new(1, 4);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 3, 0.0));
        s.wipe(2.0);
        s.insert(0, rec(0, 0.1, 0, 4.0));
        assert_eq!(top_ids(&s, 2, 1.0), vec![0]);
        assert_eq!(s.top_tau_at(2, 1.0)[0].1.reuse_count, 3, "old epoch");
        assert_eq!(top_ids(&s, 2, 3.0), Vec::<RecordId>::new(), "mid-crash");
        assert_eq!(s.top_tau_at(2, 5.0)[0].1.reuse_count, 0, "new epoch");
    }

    #[test]
    fn clear_journal_forgets_older_ops() {
        let mut s = Scrt::new(1, 4);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 1, 0.0));
        s.mark_reused(0, 0, 5.0);
        s.clear_journal();
        // Reads now only reach back to the clear: the t=1 view no longer
        // undoes the (forgotten) bump.
        let at1 = s.top_tau_at(1, 1.0);
        assert_eq!(at1[0].1.reuse_count, 2);
    }

    // ---- quantized coarse scan -------------------------------------

    use crate::util::rng::Rng;

    fn rand_pre(rng: &mut Rng, dim: usize) -> Preprocessed {
        Preprocessed {
            h: 2,
            w: 2,
            pd: (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect(),
            gray: vec![0.5; 4],
        }
    }

    fn rand_rec(id: RecordId, rng: &mut Rng, dim: usize) -> Record {
        Record {
            id,
            pre: Arc::new(rand_pre(rng, dim)),
            task_type: (id % 2) as u16,
            result: id as u32,
            reuse_count: 0,
            last_used: id as f64,
            origin: 0,
        }
    }

    /// Assert the public `nearest` (coarse path on populous buckets)
    /// returns bit-identical results to the exact scan for every task
    /// type of a set of probes.
    fn assert_nearest_matches_scan(s: &Scrt, bucket: u32, probes: &[Preprocessed]) {
        let b = &s.buckets[bucket as usize];
        for probe in probes {
            for tt in 0..2u16 {
                let got = s.nearest(bucket, tt, probe);
                let want = Scrt::nearest_scan(b, s.dim, tt, probe);
                match (got, want) {
                    (None, None) => {}
                    (Some((gs, gd)), Some((ws, wd))) => {
                        assert_eq!(gs, ws, "slot diverged (task_type {tt})");
                        assert_eq!(
                            gd.to_bits(),
                            wd.to_bits(),
                            "distance bits diverged (task_type {tt})"
                        );
                    }
                    _ => panic!("presence diverged: {got:?} vs {want:?}"),
                }
            }
        }
    }

    #[test]
    fn quantized_nearest_matches_full_scan_on_random_buckets() {
        let dim = 24;
        let mut rng = Rng::new(41);
        let mut s = Scrt::new(1, 256);
        for id in 0..64 {
            s.insert(0, rand_rec(id, &mut rng, dim));
        }
        assert!(s.buckets[0].slots.len() >= QUANT_MIN_SLOTS);
        let probes: Vec<Preprocessed> =
            (0..32).map(|_| rand_pre(&mut rng, dim)).collect();
        assert_nearest_matches_scan(&s, 0, &probes);
    }

    #[test]
    fn quantized_nearest_ties_keep_earliest_slot() {
        // Many identical features: every distance ties, so the earliest
        // eligible slot must win — on both paths.
        let dim = 24;
        let mut s = Scrt::new(1, 64);
        for id in 0..32 {
            let mut r = rec(id, 0.5, 0, id as f64);
            let p = Arc::make_mut(&mut r.pre);
            p.pd = vec![0.25; dim];
            p.gray = vec![0.25; 4];
            r.task_type = (id % 2) as u16;
            s.insert(0, r);
        }
        let mut probe = pre(0.25);
        probe.pd = vec![0.3; dim];
        let (slot, _) = s.nearest(0, 0, &probe).unwrap();
        assert_eq!(s.view(0, slot).id, 0, "earliest tied slot wins");
        let (slot1, _) = s.nearest(0, 1, &probe).unwrap();
        assert_eq!(s.view(0, slot1).id, 1);
        assert_nearest_matches_scan(&s, 0, &[probe]);
    }

    #[test]
    fn quantized_nearest_handles_near_duplicates() {
        // Records differing by ~1e-7 stress the shortlist bound: the
        // coarse pass cannot separate them, so all must be re-ranked.
        let dim = 24;
        let mut rng = Rng::new(43);
        let mut s = Scrt::new(1, 64);
        for id in 0..32usize {
            let mut r = rand_rec(id, &mut rng, dim);
            r.task_type = 0;
            Arc::make_mut(&mut r.pre).pd = (0..dim)
                .map(|j| 0.5 + (id as f32) * 1e-7 + (j as f32) * 1e-3)
                .collect();
            s.insert(0, r);
        }
        let mut probe = rand_pre(&mut rng, dim);
        probe.pd = (0..dim)
            .map(|j| 0.5 + 1.6e-6 + (j as f32) * 1e-3)
            .collect();
        assert_nearest_matches_scan(&s, 0, std::slice::from_ref(&probe));
    }

    #[test]
    fn quantized_nearest_survives_constant_and_nonfinite_records() {
        let dim = 24;
        let mut rng = Rng::new(44);
        let mut s = Scrt::new(1, 64);
        for id in 0..20 {
            s.insert(0, rand_rec(id, &mut rng, dim));
        }
        // constant record (scale = 0)
        let mut flat = rand_rec(20, &mut rng, dim);
        Arc::make_mut(&mut flat.pre).pd = vec![0.125; dim];
        flat.task_type = 0;
        s.insert(0, flat);
        // non-finite record (err bound = ∞ → always re-ranked)
        let mut weird = rand_rec(21, &mut rng, dim);
        let wp = Arc::make_mut(&mut weird.pre);
        wp.pd[3] = f32::NAN;
        wp.pd[7] = f32::INFINITY;
        weird.task_type = 0;
        s.insert(0, weird);
        let probes: Vec<Preprocessed> =
            (0..8).map(|_| rand_pre(&mut rng, dim)).collect();
        assert_nearest_matches_scan(&s, 0, &probes);
        // non-finite probe falls back to the scan — same result shape
        let mut bad_probe = rand_pre(&mut rng, dim);
        bad_probe.pd[0] = f32::NEG_INFINITY;
        assert_nearest_matches_scan(&s, 0, &[bad_probe]);
    }

    #[test]
    fn quant_mirror_stays_in_sync_across_evictions() {
        let dim = 24;
        let mut rng = Rng::new(45);
        let mut s = Scrt::new(2, 24);
        // overfill so evictions exercise the swap_remove mirror fixup
        for id in 0..48 {
            s.insert((id % 2) as u32, rand_rec(id, &mut rng, dim));
        }
        assert!(s.evictions >= 24);
        for b in &s.buckets {
            assert_eq!(b.qcodes.len(), b.slots.len() * dim);
            assert_eq!(b.qmeta.len(), b.slots.len());
            // every stored code row must equal a fresh quantization of
            // the feature row it mirrors
            for slot in 0..b.slots.len() {
                let mut fresh = Vec::new();
                let m = quantize_row(&b.feats[slot * dim..(slot + 1) * dim], &mut fresh);
                assert_eq!(
                    &b.qcodes[slot * dim..(slot + 1) * dim],
                    &fresh[..],
                    "stale code row at slot {slot}"
                );
                assert_eq!(m.err_l2.to_bits(), b.qmeta[slot].err_l2.to_bits());
            }
        }
        let probes: Vec<Preprocessed> =
            (0..8).map(|_| rand_pre(&mut rng, dim)).collect();
        assert_nearest_matches_scan(&s, 0, &probes);
        assert_nearest_matches_scan(&s, 1, &probes);
    }
}
