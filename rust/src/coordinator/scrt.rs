//! Satellite Computation Reuse Table (SCRT).
//!
//! Caches reuse records `⟨D_t, P_t, R_t, N_t⟩` (Sec. III-A), organised as a
//! hyperplane-LSH table (`p_l = 1` table, `2^p_k` buckets). The capacity
//! `C^stg` is enforced in records (every record carries the same 20.5 MB
//! payload); when full, the record with the lowest `(N_t, recency)` value is
//! evicted — reuse *value*, then LRU, mirroring how the paper reasons about
//! high-value records.
//!
//! ## Indexed hot path
//!
//! Every per-task operation is backed by a maintained index instead of a
//! whole-table scan (the paper's gains depend on lookups staying far
//! cheaper than recomputation, so the table is a first-class data
//! structure, not a scan):
//!
//! * **identity** — an id → `(bucket, slot)` map makes [`Scrt::contains`]
//!   and the broadcast-merge dedup (Sec. IV-A step 4) O(1);
//! * **value order** — an ordered index over ascending
//!   `(N_t, last_used, id)` keys serves both ends of the value spectrum:
//!   eviction pops the minimum in O(log n) and [`Scrt::top_tau`] reads the
//!   τ maxima in O(τ + log n), replacing the old full-table victim scan
//!   and full sort. `last_used` is keyed through the IEEE-754 total order
//!   (`f64::total_cmp` semantics), so a NaN recency can never panic the
//!   comparator; ties break on the record id, deterministically;
//! * **features** — each bucket stores its feature vectors
//!   structure-of-arrays style in one contiguous `Vec<f32>` (stride = pd
//!   length), so the exact nearest-neighbour scan in [`Scrt::nearest`] is
//!   a cache-friendly chunked L2 pass (what FALCONN does after hashing)
//!   instead of a pointer chase through per-record heap allocations.
//!
//! The expensive SSIM gate (eq. 12) then runs on the single best
//! candidate, via the compute backend — exactly Alg. 1 lines 2 & 8.
//!
//! ## Op journal (sharded engine support)
//!
//! With [`Scrt::enable_journal`] the table records every mutation as a
//! [`ScrtOp`] — including the full payload of eviction victims — so
//! [`Scrt::top_tau_at`] can answer "what would `top_tau` have returned at
//! an earlier virtual time `t`?" without cloning or rolling back the live
//! table. The sharded engine needs exactly that: a conservative window may
//! process a satellite past the instant another shard's Alg. 2 request
//! reads its SCRT, and the journal makes that read exact. Journaling is
//! off by default and costs the single-threaded hot path nothing beyond
//! one `Option` check per mutation.

use std::collections::{BTreeSet, HashMap};

use crate::compute::Preprocessed;
use crate::workload::SatId;

/// Globally unique record identity: the task that created it. Broadcast
/// copies keep the id so "already cached" (Sec. IV-A step 4) is decidable.
pub type RecordId = usize;

/// One reuse record in exchange form — what callers insert and what
/// broadcasts carry. Inside the table the fields are split across the
/// bucket's SoA feature array and the per-slot metadata; [`Scrt::top_tau`]
/// reassembles full records for the wire.
#[derive(Clone, Debug)]
pub struct Record {
    pub id: RecordId,
    /// Pre-processed input (`D_t` after Alg. 1 line 1) — both the feature
    /// vector for NN search and the grayscale plane for SSIM.
    pub pre: Preprocessed,
    /// Task type `P_t`.
    pub task_type: u16,
    /// Cached result `R_t` (the class label).
    pub result: u32,
    /// Reuse count `N_t`.
    pub reuse_count: u32,
    /// Virtual time of creation/last reuse (eviction recency).
    pub last_used: f64,
    /// Satellite that computed the original result (diagnostics).
    pub origin: SatId,
}

/// Borrowed view of one cached record, reassembled by reference from the
/// table's SoA storage. This is the read API for callers that previously
/// borrowed a whole `&Record`.
#[derive(Clone, Copy, Debug)]
pub struct RecordView<'a> {
    pub id: RecordId,
    pub task_type: u16,
    pub result: u32,
    pub reuse_count: u32,
    pub last_used: f64,
    pub origin: SatId,
    /// Feature vector `PD_t`, borrowed from the bucket's SoA array.
    pub pd: &'a [f32],
    /// Grayscale plane for the SSIM gate.
    pub gray: &'a [f32],
    pub h: usize,
    pub w: usize,
}

/// Per-slot metadata. The feature vector deliberately does *not* live
/// here: it sits in the owning bucket's contiguous `feats` array so the
/// NN scan never chases per-record heap pointers.
#[derive(Clone, Debug)]
struct Slot {
    id: RecordId,
    task_type: u16,
    result: u32,
    reuse_count: u32,
    last_used: f64,
    origin: SatId,
    /// Stored input with `pd` intentionally empty (it was moved into the
    /// bucket's `feats`); `h`/`w`/`gray` remain — exactly what the SSIM
    /// gate consumes via [`Scrt::candidate_pre`].
    gray_pre: Preprocessed,
}

/// One LSH bucket: SoA feature storage plus parallel slot metadata.
/// Slot `i`'s feature vector occupies `feats[i * dim .. (i + 1) * dim]`.
#[derive(Clone, Debug, Default)]
struct Bucket {
    feats: Vec<f32>,
    slots: Vec<Slot>,
}

/// One journaled table mutation (see [`Scrt::enable_journal`]). `time` is
/// the virtual time of the mutation: `mark_reused` stamps its `now`,
/// `insert` stamps the record's own `last_used` — the engines always
/// insert with `last_used == now`, so the two stamps share one clock.
#[derive(Clone, Debug)]
pub enum ScrtOp {
    /// `mark_reused` bumped a record's value key.
    Reused {
        id: RecordId,
        prev_count: u32,
        prev_last_used: f64,
        time: f64,
    },
    /// `insert` added a record, evicting at most one victim. The victim is
    /// retained in full (exchange form + its bucket) so a reconstruction
    /// at an earlier time can still broadcast it.
    Inserted {
        id: RecordId,
        time: f64,
        evicted: Option<(u32, Record)>,
    },
}

/// Ascending eviction/broadcast value key: `(N_t, recency, id)`.
type ValueKey = (u32, u64, RecordId);

/// Map an `f64` recency onto a `u64` whose unsigned order equals the
/// IEEE-754 total order (`f64::total_cmp`): NaN can never panic the value
/// index, it simply orders at the extremes (positive NaN above `+inf`,
/// negative NaN below `-inf`).
#[inline]
fn time_key(t: f64) -> u64 {
    let bits = t.to_bits();
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1u64 << 63)
    }
}

#[inline]
fn value_key(reuse_count: u32, last_used: f64, id: RecordId) -> ValueKey {
    (reuse_count, time_key(last_used), id)
}

/// The reuse table of one satellite.
#[derive(Clone, Debug)]
pub struct Scrt {
    buckets: Vec<Bucket>,
    /// Identity index: record id → (bucket, slot). Slots move on
    /// eviction (`swap_remove`), so the index is updated in lock-step.
    index: HashMap<RecordId, (u32, usize)>,
    /// Value index, ascending `(N_t, recency, id)`: the minimum end is
    /// the eviction victim, the maximum end feeds `top_tau`.
    order: BTreeSet<ValueKey>,
    /// Feature stride (pd length), fixed by the first insert. `0` means
    /// "no insert yet" — a record's `pd` is never empty (asserted on
    /// insert), so the sentinel is unambiguous and the hot-path accessors
    /// stay branch-free in release builds.
    dim: usize,
    capacity: usize,
    /// Mutation journal for retroactive reads ([`Scrt::top_tau_at`]);
    /// `None` (the default) records nothing.
    journal: Option<Vec<ScrtOp>>,
    /// Total evictions (observability).
    pub evictions: u64,
}

impl Scrt {
    /// `num_buckets = 2^p_k`; `capacity` in records (`C^stg` / record size).
    pub fn new(num_buckets: usize, capacity: usize) -> Self {
        assert!(num_buckets.is_power_of_two(), "buckets must be 2^p_k");
        assert!(capacity > 0, "capacity must be positive");
        Scrt {
            buckets: vec![Bucket::default(); num_buckets],
            index: HashMap::new(),
            order: BTreeSet::new(),
            dim: 0,
            capacity,
            journal: None,
            evictions: 0,
        }
    }

    /// Start journaling mutations (idempotent). Required by
    /// [`Scrt::top_tau_at`]; the sharded engine enables it per shard and
    /// clears the journal at every conservative-window boundary.
    pub fn enable_journal(&mut self) {
        if self.journal.is_none() {
            self.journal = Some(Vec::new());
        }
    }

    /// Drop the journaled ops (journaling stays enabled). Reads via
    /// [`Scrt::top_tau_at`] only reach back to the last clear.
    pub fn clear_journal(&mut self) {
        if let Some(journal) = &mut self.journal {
            journal.clear();
        }
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Is a record with this identity already cached? O(1).
    pub fn contains(&self, id: RecordId) -> bool {
        self.index.contains_key(&id)
    }

    /// Where a record currently lives, if cached. O(1).
    pub fn location(&self, id: RecordId) -> Option<(u32, usize)> {
        self.index.get(&id).copied()
    }

    /// Exact nearest neighbour (min L2 over `pd`) within a bucket, filtered
    /// by task type. Returns `(bucket_slot, distance²)`. The scan walks the
    /// bucket's contiguous SoA feature array in stride-`dim` chunks.
    pub fn nearest(
        &self,
        bucket: u32,
        task_type: u16,
        pre: &Preprocessed,
    ) -> Option<(usize, f32)> {
        if self.dim == 0 {
            return None; // nothing inserted yet
        }
        let dim = self.dim;
        debug_assert_eq!(pre.pd.len(), dim, "probe stride mismatch");
        let b = &self.buckets[bucket as usize];
        let mut best: Option<(usize, f32)> = None;
        for (slot, (s, feat)) in
            b.slots.iter().zip(b.feats.chunks_exact(dim)).enumerate()
        {
            if s.task_type != task_type {
                continue;
            }
            let d = l2_sq(feat, &pre.pd);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((slot, d));
            }
        }
        best
    }

    /// Borrow a record view by (bucket, slot).
    ///
    /// **Invariant:** `(bucket, slot)` coordinates only exist in callers'
    /// hands after an insert put a record there ([`Scrt::nearest`],
    /// [`Scrt::location`], [`Scrt::iter`] are the only sources), so the
    /// feature stride is always set by the time a view is taken. Debug
    /// builds assert it; the release hot path stays branch-free (the old
    /// `expect` compiled to a check + panic call on every view).
    pub fn view(&self, bucket: u32, slot: usize) -> RecordView<'_> {
        debug_assert!(self.dim != 0, "viewing a slot implies a prior insert");
        let dim = self.dim;
        let b = &self.buckets[bucket as usize];
        let s = &b.slots[slot];
        RecordView {
            id: s.id,
            task_type: s.task_type,
            result: s.result,
            reuse_count: s.reuse_count,
            last_used: s.last_used,
            origin: s.origin,
            pd: &b.feats[slot * dim..(slot + 1) * dim],
            gray: &s.gray_pre.gray,
            h: s.gray_pre.h,
            w: s.gray_pre.w,
        }
    }

    /// The stored input of a candidate, for the SSIM gate (Alg. 1 line 8).
    ///
    /// The returned [`Preprocessed`] carries the grayscale plane and dims;
    /// its `pd` is **empty** — the feature vector lives in the bucket's SoA
    /// array (borrow it via [`Scrt::view`] when needed). Both compute
    /// backends gate on the gray plane only, per eq. (12).
    pub fn candidate_pre(&self, bucket: u32, slot: usize) -> &Preprocessed {
        &self.buckets[bucket as usize].slots[slot].gray_pre
    }

    /// Register a successful reuse of a record (Alg. 1 line 11).
    pub fn mark_reused(&mut self, bucket: u32, slot: usize, now: f64) {
        let s = &mut self.buckets[bucket as usize].slots[slot];
        let old = value_key(s.reuse_count, s.last_used, s.id);
        if let Some(journal) = &mut self.journal {
            journal.push(ScrtOp::Reused {
                id: s.id,
                prev_count: s.reuse_count,
                prev_last_used: s.last_used,
                time: now,
            });
        }
        s.reuse_count += 1;
        s.last_used = now;
        let new = value_key(s.reuse_count, s.last_used, s.id);
        let removed = self.order.remove(&old);
        debug_assert!(removed, "value index out of sync");
        self.order.insert(new);
    }

    /// Insert a record into a bucket, evicting the lowest-value record
    /// (min `(reuse_count, last_used, id)` across all buckets, read off
    /// the value index in O(log n)) if full. Returns the evicted record
    /// id, if any. Panics on an id that is already cached — a duplicate
    /// would desync the identity/value indexes, so the contract is
    /// enforced unconditionally ([`Scrt::merge_broadcast`] dedups
    /// broadcasts; the O(1) probe is negligible next to the insert).
    pub fn insert(&mut self, bucket: u32, record: Record) -> Option<RecordId> {
        assert!(!self.contains(record.id), "duplicate record id");
        if self.dim == 0 {
            assert!(!record.pre.pd.is_empty(), "pd must be non-empty");
            self.dim = record.pre.pd.len();
        }
        assert_eq!(record.pre.pd.len(), self.dim, "pd stride mismatch");
        let journaling = self.journal.is_some();
        let mut evicted = None;
        let mut evicted_full = None;
        if self.len() >= self.capacity {
            if let Some((victim, full)) = self.evict_lowest_value(journaling) {
                evicted = Some(victim);
                evicted_full = full;
            }
        }
        let Record {
            id,
            mut pre,
            task_type,
            result,
            reuse_count,
            last_used,
            origin,
        } = record;
        let b = &mut self.buckets[bucket as usize];
        let slot = b.slots.len();
        // Move the feature vector into the SoA array; `pre` keeps only
        // the grayscale plane for the SSIM gate.
        b.feats.append(&mut pre.pd);
        b.slots.push(Slot {
            id,
            task_type,
            result,
            reuse_count,
            last_used,
            origin,
            gray_pre: pre,
        });
        self.index.insert(id, (bucket, slot));
        self.order.insert(value_key(reuse_count, last_used, id));
        if let Some(journal) = &mut self.journal {
            journal.push(ScrtOp::Inserted {
                id,
                time: last_used,
                evicted: evicted_full,
            });
        }
        evicted
    }

    /// Merge a broadcast record (Sec. IV-A step 4): skip when already
    /// cached (O(1) identity probe); otherwise insert a copy with `N_t`
    /// reset to zero. Returns true if the record was actually inserted.
    ///
    /// Takes the record by reference so the engines can pass the
    /// `Arc`-shared broadcast payload straight through: a duplicate
    /// delivery costs only the identity probe — the pd + gray planes are
    /// cloned *only* past the dedup, on actual insert. (Before this, every
    /// duplicate delivery in a flood paid a full payload allocation just
    /// to discard it.)
    pub fn merge_broadcast(&mut self, bucket: u32, record: &Record, now: f64) -> bool {
        if self.contains(record.id) {
            return false;
        }
        let mut owned = record.clone();
        owned.reuse_count = 0;
        owned.last_used = now;
        self.insert(bucket, owned);
        true
    }

    /// The `τ` records with the highest reuse counts (ties broken by
    /// recency, then id), cloned for broadcast with their bucket ids.
    /// Reads the τ maxima straight off the value index — O(τ + log n)
    /// instead of collecting and fully sorting the table.
    pub fn top_tau(&self, tau: usize) -> Vec<(u32, Record)> {
        self.order
            .iter()
            .rev()
            .take(tau)
            .map(|&(_, _, id)| {
                let (bucket, slot) = self.index[&id];
                (bucket, self.rebuild_record(bucket, slot))
            })
            .collect()
    }

    /// [`Scrt::top_tau`] as it would have answered at an earlier virtual
    /// time `t`, reconstructed from the op journal.
    ///
    /// Ops stamped after `t` are undone against a scratch key map — never
    /// against the live table: reuse bumps restore their previous
    /// `(N_t, recency)`, post-`t` inserts disappear, and their eviction
    /// victims (retained in full by the journal) come back. Payloads of
    /// still-live records are reassembled straight from the SoA storage.
    /// With no journaled op past `t` this degrades to exactly
    /// [`Scrt::top_tau`] (as it does when journaling is disabled).
    ///
    /// This is what lets the sharded engine's conservative windows serve
    /// an Alg. 2 source read at barrier time even when the source shard
    /// has already processed events past the requesting instant.
    pub fn top_tau_at(&self, tau: usize, t: f64) -> Vec<(u32, Record)> {
        let Some(journal) = &self.journal else {
            return self.top_tau(tau);
        };
        // (bucket, reuse_count, last_used) by id, as of "now"...
        let mut keys: HashMap<RecordId, (u32, u32, f64)> = HashMap::with_capacity(self.len());
        for (bucket, v) in self.iter() {
            keys.insert(v.id, (bucket, v.reuse_count, v.last_used));
        }
        // ... then undo everything newer than `t`, newest first.
        let mut stash: HashMap<RecordId, Record> = HashMap::new();
        for op in journal.iter().rev() {
            match op {
                ScrtOp::Reused {
                    id,
                    prev_count,
                    prev_last_used,
                    time,
                } if *time > t => {
                    if let Some(entry) = keys.get_mut(id) {
                        entry.1 = *prev_count;
                        entry.2 = *prev_last_used;
                    }
                }
                ScrtOp::Inserted { id, time, evicted } if *time > t => {
                    keys.remove(id);
                    // A post-`t` insert that was itself evicted later got
                    // stashed by the (already undone) newer eviction —
                    // drop it: the record did not exist at `t`.
                    stash.remove(id);
                    if let Some((victim_bucket, victim)) = evicted {
                        keys.insert(
                            victim.id,
                            (*victim_bucket, victim.reuse_count, victim.last_used),
                        );
                        stash.insert(victim.id, victim.clone());
                    }
                }
                _ => {}
            }
        }
        let mut entries: Vec<(RecordId, u32, u32, f64)> = keys
            .into_iter()
            .map(|(id, (bucket, count, last_used))| (id, bucket, count, last_used))
            .collect();
        // Same descending order as the live value index.
        entries
            .sort_by(|a, b| (b.2, time_key(b.3), b.0).cmp(&(a.2, time_key(a.3), a.0)));
        entries.truncate(tau);
        entries
            .into_iter()
            .map(|(id, bucket, count, last_used)| {
                let mut rec = match self.location(id) {
                    Some((b, slot)) => self.rebuild_record(b, slot),
                    None => stash
                        .get(&id)
                        .cloned()
                        .expect("evicted record retained in the journal"),
                };
                rec.reuse_count = count;
                rec.last_used = last_used;
                (bucket, rec)
            })
            .collect()
    }

    /// All records (diagnostics / tests), as borrowed views.
    pub fn iter(&self) -> impl Iterator<Item = (u32, RecordView<'_>)> + '_ {
        self.buckets.iter().enumerate().flat_map(move |(b, bucket)| {
            (0..bucket.slots.len())
                .map(move |slot| (b as u32, self.view(b as u32, slot)))
        })
    }

    /// Reassemble a full exchange-form [`Record`] (pd copied back out of
    /// the SoA array) — broadcast payloads travel by value.
    fn rebuild_record(&self, bucket: u32, slot: usize) -> Record {
        let v = self.view(bucket, slot);
        Record {
            id: v.id,
            pre: Preprocessed {
                h: v.h,
                w: v.w,
                pd: v.pd.to_vec(),
                gray: v.gray.to_vec(),
            },
            task_type: v.task_type,
            result: v.result,
            reuse_count: v.reuse_count,
            last_used: v.last_used,
            origin: v.origin,
        }
    }

    /// Pop the minimum of the value index and remove that record. With
    /// `take_record` the victim is reassembled in exchange form before
    /// removal (journaling needs its full payload); otherwise only the id
    /// survives and nothing is copied.
    fn evict_lowest_value(
        &mut self,
        take_record: bool,
    ) -> Option<(RecordId, Option<(u32, Record)>)> {
        let (_, _, id) = self.order.pop_first()?;
        let (bucket, slot) = self
            .index
            .remove(&id)
            .expect("value index entry is always indexed");
        let taken = if take_record {
            Some((bucket, self.rebuild_record(bucket, slot)))
        } else {
            None
        };
        self.remove_slot(bucket, slot);
        self.evictions += 1;
        Some((id, taken))
    }

    /// `swap_remove` a slot and mirror the swap in the SoA feature array,
    /// fixing up the identity index of the record that moved.
    fn remove_slot(&mut self, bucket: u32, slot: usize) {
        debug_assert!(self.dim != 0, "removing a slot implies a prior insert");
        let dim = self.dim;
        let b = &mut self.buckets[bucket as usize];
        let last = b.slots.len() - 1;
        b.slots.swap_remove(slot);
        if slot != last {
            let (head, tail) = b.feats.split_at_mut(last * dim);
            head[slot * dim..(slot + 1) * dim].copy_from_slice(&tail[..dim]);
            let moved = b.slots[slot].id;
            self.index.insert(moved, (bucket, slot));
        }
        b.feats.truncate(last * dim);
    }
}

#[inline]
fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pre(fill: f32) -> Preprocessed {
        Preprocessed {
            h: 2,
            w: 2,
            pd: vec![fill; 12],
            gray: vec![fill; 4],
        }
    }

    fn rec(id: RecordId, fill: f32, count: u32, t: f64) -> Record {
        Record {
            id,
            pre: pre(fill),
            task_type: 0,
            result: id as u32,
            reuse_count: count,
            last_used: t,
            origin: 0,
        }
    }

    #[test]
    fn nearest_picks_min_l2() {
        let mut s = Scrt::new(4, 10);
        s.insert(1, rec(0, 0.1, 0, 0.0));
        s.insert(1, rec(1, 0.5, 0, 0.0));
        s.insert(1, rec(2, 0.9, 0, 0.0));
        let (slot, d) = s.nearest(1, 0, &pre(0.55)).unwrap();
        assert_eq!(s.view(1, slot).id, 1);
        assert!(d < 0.1);
        // other bucket is empty
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
    }

    #[test]
    fn nearest_filters_task_type() {
        let mut s = Scrt::new(2, 10);
        let mut r = rec(0, 0.5, 0, 0.0);
        r.task_type = 3;
        s.insert(0, r);
        assert!(s.nearest(0, 0, &pre(0.5)).is_none());
        assert!(s.nearest(0, 3, &pre(0.5)).is_some());
    }

    #[test]
    fn capacity_enforced_with_value_eviction() {
        let mut s = Scrt::new(2, 3);
        s.insert(0, rec(0, 0.0, 5, 0.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // lowest count -> victim
        s.insert(1, rec(2, 0.2, 3, 2.0));
        let evicted = s.insert(1, rec(3, 0.3, 0, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(s.len(), 3);
        assert!(!s.contains(1));
        assert!(s.contains(0) && s.contains(2) && s.contains(3));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn eviction_ties_broken_by_recency() {
        let mut s = Scrt::new(1, 2);
        s.insert(0, rec(0, 0.0, 1, 5.0));
        s.insert(0, rec(1, 0.1, 1, 1.0)); // same count, older -> victim
        let evicted = s.insert(0, rec(2, 0.2, 0, 9.0));
        assert_eq!(evicted, Some(1));
    }

    #[test]
    fn top_tau_orders_by_reuse_count() {
        let mut s = Scrt::new(4, 10);
        s.insert(0, rec(0, 0.0, 2, 0.0));
        s.insert(1, rec(1, 0.1, 7, 1.0));
        s.insert(2, rec(2, 0.2, 4, 2.0));
        let top = s.top_tau(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].1.id, 1);
        assert_eq!(top[1].1.id, 2);
        assert_eq!(top[0].0, 1, "bucket id travels with the record");
        // tau larger than len -> everything
        assert_eq!(s.top_tau(99).len(), 3);
    }

    #[test]
    fn top_tau_rebuilds_full_records() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(7, 0.25, 3, 1.0));
        let top = s.top_tau(1);
        let r = &top[0].1;
        assert_eq!(r.id, 7);
        assert_eq!(r.pre.pd, vec![0.25; 12], "pd restored from SoA storage");
        assert_eq!(r.pre.gray, vec![0.25; 4]);
        assert_eq!((r.pre.h, r.pre.w), (2, 2));
    }

    #[test]
    fn top_tau_and_eviction_are_nan_proof() {
        // The old comparator called partial_cmp().unwrap() on last_used
        // and panicked on NaN; the keyed total order must not.
        let mut s = Scrt::new(2, 3);
        s.insert(0, rec(0, 0.1, 2, f64::NAN));
        s.insert(0, rec(1, 0.2, 2, 1.0));
        s.insert(1, rec(2, 0.3, 0, f64::NAN));
        let top = s.top_tau(3);
        assert_eq!(top.len(), 3);
        // total order: NaN sorts above every finite recency, so on the
        // count tie the NaN record ranks as most recent.
        assert_eq!(top[0].1.id, 0);
        assert_eq!(top[1].1.id, 1);
        assert_eq!(top[2].1.id, 2);
        // eviction keeps working: lowest count wins regardless of NaN
        let evicted = s.insert(1, rec(3, 0.4, 9, 2.0));
        assert_eq!(evicted, Some(2));
    }

    #[test]
    fn merge_broadcast_skips_duplicates_and_resets_count() {
        let mut s = Scrt::new(2, 10);
        s.insert(0, rec(7, 0.5, 3, 0.0));
        assert!(!s.merge_broadcast(0, &rec(7, 0.5, 9, 1.0), 1.0));
        assert!(s.merge_broadcast(1, &rec(8, 0.6, 9, 1.0), 1.0));
        let (_, r) = s.iter().find(|(_, r)| r.id == 8).unwrap();
        assert_eq!(r.reuse_count, 0, "broadcast count must reset (step 4)");
    }

    #[test]
    fn merge_broadcast_dedup_leaves_table_untouched() {
        let mut s = Scrt::new(2, 10);
        s.insert(0, rec(7, 0.5, 3, 0.0));
        // A dedup hit only borrows the broadcast payload: the cached copy
        // keeps its count and recency, and nothing is inserted.
        let dup = rec(7, 0.5, 9, 5.0);
        assert!(!s.merge_broadcast(0, &dup, 5.0));
        assert_eq!(s.len(), 1);
        let (_, r) = s.iter().find(|(_, r)| r.id == 7).unwrap();
        assert_eq!(r.reuse_count, 3);
        assert_eq!(r.last_used, 0.0);
    }

    #[test]
    fn mark_reused_bumps_count_and_recency() {
        let mut s = Scrt::new(1, 4);
        s.insert(0, rec(0, 0.5, 0, 0.0));
        let (slot, _) = s.nearest(0, 0, &pre(0.5)).unwrap();
        s.mark_reused(0, slot, 9.0);
        assert_eq!(s.view(0, slot).reuse_count, 1);
        assert_eq!(s.view(0, slot).last_used, 9.0);
    }

    #[test]
    fn index_tracks_slots_across_evictions() {
        let mut s = Scrt::new(1, 3);
        s.insert(0, rec(0, 0.0, 0, 0.0));
        s.insert(0, rec(1, 0.1, 5, 1.0));
        s.insert(0, rec(2, 0.2, 5, 2.0));
        // id 0 (count 0) is the victim; id 2 swaps into its slot 0
        let evicted = s.insert(0, rec(3, 0.3, 5, 3.0));
        assert_eq!(evicted, Some(0));
        let fills = [0.0f32, 0.1, 0.2, 0.3];
        for id in [1, 2, 3] {
            let (b, slot) = s.location(id).unwrap();
            assert_eq!(s.view(b, slot).id, id, "index stale for id {id}");
            assert_eq!(
                s.view(b, slot).pd,
                &vec![fills[id]; 12][..],
                "SoA features must move with the swapped slot"
            );
        }
        assert_eq!(s.location(0), None);
    }

    #[test]
    fn candidate_pre_keeps_gray_plane_only() {
        let mut s = Scrt::new(1, 2);
        s.insert(0, rec(4, 0.5, 0, 0.0));
        let p = s.candidate_pre(0, 0);
        assert!(p.pd.is_empty(), "pd lives in the SoA array");
        assert_eq!(p.gray, vec![0.5; 4]);
        assert_eq!((p.h, p.w), (2, 2));
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_buckets_rejected() {
        Scrt::new(3, 4);
    }

    #[test]
    #[should_panic]
    fn duplicate_id_insert_rejected() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(1, 0.1, 0, 0.0));
        s.insert(1, rec(1, 0.2, 0, 1.0)); // same id, different bucket
    }

    #[test]
    #[should_panic]
    fn mismatched_stride_rejected() {
        let mut s = Scrt::new(2, 4);
        s.insert(0, rec(0, 0.1, 0, 0.0));
        let mut bad = rec(1, 0.2, 0, 1.0);
        bad.pre.pd = vec![0.2; 9];
        s.insert(1, bad);
    }

    /// Ids of `top_tau_at` output, in order.
    fn top_ids(s: &Scrt, tau: usize, t: f64) -> Vec<RecordId> {
        s.top_tau_at(tau, t).iter().map(|(_, r)| r.id).collect()
    }

    #[test]
    fn top_tau_at_without_newer_ops_equals_top_tau() {
        let mut s = Scrt::new(4, 10);
        s.enable_journal();
        s.insert(0, rec(0, 0.0, 2, 0.0));
        s.insert(1, rec(1, 0.1, 7, 1.0));
        s.insert(2, rec(2, 0.2, 4, 2.0));
        let live: Vec<RecordId> = s.top_tau(3).iter().map(|(_, r)| r.id).collect();
        assert_eq!(top_ids(&s, 3, 10.0), live, "no op past t=10");
        // ... and so does a disabled-journal table at any t.
        let mut plain = Scrt::new(4, 10);
        plain.insert(0, rec(0, 0.0, 2, 0.0));
        plain.insert(1, rec(1, 0.1, 7, 1.0));
        assert_eq!(top_ids(&plain, 2, -1.0), vec![1, 0]);
    }

    #[test]
    fn top_tau_at_undoes_reuse_bumps() {
        let mut s = Scrt::new(2, 10);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 1, 0.0));
        s.insert(0, rec(1, 0.2, 2, 0.0));
        // at t=5 record 1 leads; the reuse bumps at t=6/7 flip the order
        s.mark_reused(0, 0, 6.0);
        s.mark_reused(0, 0, 7.0);
        assert_eq!(top_ids(&s, 2, 10.0), vec![0, 1], "after the bumps");
        assert_eq!(top_ids(&s, 2, 5.0), vec![1, 0], "as of t=5");
        let at5 = s.top_tau_at(2, 5.0);
        assert_eq!(at5[0].1.reuse_count, 2);
        assert_eq!(at5[1].1.reuse_count, 1, "pre-bump count restored");
        assert_eq!(at5[1].1.last_used, 0.0, "pre-bump recency restored");
    }

    #[test]
    fn top_tau_at_resurrects_evicted_victims() {
        let mut s = Scrt::new(1, 2);
        s.enable_journal();
        s.insert(0, rec(0, 0.25, 5, 0.0));
        s.insert(0, rec(1, 0.1, 1, 1.0));
        // t=2: table holds {0, 1}. The insert at t=3 evicts record 1.
        let evicted = s.insert(0, rec(2, 0.2, 3, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(top_ids(&s, 2, 10.0), vec![0, 2]);
        let at2 = s.top_tau_at(2, 2.0);
        assert_eq!(
            at2.iter().map(|(_, r)| r.id).collect::<Vec<_>>(),
            vec![0, 1],
            "the victim must come back as of t=2"
        );
        // the resurrected victim carries its full payload
        assert_eq!(at2[1].1.pre.pd, vec![0.1f32; 12]);
        assert_eq!(at2[1].1.pre.gray, vec![0.1f32; 4]);
        assert_eq!(at2[1].0, 0, "bucket travels with the victim");
    }

    #[test]
    fn top_tau_at_drops_post_t_inserts_even_when_later_evicted() {
        let mut s = Scrt::new(1, 2);
        s.enable_journal();
        s.insert(0, rec(0, 0.3, 9, 0.0));
        // both of these happen after t=1: record 1 arrives, then record 2
        // evicts it — neither may surface in the t=1 reconstruction.
        s.insert(0, rec(1, 0.1, 0, 2.0));
        let evicted = s.insert(0, rec(2, 0.2, 4, 3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(top_ids(&s, 3, 1.0), vec![0]);
    }

    #[test]
    fn clear_journal_forgets_older_ops() {
        let mut s = Scrt::new(1, 4);
        s.enable_journal();
        s.insert(0, rec(0, 0.1, 1, 0.0));
        s.mark_reused(0, 0, 5.0);
        s.clear_journal();
        // Reads now only reach back to the clear: the t=1 view no longer
        // undoes the (forgotten) bump.
        let at1 = s.top_tau_at(1, 1.0);
        assert_eq!(at1[0].1.reuse_count, 2);
    }
}
