//! Run metrics — the paper's five evaluation criteria (Sec. V-A) plus
//! diagnostics, with markdown/CSV table emission shaped like the paper's
//! tables and figures.
//!
//! The simulator emits one [`TaskLog`] per completed task and one
//! [`SatSummary`] per satellite; [`aggregate`] folds them into a
//! [`RunReport`] carrying the five criteria:
//!
//! 1. **task completion time** `ς = α·Ψ + χ` (eq. 9) — total communication
//!    plus computation seconds across the network;
//! 2. **reuse rate** — reused tasks / total tasks;
//! 3. **CPU occupancy** — mean per-satellite busy fraction;
//! 4. **reuse accuracy** — correctly reused / reused (1.0 when nothing
//!    was reused);
//! 5. **data transfer volume** — every byte crossing an inter-satellite
//!    link, in MB.
//!
//! [`scale_scenario_table`] and [`sweep_table`] render the paper's table
//! and figure layouts in markdown; [`reports_to_csv`] feeds plotting
//! pipelines. Reports serialize to JSON via [`RunReport::to_json`] for the
//! CLI's `--json` mode.

use crate::coordinator::Scenario;
use crate::util::json::Json;
use crate::util::stats;

/// Run-wide communication / collaboration counters, accumulated by every
/// engine flavour and folded into the [`RunReport`] at finish time.
/// Previously six positional scalars threaded through
/// `MetricsAccum::finish`; the struct keeps the three engines' call sites
/// in lockstep now that the lossy link layer adds three more.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunCounters {
    /// Bytes put on ISLs (criterion 5 numerator).
    pub transfer_bytes: f64,
    /// Link airtime Ψ, seconds (eq. 5).
    pub comm_seconds: f64,
    pub collab_events: usize,
    pub expanded_events: usize,
    pub aborted_collabs: usize,
    pub broadcast_records: usize,
    /// Chunk attempts retransmitted after loss/corruption.
    pub retransmits: u64,
    /// Chunks abandoned after retry exhaustion.
    pub dropped_chunks: u64,
    /// Bytes saved by content-id dedup (chunks the holder already had).
    pub dedup_saved_bytes: f64,
    /// Chunk sends deferred to a later contact window (contact plans).
    pub handovers: u64,
    /// Chunks no contact window could ever carry (never sent at all).
    pub stranded_chunks: u64,
    /// Seconds chunks spent waiting for a contact window to open.
    pub contact_wait_s: f64,
    /// Satellite crashes (scripted + MTBF), node-fault model.
    pub crashes: u64,
    /// Tasks lost to crashes: queued/in-flight work dropped by a crash
    /// plus arrivals at a down satellite.
    pub lost_tasks: u64,
    /// Failover reselections: collaboration requests re-running Alg. 2
    /// after a source-side response timeout.
    pub failover_reselections: u64,
    /// Collaboration requests that exhausted every failover retry and
    /// degraded to local compute.
    pub timeout_fallbacks: u64,
    /// Reboots that came back with an empty SCRT (`scrt_persist = false`).
    pub cold_scrt_rebuilds: u64,
    /// Chunks a crashed *sender* never transmitted (no wire contact).
    pub crash_dropped_chunks: u64,
}

/// Per-satellite summary at the end of a run.
#[derive(Clone, Debug)]
pub struct SatSummary {
    pub sat: usize,
    pub tasks: usize,
    pub reused: usize,
    pub busy_s: f64,
    pub cpu_occupancy: f64,
    pub collab_requests: usize,
    pub times_source: usize,
    pub scrt_len: usize,
    pub evictions: u64,
}

/// Per-task log entry.
#[derive(Clone, Debug)]
pub struct TaskLog {
    pub task_id: usize,
    pub sat: usize,
    pub arrival: f64,
    pub start: f64,
    pub completion: f64,
    pub reused: bool,
    pub correct: bool,
    pub ssim: Option<f32>,
    pub scene: u32,
    /// Scene of the record that served this task, when reused.
    pub reused_from_scene: Option<u32>,
    /// Satellite that originally computed the serving record.
    pub reused_from_sat: Option<usize>,
}

impl TaskLog {
    pub fn latency(&self) -> f64 {
        self.completion - self.arrival
    }
}

/// Full report of one scenario run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub scenario: Scenario,
    pub n: usize,
    /// Criterion 1 — task completion time (seconds): the paper's eq. (9)
    /// objective ς = α·Ψ + χ, i.e. total communication time plus total
    /// computation time across the network. (This is the only reading under
    /// which the paper's "SRS Priority exceeds w/o CR by 41%" is possible —
    /// a wall-clock makespan cannot exceed w/o CR when reuse only removes
    /// work; see DESIGN.md.)
    pub completion_time: f64,
    /// Total on-board computation time χ (eq. 8), seconds.
    pub compute_seconds: f64,
    /// Total ISL communication time Ψ (eq. 5), seconds.
    pub comm_seconds: f64,
    /// Virtual wall-clock until the last task completes (diagnostic).
    pub makespan: f64,
    /// Criterion 2 — average proportion of reused tasks.
    pub reuse_rate: f64,
    /// Criterion 3 — average per-satellite CPU occupancy.
    pub cpu_occupancy: f64,
    /// Criterion 4 — correctly reused / reused (1.0 when nothing reused).
    pub reuse_accuracy: f64,
    /// Criterion 5 — total bytes crossing ISLs, in MB.
    pub data_transfer_mb: f64,
    pub total_tasks: usize,
    pub reused_tasks: usize,
    /// Reuses where the serving record came from a *different* scene.
    pub cross_scene_reuses: usize,
    /// Reuses served by a record another satellite computed (collaboration
    /// actually paying off).
    pub foreign_reuses: usize,
    /// Incorrect reuses split by provenance (calibration diagnostics).
    pub errors_same_scene: usize,
    pub errors_cross_scene: usize,
    pub collab_events: usize,
    pub expanded_events: usize,
    pub aborted_collabs: usize,
    pub broadcast_records: usize,
    /// Chunk attempts retransmitted after loss/corruption (0 on ideal links).
    pub retransmits: u64,
    /// Chunks abandoned after retry exhaustion (0 on ideal links).
    pub dropped_chunks: u64,
    /// MB *not* re-sent thanks to content-id chunk dedup.
    pub dedup_saved_mb: f64,
    /// Chunk sends deferred to a later contact window (0 on a static
    /// always-on topology).
    pub handovers: u64,
    /// Chunks no contact window could ever carry (0 on a static topology).
    pub stranded_chunks: u64,
    /// Total seconds chunks waited for contact windows.
    pub contact_wait_s: f64,
    /// Fraction of link engagement spent transmitting rather than waiting
    /// for a contact: `airtime / (airtime + wait)`, 1.0 when nothing waited.
    pub contact_utilization: f64,
    /// Satellite crashes (0 for the immortal legacy constellation).
    pub crashes: u64,
    /// Tasks lost to crashes (dropped queues/in-flight + dead arrivals).
    pub lost_tasks: u64,
    /// Failover reselections after a collaboration response timeout.
    pub failover_reselections: u64,
    /// Collaborations that exhausted failover retries (local fallback).
    pub timeout_fallbacks: u64,
    /// Reboots with a wiped SCRT (cold starts).
    pub cold_scrt_rebuilds: u64,
    /// Chunks a crashed sender never put on the wire.
    pub crash_dropped_chunks: u64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub per_satellite: Vec<SatSummary>,
    pub tasks: Vec<TaskLog>,
    /// Wall-clock seconds the simulation itself took (perf accounting).
    /// When the run came from the parallel experiment harness, scenario
    /// threads contend for cores, so this includes descheduled time —
    /// compare wallclocks only between runs executed the same way.
    pub wallclock_s: f64,
}

impl RunReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<12} n={}  T={:>8.2}s  rr={:.3}  cpu={:.3}  acc={:.4}  xfer={:>10.2}MB  collabs={} (+{} expanded, {} aborted)",
            self.scenario.label(),
            self.n,
            self.completion_time,
            self.reuse_rate,
            self.cpu_occupancy,
            self.reuse_accuracy,
            self.data_transfer_mb,
            self.collab_events,
            self.expanded_events,
            self.aborted_collabs,
        )
    }

    /// Serialize to JSON (experiment artifacts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.label())),
            ("n", Json::num(self.n as f64)),
            ("completion_time_s", Json::num(self.completion_time)),
            ("compute_seconds", Json::num(self.compute_seconds)),
            ("comm_seconds", Json::num(self.comm_seconds)),
            ("makespan_s", Json::num(self.makespan)),
            ("cross_scene_reuses", Json::num(self.cross_scene_reuses as f64)),
            ("foreign_reuses", Json::num(self.foreign_reuses as f64)),
            ("errors_same_scene", Json::num(self.errors_same_scene as f64)),
            ("errors_cross_scene", Json::num(self.errors_cross_scene as f64)),
            ("reuse_rate", Json::num(self.reuse_rate)),
            ("cpu_occupancy", Json::num(self.cpu_occupancy)),
            ("reuse_accuracy", Json::num(self.reuse_accuracy)),
            ("data_transfer_mb", Json::num(self.data_transfer_mb)),
            ("total_tasks", Json::num(self.total_tasks as f64)),
            ("reused_tasks", Json::num(self.reused_tasks as f64)),
            ("collab_events", Json::num(self.collab_events as f64)),
            ("expanded_events", Json::num(self.expanded_events as f64)),
            ("aborted_collabs", Json::num(self.aborted_collabs as f64)),
            ("broadcast_records", Json::num(self.broadcast_records as f64)),
            ("retransmits", Json::num(self.retransmits as f64)),
            ("dropped_chunks", Json::num(self.dropped_chunks as f64)),
            ("dedup_saved_mb", Json::num(self.dedup_saved_mb)),
            ("handovers", Json::num(self.handovers as f64)),
            ("stranded_chunks", Json::num(self.stranded_chunks as f64)),
            ("contact_wait_s", Json::num(self.contact_wait_s)),
            ("contact_utilization", Json::num(self.contact_utilization)),
            ("crashes", Json::num(self.crashes as f64)),
            ("lost_tasks", Json::num(self.lost_tasks as f64)),
            (
                "failover_reselections",
                Json::num(self.failover_reselections as f64),
            ),
            ("timeout_fallbacks", Json::num(self.timeout_fallbacks as f64)),
            ("cold_scrt_rebuilds", Json::num(self.cold_scrt_rebuilds as f64)),
            (
                "crash_dropped_chunks",
                Json::num(self.crash_dropped_chunks as f64),
            ),
            ("mean_latency_s", Json::num(self.mean_latency)),
            ("p95_latency_s", Json::num(self.p95_latency)),
            ("wallclock_s", Json::num(self.wallclock_s)),
        ])
    }
}

/// Incremental run-metrics accumulator: one [`MetricsAccum::record`] call
/// per completed task, folded on the fly into every aggregate a
/// [`RunReport`] carries.
///
/// The engine feeds this as tasks complete, so constellation-scale runs no
/// longer need the full `Vec<TaskLog>` in memory when only aggregates are
/// wanted: with `keep_logs = false` the accumulator retains one `f64`
/// latency per task (the exact p95 requires the full latency population)
/// instead of a whole [`TaskLog`], and the report's `tasks` vec comes back
/// empty. With `keep_logs = true` the result is field-for-field identical
/// to the batch [`aggregate`] fold — which is itself implemented on top of
/// this accumulator, so the two paths cannot drift.
#[derive(Clone, Debug)]
pub struct MetricsAccum {
    keep_logs: bool,
    logs: Vec<TaskLog>,
    latencies: Vec<f64>,
    makespan: f64,
    compute_seconds: f64,
    total: usize,
    reused: usize,
    reused_correct: usize,
    cross_scene_reuses: usize,
    foreign_reuses: usize,
    errors_same_scene: usize,
    errors_cross_scene: usize,
}

impl MetricsAccum {
    /// `keep_logs`: retain the per-task [`TaskLog`]s in the final report
    /// (O(tasks) memory) or only the running aggregates.
    pub fn new(keep_logs: bool) -> Self {
        MetricsAccum {
            keep_logs,
            logs: Vec::new(),
            latencies: Vec::new(),
            makespan: 0.0,
            compute_seconds: 0.0,
            total: 0,
            reused: 0,
            reused_correct: 0,
            cross_scene_reuses: 0,
            foreign_reuses: 0,
            errors_same_scene: 0,
            errors_cross_scene: 0,
        }
    }

    /// Fold one completed task into the running aggregates. Call order
    /// must be completion order — the floating-point sums reproduce the
    /// batch fold bit for bit only when the order matches.
    pub fn record(&mut self, t: TaskLog) {
        self.makespan = f64::max(self.makespan, t.completion);
        self.compute_seconds += t.completion - t.start;
        self.total += 1;
        if t.reused {
            self.reused += 1;
            if t.correct {
                self.reused_correct += 1;
            }
            if t.reused_from_scene != Some(t.scene) {
                self.cross_scene_reuses += 1;
                if !t.correct {
                    self.errors_cross_scene += 1;
                }
            } else if !t.correct {
                self.errors_same_scene += 1;
            }
            if t.reused_from_sat.is_some_and(|s| s != t.sat) {
                self.foreign_reuses += 1;
            }
        }
        self.latencies.push(t.latency());
        if self.keep_logs {
            self.logs.push(t);
        }
    }

    /// Tasks recorded so far.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Latest completion time seen so far (0 before the first task) — the
    /// engine prices end-of-run CPU occupancy against this.
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// Close the accumulator into a full [`RunReport`].
    pub fn finish(
        self,
        scenario: Scenario,
        n: usize,
        per_satellite: Vec<SatSummary>,
        alpha: f64,
        counters: &RunCounters,
        wallclock_s: f64,
    ) -> RunReport {
        let completion_time =
            alpha * counters.comm_seconds + self.compute_seconds;
        let occupancies: Vec<f64> = per_satellite
            .iter()
            .filter(|s| s.tasks > 0)
            .map(|s| s.cpu_occupancy)
            .collect();
        RunReport {
            scenario,
            n,
            completion_time,
            compute_seconds: self.compute_seconds,
            comm_seconds: counters.comm_seconds,
            makespan: self.makespan,
            reuse_rate: if self.total == 0 {
                0.0
            } else {
                self.reused as f64 / self.total as f64
            },
            cpu_occupancy: stats::mean(&occupancies),
            reuse_accuracy: if self.reused == 0 {
                1.0
            } else {
                self.reused_correct as f64 / self.reused as f64
            },
            data_transfer_mb: counters.transfer_bytes / 1e6,
            total_tasks: self.total,
            reused_tasks: self.reused,
            cross_scene_reuses: self.cross_scene_reuses,
            foreign_reuses: self.foreign_reuses,
            errors_same_scene: self.errors_same_scene,
            errors_cross_scene: self.errors_cross_scene,
            collab_events: counters.collab_events,
            expanded_events: counters.expanded_events,
            aborted_collabs: counters.aborted_collabs,
            broadcast_records: counters.broadcast_records,
            retransmits: counters.retransmits,
            dropped_chunks: counters.dropped_chunks,
            dedup_saved_mb: counters.dedup_saved_bytes / 1e6,
            handovers: counters.handovers,
            stranded_chunks: counters.stranded_chunks,
            contact_wait_s: counters.contact_wait_s,
            contact_utilization: if counters.contact_wait_s == 0.0 {
                1.0
            } else {
                counters.comm_seconds
                    / (counters.comm_seconds + counters.contact_wait_s)
            },
            crashes: counters.crashes,
            lost_tasks: counters.lost_tasks,
            failover_reselections: counters.failover_reselections,
            timeout_fallbacks: counters.timeout_fallbacks,
            cold_scrt_rebuilds: counters.cold_scrt_rebuilds,
            crash_dropped_chunks: counters.crash_dropped_chunks,
            mean_latency: stats::mean(&self.latencies),
            p95_latency: stats::percentile(&self.latencies, 95.0),
            per_satellite,
            tasks: self.logs,
            wallclock_s,
        }
    }
}

/// Global completion order for merging per-shard log streams: completion
/// time (IEEE-754 total order), then service start, then task id. Two
/// *distinct* tasks share an exact f64 completion time only on a
/// measure-zero coincidence of independent arrival/service sums, so the
/// trailing keys are deterministic tie-breakers that in practice never
/// fire — the golden-pin and property suites hold the merged order
/// bit-identical to the single-threaded engine's.
fn completion_order(a: &TaskLog, b: &TaskLog) -> std::cmp::Ordering {
    a.completion
        .total_cmp(&b.completion)
        .then(a.start.total_cmp(&b.start))
        .then(a.task_id.cmp(&b.task_id))
}

/// Fold per-shard completion-log streams (each already in its shard's
/// completion order) into one accumulator in **global** completion order.
/// The sharded engine finishes through this so its floating-point
/// aggregates sum in exactly the order the single-threaded engine's
/// incremental accumulation would. Ties across shards break via the
/// completion-order key above and then lowest shard index (a stable
/// k-way merge); within one shard the stream order is preserved.
pub fn fold_sharded(keep_logs: bool, shard_logs: Vec<Vec<TaskLog>>) -> MetricsAccum {
    let mut acc = MetricsAccum::new(keep_logs);
    let mut fronts = vec![0usize; shard_logs.len()];
    let total: usize = shard_logs.iter().map(Vec::len).sum();
    for _ in 0..total {
        let mut best: Option<usize> = None;
        for (i, logs) in shard_logs.iter().enumerate() {
            let Some(candidate) = logs.get(fronts[i]) else {
                continue;
            };
            best = match best {
                None => Some(i),
                Some(b) => {
                    let current = &shard_logs[b][fronts[b]];
                    if completion_order(candidate, current)
                        == std::cmp::Ordering::Less
                    {
                        Some(i)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        let b = best.expect("merge pops exactly `total` logs");
        acc.record(shard_logs[b][fronts[b]].clone());
        fronts[b] += 1;
    }
    acc
}

/// Build the aggregate numbers from raw logs; shared by the simulator's
/// reference path. One [`MetricsAccum`] fold in log order — by definition
/// identical to the engine's incremental accumulation.
pub fn aggregate(
    scenario: Scenario,
    n: usize,
    tasks: Vec<TaskLog>,
    per_satellite: Vec<SatSummary>,
    alpha: f64,
    counters: &RunCounters,
    wallclock_s: f64,
) -> RunReport {
    let mut acc = MetricsAccum::new(true);
    for t in tasks {
        acc.record(t);
    }
    acc.finish(scenario, n, per_satellite, alpha, counters, wallclock_s)
}

/// Render a paper-style markdown table: rows = network scale, columns =
/// scenarios, cell = `extract(report)`.
pub fn scale_scenario_table(
    title: &str,
    reports: &[RunReport],
    extract: impl Fn(&RunReport) -> String,
) -> String {
    let mut scales: Vec<usize> = reports.iter().map(|r| r.n).collect();
    scales.sort_unstable();
    scales.dedup();
    let mut out = format!("### {title}\n\n| NW Scale |");
    for s in Scenario::ALL {
        out.push_str(&format!(" {} |", s.label()));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in Scenario::ALL {
        out.push_str("---|");
    }
    out.push('\n');
    for n in scales {
        out.push_str(&format!("| {n}x{n} |"));
        for s in Scenario::ALL {
            let cell = reports
                .iter()
                .find(|r| r.n == n && r.scenario == s)
                .map(&extract)
                .unwrap_or_else(|| "—".to_string());
            out.push_str(&format!(" {cell} |"));
        }
        out.push('\n');
    }
    out
}

/// Render a sweep series (Figs. 4 & 5): one row per x-value.
pub fn sweep_table(
    title: &str,
    x_label: &str,
    series_labels: &[&str],
    rows: &[(f64, Vec<f64>)],
) -> String {
    let mut out = format!("### {title}\n\n| {x_label} |");
    for l in series_labels {
        out.push_str(&format!(" {l} |"));
    }
    out.push_str("\n|---|");
    for _ in series_labels {
        out.push_str("---|");
    }
    out.push('\n');
    for (x, ys) in rows {
        out.push_str(&format!("| {x} |"));
        for y in ys {
            out.push_str(&format!(" {y:.2} |"));
        }
        out.push('\n');
    }
    out
}

/// CSV emission for downstream plotting.
pub fn reports_to_csv(reports: &[RunReport]) -> String {
    let mut out = String::from(
        "scenario,n,completion_time_s,reuse_rate,cpu_occupancy,reuse_accuracy,data_transfer_mb,collab_events,mean_latency_s,p95_latency_s\n",
    );
    for r in reports {
        out.push_str(&format!(
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.3},{},{:.4},{:.4}\n",
            r.scenario.label().replace(',', ";"),
            r.n,
            r.completion_time,
            r.reuse_rate,
            r.cpu_occupancy,
            r.reuse_accuracy,
            r.data_transfer_mb,
            r.collab_events,
            r.mean_latency,
            r.p95_latency,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_task(id: usize, reused: bool, correct: bool, completion: f64) -> TaskLog {
        TaskLog {
            task_id: id,
            sat: 0,
            arrival: 0.0,
            start: 0.0,
            completion,
            reused,
            correct,
            ssim: None,
            scene: 0,
            reused_from_scene: if reused { Some(1) } else { None },
            reused_from_sat: if reused { Some(0) } else { None },
        }
    }

    fn mk_sat(tasks: usize, occ: f64) -> SatSummary {
        SatSummary {
            sat: 0,
            tasks,
            reused: 0,
            busy_s: 0.0,
            cpu_occupancy: occ,
            collab_requests: 0,
            times_source: 0,
            scrt_len: 0,
            evictions: 0,
        }
    }

    fn mk_counters(
        comm_seconds: f64,
        transfer_bytes: f64,
        collab_events: usize,
        expanded_events: usize,
        aborted_collabs: usize,
        broadcast_records: usize,
    ) -> RunCounters {
        RunCounters {
            transfer_bytes,
            comm_seconds,
            collab_events,
            expanded_events,
            aborted_collabs,
            broadcast_records,
            ..RunCounters::default()
        }
    }

    #[test]
    fn aggregate_computes_criteria() {
        let tasks = vec![
            mk_task(0, false, true, 1.0),
            mk_task(1, true, true, 2.0),
            mk_task(2, true, false, 5.0),
            mk_task(3, false, true, 4.0),
        ];
        let sats = vec![mk_sat(4, 0.5), mk_sat(0, 0.0)];
        let counters = mk_counters(2.5, 20.5e6, 3, 1, 0, 33);
        let r = aggregate(Scenario::Sccr, 5, tasks, sats, 1.0, &counters, 0.1);
        assert_eq!(r.makespan, 5.0);
        // sigma = alpha*comm + total service; service = completion - start
        assert!((r.completion_time - (2.5 + 12.0)).abs() < 1e-9);
        assert_eq!(r.reuse_rate, 0.5);
        assert_eq!(r.reuse_accuracy, 0.5);
        assert_eq!(r.cpu_occupancy, 0.5, "idle satellites excluded");
        assert!((r.data_transfer_mb - 20.5).abs() < 1e-9);
        assert_eq!(r.collab_events, 3);
        assert_eq!(r.retransmits, 0);
        assert_eq!(r.dropped_chunks, 0);
        assert_eq!(r.dedup_saved_mb, 0.0);
    }

    #[test]
    fn fault_counters_flow_into_the_report_and_json() {
        let counters = RunCounters {
            transfer_bytes: 2e6,
            comm_seconds: 1.0,
            retransmits: 7,
            dropped_chunks: 2,
            dedup_saved_bytes: 3.5e6,
            ..RunCounters::default()
        };
        let r = aggregate(
            Scenario::Sccr,
            5,
            vec![mk_task(0, false, true, 1.0)],
            vec![mk_sat(1, 0.5)],
            1.0,
            &counters,
            0.0,
        );
        assert_eq!(r.retransmits, 7);
        assert_eq!(r.dropped_chunks, 2);
        assert!((r.dedup_saved_mb - 3.5).abs() < 1e-12);
        let json = r.to_json().to_string_pretty();
        assert!(json.contains("\"retransmits\""));
        assert!(json.contains("\"dropped_chunks\""));
        assert!(json.contains("\"dedup_saved_mb\""));
    }

    #[test]
    fn contact_counters_flow_into_the_report_and_json() {
        let counters = RunCounters {
            comm_seconds: 3.0,
            handovers: 4,
            stranded_chunks: 2,
            contact_wait_s: 1.0,
            ..RunCounters::default()
        };
        let r = aggregate(
            Scenario::Sccr,
            5,
            vec![mk_task(0, false, true, 1.0)],
            vec![mk_sat(1, 0.5)],
            1.0,
            &counters,
            0.0,
        );
        assert_eq!(r.handovers, 4);
        assert_eq!(r.stranded_chunks, 2);
        assert_eq!(r.contact_wait_s, 1.0);
        assert!((r.contact_utilization - 0.75).abs() < 1e-12);
        let json = r.to_json().to_string_pretty();
        assert!(json.contains("\"handovers\""));
        assert!(json.contains("\"stranded_chunks\""));
        assert!(json.contains("\"contact_wait_s\""));
        assert!(json.contains("\"contact_utilization\""));
    }

    #[test]
    fn node_fault_counters_flow_into_the_report_and_json() {
        let counters = RunCounters {
            crashes: 3,
            lost_tasks: 11,
            failover_reselections: 2,
            timeout_fallbacks: 1,
            cold_scrt_rebuilds: 3,
            crash_dropped_chunks: 8,
            ..RunCounters::default()
        };
        let r = aggregate(
            Scenario::Sccr,
            5,
            vec![mk_task(0, false, true, 1.0)],
            vec![mk_sat(1, 0.5)],
            1.0,
            &counters,
            0.0,
        );
        assert_eq!(r.crashes, 3);
        assert_eq!(r.lost_tasks, 11);
        assert_eq!(r.failover_reselections, 2);
        assert_eq!(r.timeout_fallbacks, 1);
        assert_eq!(r.cold_scrt_rebuilds, 3);
        assert_eq!(r.crash_dropped_chunks, 8);
        let json = r.to_json().to_string_pretty();
        for key in [
            "\"crashes\"",
            "\"lost_tasks\"",
            "\"failover_reselections\"",
            "\"timeout_fallbacks\"",
            "\"cold_scrt_rebuilds\"",
            "\"crash_dropped_chunks\"",
        ] {
            assert!(json.contains(key), "missing {key} in JSON");
        }
    }

    #[test]
    fn contact_utilization_defaults_to_one_with_no_waiting() {
        let counters = RunCounters {
            comm_seconds: 0.0,
            ..RunCounters::default()
        };
        let r = aggregate(
            Scenario::Sccr,
            5,
            vec![mk_task(0, false, true, 1.0)],
            vec![mk_sat(1, 0.5)],
            1.0,
            &counters,
            0.0,
        );
        assert_eq!(r.contact_utilization, 1.0);
    }

    #[test]
    fn aggregate_only_accumulator_matches_batch_fold() {
        let tasks = vec![
            mk_task(0, false, true, 1.0),
            mk_task(1, true, true, 2.0),
            mk_task(2, true, false, 5.0),
            mk_task(3, false, true, 4.0),
        ];
        let sats = vec![mk_sat(4, 0.5), mk_sat(0, 0.0)];
        let counters = mk_counters(2.5, 20.5e6, 3, 1, 0, 33);
        let batch = aggregate(
            Scenario::Sccr,
            5,
            tasks.clone(),
            sats.clone(),
            1.0,
            &counters,
            0.1,
        );
        let mut acc = MetricsAccum::new(false);
        for t in tasks {
            acc.record(t);
        }
        let slim = acc.finish(Scenario::Sccr, 5, sats, 1.0, &counters, 0.1);
        assert_eq!(slim.completion_time, batch.completion_time);
        assert_eq!(slim.compute_seconds, batch.compute_seconds);
        assert_eq!(slim.makespan, batch.makespan);
        assert_eq!(slim.reuse_rate, batch.reuse_rate);
        assert_eq!(slim.reuse_accuracy, batch.reuse_accuracy);
        assert_eq!(slim.cpu_occupancy, batch.cpu_occupancy);
        assert_eq!(slim.mean_latency, batch.mean_latency);
        assert_eq!(slim.p95_latency, batch.p95_latency);
        assert_eq!(slim.cross_scene_reuses, batch.cross_scene_reuses);
        assert_eq!(slim.errors_same_scene, batch.errors_same_scene);
        assert_eq!(slim.errors_cross_scene, batch.errors_cross_scene);
        assert_eq!(slim.foreign_reuses, batch.foreign_reuses);
        assert_eq!(slim.total_tasks, batch.total_tasks);
        assert_eq!(slim.reused_tasks, batch.reused_tasks);
        assert_eq!(batch.tasks.len(), 4, "batch fold keeps the logs");
        assert!(slim.tasks.is_empty(), "aggregate-only drops the logs");
    }

    #[test]
    fn fold_sharded_merges_in_global_completion_order() {
        // Shard streams are each completion-ordered; the merge must
        // interleave them globally and reproduce the single-stream fold.
        let a = vec![mk_task(0, false, true, 1.0), mk_task(2, true, true, 4.0)];
        let b = vec![mk_task(1, true, false, 2.0), mk_task(3, false, true, 9.0)];
        let merged = fold_sharded(true, vec![a.clone(), b.clone()]);
        let order: Vec<usize> = merged.logs.iter().map(|t| t.task_id).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);

        let mut single = MetricsAccum::new(true);
        for t in [&a[0], &b[0], &a[1], &b[1]] {
            single.record(t.clone());
        }
        assert_eq!(merged.compute_seconds, single.compute_seconds);
        assert_eq!(merged.makespan, single.makespan);
        assert_eq!(merged.total, single.total);
        assert_eq!(merged.reused, single.reused);
        assert_eq!(merged.reused_correct, single.reused_correct);
        assert_eq!(merged.latencies, single.latencies);

        // aggregate-only drops the logs but keeps the fold.
        let slim = fold_sharded(false, vec![a, b]);
        assert!(slim.logs.is_empty());
        assert_eq!(slim.total, 4);
    }

    #[test]
    fn fold_sharded_ties_break_deterministically() {
        // Equal completion and start: the task id decides; a full tie is
        // impossible for distinct tasks (ids are unique).
        let a = vec![mk_task(5, false, true, 3.0)];
        let b = vec![mk_task(2, false, true, 3.0)];
        let merged = fold_sharded(true, vec![a, b]);
        let order: Vec<usize> = merged.logs.iter().map(|t| t.task_id).collect();
        assert_eq!(order, vec![2, 5]);
    }

    #[test]
    fn accuracy_is_one_without_reuse() {
        let tasks = vec![mk_task(0, false, true, 1.0)];
        let r = aggregate(
            Scenario::WithoutCr,
            5,
            tasks,
            vec![mk_sat(1, 0.9)],
            1.0,
            &RunCounters::default(),
            0.0,
        );
        assert_eq!(r.reuse_accuracy, 1.0);
        assert_eq!(r.reuse_rate, 0.0);
    }

    #[test]
    fn table_renders_all_scenarios() {
        let tasks = vec![mk_task(0, false, true, 1.0)];
        let r = aggregate(
            Scenario::Slcr,
            5,
            tasks,
            vec![mk_sat(1, 0.4)],
            1.0,
            &RunCounters::default(),
            0.0,
        );
        let table = scale_scenario_table("Reuse accuracy", &[r], |r| {
            format!("{:.4}", r.reuse_accuracy)
        });
        assert!(table.contains("| 5x5 |"));
        assert!(table.contains("SLCR"));
        assert!(table.contains("SCCR-INIT"));
        assert!(table.contains("—"), "missing scenarios show a dash");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let tasks = vec![mk_task(0, true, true, 2.0)];
        let counters = mk_counters(0.1, 1e6, 1, 0, 0, 5);
        let r = aggregate(
            Scenario::Sccr,
            7,
            tasks,
            vec![mk_sat(1, 0.2)],
            1.0,
            &counters,
            0.0,
        );
        let csv = reports_to_csv(&[r]);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("SCCR,7,"));
    }

    #[test]
    fn sweep_table_shape() {
        let t = sweep_table(
            "Impact of tau",
            "tau",
            &["SCCR-INIT", "SCCR"],
            &[(1.0, vec![10.0, 9.0]), (11.0, vec![8.0, 7.0])],
        );
        assert!(t.contains("| 11 |"));
        assert!(t.lines().count() >= 5);
    }
}
