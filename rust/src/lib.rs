//! # CCRSat — Collaborative Computation Reuse for Satellite Edge Computing
//!
//! Reproduction of *"CCRSat: A Collaborative Computation Reuse Framework for
//! Satellite Edge Computing Networks"* (Zhang et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the satellite
//!   constellation substrate, the SCRT reuse cache, the SLCR / SCCR
//!   algorithms, the baselines, a discrete-event simulator and the CLI
//!   launcher.
//! * **Layer 2 / Layer 1** — JAX compute graphs and Pallas kernels
//!   (preprocess, hyperplane LSH, SSIM, MicroGoogLeNet), AOT-lowered once to
//!   `artifacts/*.hlo.txt` and executed here through the PJRT C API
//!   ([`runtime`]). Python never runs on the request path.
//!
//! The public API is organised so a downstream user can:
//!
//! ```no_run
//! use ccrsat::config::SimConfig;
//! use ccrsat::compute::NativeBackend;
//! use ccrsat::coordinator::Scenario;
//! use ccrsat::simulator::Simulation;
//!
//! let cfg = SimConfig::paper_default(5);
//! let backend = NativeBackend::new(&cfg);
//! let report = Simulation::new(&cfg, &backend, Scenario::Sccr).run().unwrap();
//! println!("{}", report.summary());
//! ```
//!
//! See `README.md` for the repository layout and `docs/ARCHITECTURE.md`
//! for the event flow and module map.

#![deny(rustdoc::broken_intra_doc_links)]
#![forbid(unsafe_code)]

pub mod compute;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod harness;
pub mod metrics;
pub mod network;
pub mod runtime;
pub mod satellite;
pub mod simulator;
pub mod util;
pub mod workload;

pub use error::{Error, Result};

/// Crate version, re-exported for the CLI `--version` output.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
