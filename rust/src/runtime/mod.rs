//! PJRT runtime: load AOT artifacts and execute them from the Rust hot path.
//!
//! The interchange format is HLO **text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids which the
//! `xla` crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
//! and round-trips cleanly.
//!
//! [`Engine`] compiles each artifact once on first use and caches the loaded
//! executable; every subsequent call is a buffer upload + execute.
//!
//! ## Offline builds
//!
//! The real engine needs the `xla` crate, which the offline build image
//! cannot fetch. By default (no `pjrt` feature) this module therefore ships
//! an **API-compatible stub**: [`Manifest`] and [`Tensor`] work in full,
//! while [`Engine::new`] returns an error explaining that artifact
//! execution is unavailable. Callers (CLI, benches, examples) already fall
//! back to the pure-Rust [`crate::compute::NativeBackend`] when artifacts
//! cannot be opened, so the default build is fully functional end-to-end.
//! Enable the `pjrt` feature with a vendored `xla` crate for the real
//! three-layer path.

pub mod manifest;
pub mod tensor;

use crate::error::Result;
#[cfg(not(feature = "pjrt"))]
use crate::error::Error;
use std::path::Path;

pub use manifest::{ArtifactEntry, DType, Manifest, ModelConstants, TensorSpec};
pub use tensor::Tensor;

/// Execution statistics (observability + perf accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
}

/// Offline stand-in for the PJRT execution engine.
///
/// Uninhabited: no value of this type can exist, so every method body is
/// statically unreachable, yet the API surface matches the real engine and
/// no caller needs `cfg` guards. [`Engine::new`] validates the manifest
/// first (so "missing artifacts" errors stay identical to the real path),
/// then reports that execution requires the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub enum Engine {}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Create an engine over an artifacts directory (reads `manifest.json`).
    ///
    /// In the offline build this always errors: first with the manifest
    /// problem if the directory is unusable, otherwise with a note that the
    /// `pjrt` feature is disabled.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let _manifest = Manifest::load(artifacts_dir)?;
        Err(Error::artifact(
            "PJRT execution unavailable: built without the `pjrt` feature \
             (requires a vendored `xla` crate); use the native backend",
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        match *self {}
    }

    pub fn constants(&self) -> &ModelConstants {
        match *self {}
    }

    pub fn stats(&self) -> EngineStats {
        match *self {}
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    /// Eagerly compile every artifact (useful to front-load latency).
    pub fn warmup(&self) -> Result<()> {
        match *self {}
    }

    /// Execute an entry with host tensors; returns the decomposed out-tuple.
    pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match *self {}
    }

    /// `preprocess`: raw `[raw_h, raw_w, 3]` (0..255) → `(pd, gray)`.
    pub fn preprocess(&self, _raw: &Tensor) -> Result<(Tensor, Tensor)> {
        match *self {}
    }

    /// `lsh_hash`: pd → (bucket id, raw projections).
    pub fn lsh_hash(&self, _pd: &Tensor) -> Result<(u32, Vec<f32>)> {
        match *self {}
    }

    /// `ssim_pair`: two gray images → SSIM scalar.
    pub fn ssim(&self, _a: &Tensor, _b: &Tensor) -> Result<f32> {
        match *self {}
    }

    /// `classifier`: pd → (logits, label).
    pub fn classify(&self, _pd: &Tensor) -> Result<(Vec<f32>, u32)> {
        match *self {}
    }

    /// `classifier_batch`: `[batch, pre_h, pre_w, 3]` → labels for the batch.
    pub fn classify_batch(&self, _pds: &Tensor, _valid: usize) -> Result<Vec<u32>> {
        match *self {}
    }
}

/// The PJRT execution engine: one CPU client + a compile-once cache.
///
/// Interior mutability is `Mutex`-based (not `RefCell`) so the engine stays
/// [`Sync`] — the parallel experiment harness shares one backend across
/// scenario threads.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: std::sync::Mutex<
        std::collections::HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>,
    >,
    stats: std::sync::Mutex<EngineStats>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create an engine over an artifacts directory (reads `manifest.json`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            executables: std::sync::Mutex::new(std::collections::HashMap::new()),
            stats: std::sync::Mutex::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn constants(&self) -> &ModelConstants {
        &self.manifest.constants
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an entry. The map lock
    /// is held across the compile so two threads can never compile the
    /// same artifact twice; execution itself runs lock-free on the
    /// returned `Arc` handle.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut execs = self.executables.lock().unwrap();
        if let Some(exe) = execs.get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file).map_err(|e| {
            crate::error::Error::artifact(format!(
                "parse {} failed: {e} (re-run `make artifacts`)",
                entry.file.display()
            ))
        })?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&computation)?);
        execs.insert(name.to_string(), exe.clone());
        self.stats.lock().unwrap().compiles += 1;
        Ok(exe)
    }

    /// Eagerly compile every artifact (useful to front-load latency).
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for name in names {
            self.executable(&name)?;
        }
        Ok(())
    }

    /// Execute an entry with host tensors; returns the decomposed out-tuple.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            return Err(crate::error::Error::artifact(format!(
                "{name}: got {} inputs, want {}",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if !t.matches(spec) {
                return Err(crate::error::Error::artifact(format!(
                    "{name}: input {i} is {:?}/{:?}, want {:?}/{:?}",
                    t.shape(),
                    t.dtype(),
                    spec.shape,
                    spec.dtype
                )));
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.stats.lock().unwrap().executions += 1;
        // All artifacts are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            return Err(crate::error::Error::artifact(format!(
                "{name}: got {} outputs, want {}",
                parts.len(),
                entry.outputs.len()
            )));
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, spec))
            .collect()
    }

    /// `preprocess`: raw `[raw_h, raw_w, 3]` (0..255) → `(pd, gray)`.
    pub fn preprocess(&self, raw: &Tensor) -> Result<(Tensor, Tensor)> {
        let mut out = self.execute("preprocess", std::slice::from_ref(raw))?;
        let gray = out.pop().unwrap();
        let pd = out.pop().unwrap();
        Ok((pd, gray))
    }

    /// `lsh_hash`: pd → (bucket id, raw projections).
    pub fn lsh_hash(&self, pd: &Tensor) -> Result<(u32, Vec<f32>)> {
        let out = self.execute("lsh_hash", std::slice::from_ref(pd))?;
        let bucket = out[0].scalar_u32()?;
        let proj = out[1].as_f32()?.to_vec();
        Ok((bucket, proj))
    }

    /// `ssim_pair`: two gray images → SSIM scalar.
    pub fn ssim(&self, a: &Tensor, b: &Tensor) -> Result<f32> {
        let out = self.execute("ssim_pair", &[a.clone(), b.clone()])?;
        out[0].scalar_f32()
    }

    /// `classifier`: pd → (logits, label).
    pub fn classify(&self, pd: &Tensor) -> Result<(Vec<f32>, u32)> {
        let out = self.execute("classifier", std::slice::from_ref(pd))?;
        Ok((out[0].as_f32()?.to_vec(), out[1].scalar_u32()?))
    }

    /// `classifier_batch`: `[batch, pre_h, pre_w, 3]` → labels for the batch.
    /// Callers pad the final chunk; `valid` trims the returned labels.
    pub fn classify_batch(&self, pds: &Tensor, valid: usize) -> Result<Vec<u32>> {
        let out = self.execute("classifier_batch", std::slice::from_ref(pds))?;
        let labels = out[1].as_u32()?;
        if valid > labels.len() {
            return Err(crate::error::Error::artifact(format!(
                "valid={valid} exceeds batch {}",
                labels.len()
            )));
        }
        Ok(labels[..valid].to_vec())
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/runtime_it.rs
    // (they require `make artifacts`); here we only cover pure logic.
    use super::*;

    #[test]
    fn engine_missing_dir_errors() {
        match Engine::new("/nonexistent-artifacts-dir") {
            Ok(_) => panic!("engine must not open a missing directory"),
            Err(err) => {
                assert!(err.to_string().contains("make artifacts"), "{err}")
            }
        }
    }
}
