//! PJRT runtime: load AOT artifacts and execute them from the Rust hot path.
//!
//! The interchange format is HLO **text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids which the
//! `xla` crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
//! and round-trips cleanly (see /opt/xla-example/README.md).
//!
//! [`Engine`] compiles each artifact once on first use and caches the loaded
//! executable; every subsequent call is a buffer upload + execute.

pub mod manifest;
pub mod tensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
pub use manifest::{ArtifactEntry, DType, Manifest, ModelConstants, TensorSpec};
pub use tensor::Tensor;

/// Execution statistics (observability + perf accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
}

/// The PJRT execution engine: one CPU client + a compile-once cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create an engine over an artifacts directory (reads `manifest.json`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn constants(&self) -> &ModelConstants {
        &self.manifest.constants
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an entry.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file).map_err(|e| {
            Error::artifact(format!(
                "parse {} failed: {e} (re-run `make artifacts`)",
                entry.file.display()
            ))
        })?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&computation)?;
        self.executables.borrow_mut().insert(name.to_string(), exe);
        self.stats.borrow_mut().compiles += 1;
        Ok(())
    }

    /// Eagerly compile every artifact (useful to front-load latency).
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for name in names {
            self.ensure_compiled(&name)?;
        }
        Ok(())
    }

    /// Execute an entry with host tensors; returns the decomposed out-tuple.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            return Err(Error::artifact(format!(
                "{name}: got {} inputs, want {}",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if !t.matches(spec) {
                return Err(Error::artifact(format!(
                    "{name}: input {i} is {:?}/{:?}, want {:?}/{:?}",
                    t.shape(),
                    t.dtype(),
                    spec.shape,
                    spec.dtype
                )));
            }
        }
        self.ensure_compiled(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let execs = self.executables.borrow();
        let exe = execs.get(name).expect("ensured above");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.stats.borrow_mut().executions += 1;
        // All artifacts are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::artifact(format!(
                "{name}: got {} outputs, want {}",
                parts.len(),
                entry.outputs.len()
            )));
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, spec))
            .collect()
    }

    // ------------------------------------------------------------------
    // Typed helpers for the five artifacts (the coordinator's call sites).
    // ------------------------------------------------------------------

    /// `preprocess`: raw `[raw_h, raw_w, 3]` (0..255) → `(pd, gray)`.
    pub fn preprocess(&self, raw: &Tensor) -> Result<(Tensor, Tensor)> {
        let mut out = self.execute("preprocess", std::slice::from_ref(raw))?;
        let gray = out.pop().unwrap();
        let pd = out.pop().unwrap();
        Ok((pd, gray))
    }

    /// `lsh_hash`: pd → (bucket id, raw projections).
    pub fn lsh_hash(&self, pd: &Tensor) -> Result<(u32, Vec<f32>)> {
        let out = self.execute("lsh_hash", std::slice::from_ref(pd))?;
        let bucket = out[0].scalar_u32()?;
        let proj = out[1].as_f32()?.to_vec();
        Ok((bucket, proj))
    }

    /// `ssim_pair`: two gray images → SSIM scalar.
    pub fn ssim(&self, a: &Tensor, b: &Tensor) -> Result<f32> {
        let out = self.execute("ssim_pair", &[a.clone(), b.clone()])?;
        out[0].scalar_f32()
    }

    /// `classifier`: pd → (logits, label).
    pub fn classify(&self, pd: &Tensor) -> Result<(Vec<f32>, u32)> {
        let out = self.execute("classifier", std::slice::from_ref(pd))?;
        Ok((out[0].as_f32()?.to_vec(), out[1].scalar_u32()?))
    }

    /// `classifier_batch`: `[batch, pre_h, pre_w, 3]` → labels for the batch.
    /// Callers pad the final chunk; `valid` trims the returned labels.
    pub fn classify_batch(&self, pds: &Tensor, valid: usize) -> Result<Vec<u32>> {
        let out = self.execute("classifier_batch", std::slice::from_ref(pds))?;
        let labels = out[1].as_u32()?;
        if valid > labels.len() {
            return Err(Error::artifact(format!(
                "valid={valid} exceeds batch {}",
                labels.len()
            )));
        }
        Ok(labels[..valid].to_vec())
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/runtime_it.rs
    // (they require `make artifacts`); here we only cover pure logic.
    use super::*;

    #[test]
    fn engine_missing_dir_errors() {
        match Engine::new("/nonexistent-artifacts-dir") {
            Ok(_) => panic!("engine must not open a missing directory"),
            Err(err) => {
                assert!(err.to_string().contains("make artifacts"), "{err}")
            }
        }
    }
}
