//! Host-side tensors crossing the PJRT boundary.
//!
//! A deliberately small representation: contiguous row-major data plus a
//! shape, convertible to/from `xla::Literal` when the `pjrt` feature is
//! enabled. Only the two element types the artifacts use (f32, u32) are
//! supported.

use crate::error::{Error, Result};
use crate::runtime::manifest::{DType, TensorSpec};

/// A host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(Error::artifact(format!(
                "tensor shape {shape:?} wants {want} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(Error::artifact(format!(
                "tensor shape {shape:?} wants {want} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor::U32 { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::U32 { .. } => DType::U32,
        }
    }

    pub fn element_count(&self) -> usize {
        self.shape().iter().product()
    }

    /// Borrow f32 data or error.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::artifact("tensor is not f32")),
        }
    }

    /// Borrow u32 data or error.
    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Tensor::U32 { data, .. } => Ok(data),
            _ => Err(Error::artifact("tensor is not u32")),
        }
    }

    /// Scalar f32 accessor (rank-0 or single-element).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(Error::artifact(format!(
                "expected scalar, got {} elements",
                d.len()
            )));
        }
        Ok(d[0])
    }

    /// Scalar u32 accessor.
    pub fn scalar_u32(&self) -> Result<u32> {
        let d = self.as_u32()?;
        if d.len() != 1 {
            return Err(Error::artifact(format!(
                "expected scalar, got {} elements",
                d.len()
            )));
        }
        Ok(d[0])
    }

    /// Does this tensor match a manifest boundary spec?
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape() == spec.shape.as_slice() && self.dtype() == spec.dtype
    }

    /// Convert to an `xla::Literal` with the right shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        // reshape handles rank-0 via an empty dims slice
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an `xla::Literal` using the manifest spec for shape.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        match spec.dtype {
            DType::F32 => Tensor::f32(spec.shape.clone(), lit.to_vec::<f32>()?),
            DType::U32 => Tensor::u32(spec.shape.clone(), lit.to_vec::<u32>()?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::u32(vec![], vec![7]).is_ok()); // rank-0
        assert!(Tensor::u32(vec![], vec![]).is_err());
    }

    #[test]
    fn accessors() {
        let t = Tensor::f32(vec![], vec![1.5]).unwrap();
        assert_eq!(t.scalar_f32().unwrap(), 1.5);
        assert!(t.scalar_u32().is_err());
        let t = Tensor::u32(vec![2], vec![1, 2]).unwrap();
        assert!(t.scalar_u32().is_err()); // two elements
        assert_eq!(t.as_u32().unwrap(), &[1, 2]);
    }

    #[test]
    fn spec_matching() {
        let spec = TensorSpec {
            shape: vec![2, 2],
            dtype: DType::F32,
        };
        assert!(Tensor::f32(vec![2, 2], vec![0.0; 4]).unwrap().matches(&spec));
        assert!(!Tensor::u32(vec![2, 2], vec![0; 4]).unwrap().matches(&spec));
        assert!(!Tensor::f32(vec![4], vec![0.0; 4]).unwrap().matches(&spec));
    }
}
