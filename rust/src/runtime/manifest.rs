//! `artifacts/manifest.json` model.
//!
//! The AOT pass (`python/compile/aot.py`) records, for every lowered entry
//! point, the artifact file plus input/output shapes and dtypes, and a block
//! of model constants the simulator needs (FLOPs, LSH geometry, ...).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Element type of a tensor boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "uint32" => Ok(DType::U32),
            other => Err(Error::artifact(format!("unsupported dtype '{other}'"))),
        }
    }
}

/// Shape + dtype of one input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .at(&["shape"])?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.at(&["dtype"])?.as_str()?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Constants the L2 model bakes in; the simulator must agree with them.
#[derive(Clone, Debug)]
pub struct ModelConstants {
    pub raw_h: usize,
    pub raw_w: usize,
    pub pre_h: usize,
    pub pre_w: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub p_l: usize,
    pub p_k: usize,
    pub num_buckets: usize,
    pub feature_dim: usize,
    pub batch: usize,
    pub classifier_flops: u64,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
    pub constants: ModelConstants,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (split out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let v = Json::parse(text)?;
        if v.at(&["format"])?.as_str()? != "hlo-text" {
            return Err(Error::artifact("manifest format is not hlo-text"));
        }
        let mut entries = BTreeMap::new();
        for (name, ev) in v.at(&["entries"])?.as_obj()? {
            let inputs = ev
                .at(&["inputs"])?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = ev
                .at(&["outputs"])?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: dir.join(ev.at(&["file"])?.as_str()?),
                    inputs,
                    outputs,
                },
            );
        }
        let c = v.at(&["constants"])?;
        let get = |k: &str| -> Result<usize> { c.at(&[k])?.as_usize() };
        let constants = ModelConstants {
            raw_h: get("raw_h")?,
            raw_w: get("raw_w")?,
            pre_h: get("pre_h")?,
            pre_w: get("pre_w")?,
            channels: get("channels")?,
            num_classes: get("num_classes")?,
            p_l: get("p_l")?,
            p_k: get("p_k")?,
            num_buckets: get("num_buckets")?,
            feature_dim: get("feature_dim")?,
            batch: get("batch")?,
            classifier_flops: c.at(&["classifier_flops"])?.as_u64()?,
        };
        let m = Manifest {
            dir,
            entries,
            constants,
        };
        m.validate()?;
        Ok(m)
    }

    /// Check the entries the runtime depends on exist with the right arity.
    pub fn validate(&self) -> Result<()> {
        for (name, n_in, n_out) in [
            ("preprocess", 1, 2),
            ("lsh_hash", 1, 2),
            ("ssim_pair", 2, 1),
            ("classifier", 1, 2),
            ("classifier_batch", 1, 2),
        ] {
            let e = self.entries.get(name).ok_or_else(|| {
                Error::artifact(format!("manifest missing entry '{name}'"))
            })?;
            if e.inputs.len() != n_in || e.outputs.len() != n_out {
                return Err(Error::artifact(format!(
                    "entry '{name}' arity mismatch: {}→{} (want {n_in}→{n_out})",
                    e.inputs.len(),
                    e.outputs.len()
                )));
            }
        }
        if self.constants.num_buckets != 1 << self.constants.p_k {
            return Err(Error::artifact("num_buckets != 2^p_k"));
        }
        Ok(())
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::artifact(format!("no artifact entry '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
  "format": "hlo-text",
  "return_tuple": true,
  "entries": {
    "preprocess": {"file": "preprocess.hlo.txt",
      "inputs": [{"shape": [64, 64, 3], "dtype": "float32"}],
      "outputs": [{"shape": [32, 32, 3], "dtype": "float32"},
                  {"shape": [32, 32], "dtype": "float32"}]},
    "lsh_hash": {"file": "lsh_hash.hlo.txt",
      "inputs": [{"shape": [32, 32, 3], "dtype": "float32"}],
      "outputs": [{"shape": [], "dtype": "uint32"},
                  {"shape": [2], "dtype": "float32"}]},
    "ssim_pair": {"file": "ssim_pair.hlo.txt",
      "inputs": [{"shape": [32, 32], "dtype": "float32"},
                 {"shape": [32, 32], "dtype": "float32"}],
      "outputs": [{"shape": [], "dtype": "float32"}]},
    "classifier": {"file": "classifier.hlo.txt",
      "inputs": [{"shape": [32, 32, 3], "dtype": "float32"}],
      "outputs": [{"shape": [21], "dtype": "float32"},
                  {"shape": [], "dtype": "uint32"}]},
    "classifier_batch": {"file": "classifier_batch.hlo.txt",
      "inputs": [{"shape": [32, 32, 32, 3], "dtype": "float32"}],
      "outputs": [{"shape": [32, 21], "dtype": "float32"},
                  {"shape": [32], "dtype": "uint32"}]}
  },
  "constants": {
    "raw_h": 64, "raw_w": 64, "pre_h": 32, "pre_w": 32, "channels": 3,
    "num_classes": 21, "p_l": 1, "p_k": 2, "num_buckets": 4,
    "feature_dim": 3072, "batch": 32, "classifier_flops": 11460608,
    "matmul_vmem_bytes": 196608
  }
}"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample(), PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.constants.num_classes, 21);
        assert_eq!(m.constants.p_k, 2);
        assert_eq!(m.entry("ssim_pair").unwrap().inputs.len(), 2);
        assert_eq!(
            m.entry("preprocess").unwrap().file,
            PathBuf::from("/tmp/a/preprocess.hlo.txt")
        );
        assert_eq!(m.entry("classifier").unwrap().outputs[0].shape, vec![21]);
    }

    #[test]
    fn rejects_missing_entry() {
        let text = sample().replace("\"ssim_pair\"", "\"ssim_other\"");
        assert!(Manifest::parse(&text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_bucket_mismatch() {
        let text = sample().replace("\"num_buckets\": 4", "\"num_buckets\": 8");
        assert!(Manifest::parse(&text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let text = sample().replace("uint32", "int64");
        assert!(Manifest::parse(&text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn spec_element_count() {
        let s = TensorSpec {
            shape: vec![32, 32, 3],
            dtype: DType::F32,
        };
        assert_eq!(s.element_count(), 3072);
    }
}
