//! PJRT compute backend — the production three-layer path.
//!
//! Every operation executes the corresponding AOT artifact (Pallas kernels
//! inside JAX graphs, lowered to HLO text) on the embedded PJRT CPU client.
//! No Python anywhere near this code path.

use crate::compute::{ComputeBackend, Preprocessed};
use crate::error::{Error, Result};
use crate::runtime::{Engine, Tensor};
use crate::workload::ImageData;

/// Backend over a PJRT [`Engine`].
pub struct PjrtBackend {
    engine: Engine,
    raw_h: usize,
    raw_w: usize,
    pre_h: usize,
    pre_w: usize,
    batch: usize,
}

impl PjrtBackend {
    /// Wrap an engine; validates dims against the manifest constants.
    pub fn new(engine: Engine) -> Result<Self> {
        let c = engine.constants().clone();
        if c.channels != 3 {
            return Err(Error::artifact("expected 3-channel artifacts"));
        }
        Ok(PjrtBackend {
            raw_h: c.raw_h,
            raw_w: c.raw_w,
            pre_h: c.pre_h,
            pre_w: c.pre_w,
            batch: c.batch,
            engine,
        })
    }

    /// Open the default artifacts directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::new(Engine::new(dir)?)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn pre_to_tensor(&self, pre: &Preprocessed) -> Result<Tensor> {
        Tensor::f32(vec![self.pre_h, self.pre_w, 3], pre.pd.clone())
    }

    fn gray_to_tensor(&self, pre: &Preprocessed) -> Result<Tensor> {
        Tensor::f32(vec![self.pre_h, self.pre_w], pre.gray.clone())
    }
}

impl ComputeBackend for PjrtBackend {
    fn preprocess(&self, raw: &ImageData) -> Result<Preprocessed> {
        if raw.h != self.raw_h || raw.w != self.raw_w {
            return Err(Error::simulation(format!(
                "raw image {}x{} does not match artifact {}x{}",
                raw.h, raw.w, self.raw_h, self.raw_w
            )));
        }
        let t = Tensor::f32(vec![self.raw_h, self.raw_w, 3], raw.pixels.clone())?;
        let (pd, gray) = self.engine.preprocess(&t)?;
        Ok(Preprocessed {
            h: self.pre_h,
            w: self.pre_w,
            pd: pd.as_f32()?.to_vec(),
            gray: gray.as_f32()?.to_vec(),
        })
    }

    fn lsh_bucket(&self, pre: &Preprocessed) -> Result<u32> {
        let (bucket, _proj) = self.engine.lsh_hash(&self.pre_to_tensor(pre)?)?;
        Ok(bucket)
    }

    fn ssim(&self, a: &Preprocessed, b: &Preprocessed) -> Result<f32> {
        self.engine
            .ssim(&self.gray_to_tensor(a)?, &self.gray_to_tensor(b)?)
    }

    fn classify(&self, pre: &Preprocessed) -> Result<u32> {
        let (_logits, label) = self.engine.classify(&self.pre_to_tensor(pre)?)?;
        Ok(label)
    }

    /// Batched oracle pass through the `classifier_batch` artifact —
    /// amortises PJRT dispatch over `batch` images per call.
    fn classify_many(&self, pres: &[&Preprocessed]) -> Result<Vec<u32>> {
        let per_image = self.pre_h * self.pre_w * 3;
        let mut labels = Vec::with_capacity(pres.len());
        for chunk in pres.chunks(self.batch) {
            let mut data = vec![0f32; self.batch * per_image];
            for (i, pre) in chunk.iter().enumerate() {
                data[i * per_image..(i + 1) * per_image].copy_from_slice(&pre.pd);
            }
            let t = Tensor::f32(
                vec![self.batch, self.pre_h, self.pre_w, 3],
                data,
            )?;
            labels.extend(self.engine.classify_batch(&t, chunk.len())?);
        }
        Ok(labels)
    }

    fn num_buckets(&self) -> usize {
        self.engine.constants().num_buckets
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
