//! Compute backends: the data-dependent operations of the reuse pipeline.
//!
//! The coordinator is generic over [`ComputeBackend`] with two production
//! implementations:
//!
//! * [`PjrtBackend`] — executes the AOT artifacts (Pallas/JAX lowered to
//!   HLO) through the PJRT engine. This is the real three-layer path used
//!   by the paper-reproduction runs.
//! * [`NativeBackend`] — a pure-Rust reference of preprocess / hyperplane
//!   LSH / SSIM plus a seeded linear classifier. Used by unit tests, fast
//!   sweeps and as a cross-check against the artifacts (the integration
//!   suite asserts both backends agree on SSIM and preprocessing).

pub mod kernels;
pub mod native;
pub mod pjrt;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use crate::error::Result;
use crate::workload::ImageData;

/// A pre-processed task input (`PD_t` in Alg. 1) plus the grayscale plane
/// the SSIM gate consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct Preprocessed {
    pub h: usize,
    pub w: usize,
    /// `[h, w, 3]` row-major, values in [0, 1].
    pub pd: Vec<f32>,
    /// `[h, w]` grayscale, values in [0, 1].
    pub gray: Vec<f32>,
}

/// The data-dependent operations Alg. 1/2 need.
///
/// `Send + Sync` is a supertrait requirement: the experiment harness
/// ([`crate::harness::experiments`]) shares one backend across scenario
/// threads, so implementations must use thread-safe interior mutability
/// (the PJRT engine's compile cache is a `Mutex`, the native backend is
/// immutable after construction).
pub trait ComputeBackend: Send + Sync {
    /// Alg. 1 line 1: resize + normalise + grayscale.
    fn preprocess(&self, raw: &ImageData) -> Result<Preprocessed>;

    /// Batched preprocess — the bulk entry point `simulator::prepare`
    /// drives. The default maps [`ComputeBackend::preprocess`]; backends
    /// with batch kernels override. Output order matches input order.
    fn preprocess_many(&self, raws: &[&ImageData]) -> Result<Vec<Preprocessed>> {
        raws.iter().map(|&raw| self.preprocess(raw)).collect()
    }

    /// Alg. 1 line 2: LSH bucket of a pre-processed input.
    fn lsh_bucket(&self, pre: &Preprocessed) -> Result<u32>;

    /// Batched LSH hashing; the default maps
    /// [`ComputeBackend::lsh_bucket`]. Output order matches input order.
    fn lsh_bucket_many(&self, pres: &[&Preprocessed]) -> Result<Vec<u32>> {
        pres.iter().map(|&pre| self.lsh_bucket(pre)).collect()
    }

    /// Alg. 1 line 8: SSIM between two pre-processed inputs (eq. 12).
    fn ssim(&self, a: &Preprocessed, b: &Preprocessed) -> Result<f32>;

    /// Alg. 1 lines 4/13: run the pre-trained model, return the label.
    fn classify(&self, pre: &Preprocessed) -> Result<u32>;

    /// Batched classify for the oracle pass; the default maps `classify`.
    fn classify_many(&self, pres: &[&Preprocessed]) -> Result<Vec<u32>> {
        pres.iter().map(|p| self.classify(p)).collect()
    }

    /// Number of LSH buckets (`2^p_k`).
    fn num_buckets(&self) -> usize;

    /// Human-readable backend name (logs, reports).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::rng::Rng;
    use crate::workload::texture::{SceneSpec, TextureSynth};

    /// Shared backend conformance suite, run against NativeBackend here and
    /// against PjrtBackend in the integration tests (needs artifacts).
    pub fn conformance(backend: &dyn ComputeBackend, raw_h: usize, raw_w: usize) {
        let synth = TextureSynth::new(raw_h, raw_w, 0.05);
        let scene_a = SceneSpec::sample(0, 2, &mut Rng::new(1));
        let scene_b = SceneSpec::sample(1, 9, &mut Rng::new(2));
        let img_a1 = synth.render(&scene_a, &mut Rng::new(10));
        let img_a2 = synth.render(&scene_a, &mut Rng::new(11));
        let img_b = synth.render(&scene_b, &mut Rng::new(12));

        let pa1 = backend.preprocess(&img_a1).unwrap();
        let pa2 = backend.preprocess(&img_a2).unwrap();
        let pb = backend.preprocess(&img_b).unwrap();

        // pd in [0,1], right sizes
        assert_eq!(pa1.pd.len(), pa1.h * pa1.w * 3);
        assert_eq!(pa1.gray.len(), pa1.h * pa1.w);
        assert!(pa1.pd.iter().all(|&x| (0.0..=1.0).contains(&x)));

        // SSIM: identical = 1, same scene high, cross-class lower
        let s_self = backend.ssim(&pa1, &pa1).unwrap();
        assert!((s_self - 1.0).abs() < 1e-4, "ssim(self)={s_self}");
        let s_same = backend.ssim(&pa1, &pa2).unwrap();
        let s_cross = backend.ssim(&pa1, &pb).unwrap();
        assert!(s_same > s_cross, "same {s_same} !> cross {s_cross}");
        assert!(s_same > 0.7, "same-scene ssim {s_same}");

        // LSH: deterministic, in range, same scene collides
        let b1 = backend.lsh_bucket(&pa1).unwrap();
        assert_eq!(b1, backend.lsh_bucket(&pa1).unwrap());
        assert!((b1 as usize) < backend.num_buckets());
        assert_eq!(b1, backend.lsh_bucket(&pa2).unwrap());

        // classifier: deterministic, stable within a scene
        let l1 = backend.classify(&pa1).unwrap();
        assert_eq!(l1, backend.classify(&pa1).unwrap());
        assert_eq!(l1, backend.classify(&pa2).unwrap());

        // classify_many matches classify
        let many = backend.classify_many(&[&pa1, &pb]).unwrap();
        assert_eq!(many[0], l1);
        assert_eq!(many[1], backend.classify(&pb).unwrap());

        // batched preprocess / LSH match the single-task paths
        let pre_many = backend.preprocess_many(&[&img_a1, &img_b]).unwrap();
        assert_eq!(pre_many.len(), 2);
        assert_eq!(pre_many[0], pa1);
        assert_eq!(pre_many[1], pb);
        let bucket_many = backend.lsh_bucket_many(&[&pa1, &pa2, &pb]).unwrap();
        assert_eq!(bucket_many[0], b1);
        assert_eq!(bucket_many[1], b1);
        assert_eq!(bucket_many[2], backend.lsh_bucket(&pb).unwrap());
    }

    #[test]
    fn native_backend_conformance() {
        let cfg = SimConfig::paper_default(5);
        let backend = NativeBackend::new(&cfg);
        conformance(&backend, cfg.workload.raw_h, cfg.workload.raw_w);
    }
}
