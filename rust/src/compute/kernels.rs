//! Blocked, flat-matrix linear-algebra kernels for the native backend.
//!
//! The per-task tax of the reuse pipeline (Alg. 1) is a handful of dense
//! dot products: `p_k` hyperplane projections for the LSH bucket and
//! `num_classes` rows of the classifier projection. The seed implementation
//! walked `Vec<Vec<f32>>` rows with `iter().zip().sum()` — a strict-order
//! IEEE reduction LLVM must keep scalar, on top of a pointer chase per row.
//!
//! These kernels fix both halves:
//!
//! * **flat row-major storage** — one contiguous `Vec<f32>` per matrix,
//!   `rows × cols`, walked in stride-`cols` chunks (no per-row heap hops);
//! * **multi-accumulator lanes** — the inner loop keeps [`LANES`]
//!   independent partial sums, so the reduction is re-associated into a
//!   form the autovectorizer can turn into SIMD adds/FMAs;
//! * **fixed-width array arithmetic** — every inner loop converts its
//!   chunk slices to `&[f32; LANES]` arrays before the lane loop, so the
//!   trip count and the absence of bounds checks are visible in the IR
//!   (a `chunks_exact` slice still carries a runtime length LLVM has to
//!   re-derive per loop; the array type carries it in the type);
//! * **row blocking** — [`gemm_nt`] walks the weight matrix once per block
//!   of [`GEMM_ROW_BLOCK`] input rows, so weights stream from cache instead
//!   of from memory once per task, and [`gemv`]/[`gemm_nt`] process
//!   [`ROW_LANES`] matrix rows per pass through the shared vector so each
//!   loaded input chunk is reused `ROW_LANES` times from registers.
//!
//! Determinism contract: every kernel reduces each dot product in exactly
//! the same order ([`dot`]'s fixed lane tree), so `gemm_nt` is bitwise
//! identical to a loop of [`gemv`] calls, which is bitwise identical to a
//! loop of [`dot`] calls. The batched backend entry points therefore
//! produce the same labels/buckets as the single-task paths, bit for bit.
//! (Results differ from the seed's strict left-to-right sum by normal
//! floating-point re-association — within ~1e-4 relative error, see the
//! property tests in `tests/properties.rs`.)

/// Independent partial sums kept by the inner loops. Eight f32 lanes fill
/// two SSE / one AVX register — enough to hide FP add latency without
/// spilling on any x86-64 or aarch64 target.
pub const LANES: usize = 8;

/// Input rows per [`gemm_nt`] block: the block (8 × 3072 floats ≈ 96 KiB
/// at paper dims) stays L2-resident while the weight matrix streams over
/// it once per block.
pub const GEMM_ROW_BLOCK: usize = 8;

/// Matrix rows processed together by the multi-row kernel behind
/// [`gemv`]/[`gemm_nt`]: each chunk of the shared vector is loaded once
/// and multiplied against [`ROW_LANES`] rows from registers. Four rows ×
/// eight lanes keeps the accumulator working set (4 vector registers)
/// comfortably inside both SSE and NEON register files.
pub const ROW_LANES: usize = 4;

/// Reduce the lane accumulators in a fixed pairwise tree. One order,
/// everywhere — this is what makes batched and single-task paths agree
/// bitwise.
#[inline]
fn reduce(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// View a `LANES`-long chunk slice as a fixed-width array. The conversion
/// is free; what it buys is a compile-time length on every lane loop below
/// (no bounds checks, a known trip count for the vectorizer).
#[inline]
fn lanes(chunk: &[f32]) -> &[f32; LANES] {
    chunk.try_into().expect("chunk length == LANES")
}

/// Lane-accumulator dot product over equal-length slices.
///
/// Panics if the lengths differ (the backend validates dims before any
/// kernel call, so a mismatch here is a bug, not an input error).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let split = a.len() - a.len() % LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (b_main, b_tail) = b.split_at(split);
    let mut acc = [0f32; LANES];
    for (ca, cb) in a_main.chunks_exact(LANES).zip(b_main.chunks_exact(LANES)) {
        let (ca, cb) = (lanes(ca), lanes(cb));
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0f32;
    for (x, y) in a_tail.iter().zip(b_tail.iter()) {
        tail += x * y;
    }
    reduce(acc) + tail
}

/// `R` simultaneous dot products sharing one pass over `x`: each chunk of
/// `x` is loaded once and multiplied against the matching chunk of every
/// row. Per row, the multiply/accumulate sequence — chunk order, lane
/// assignment, the [`reduce`] tree, the scalar tail — is *exactly*
/// [`dot`]'s, so `dot_rows(rows, x)[r] == dot(rows[r], x)` bit for bit
/// (asserted by the unit tests below). Rows must all have `x`'s length.
#[inline]
fn dot_rows<const R: usize>(rows: [&[f32]; R], x: &[f32]) -> [f32; R] {
    for row in &rows {
        debug_assert_eq!(row.len(), x.len(), "dot_rows: length mismatch");
    }
    let split = x.len() - x.len() % LANES;
    let (x_main, x_tail) = x.split_at(split);
    let mut acc = [[0f32; LANES]; R];
    for (c, cx) in x_main.chunks_exact(LANES).enumerate() {
        let cx = lanes(cx);
        let base = c * LANES;
        for r in 0..R {
            let cr = lanes(&rows[r][base..base + LANES]);
            for l in 0..LANES {
                acc[r][l] += cr[l] * cx[l];
            }
        }
    }
    let mut out = [0f32; R];
    for r in 0..R {
        let mut tail = 0f32;
        for (v, y) in rows[r][split..].iter().zip(x_tail.iter()) {
            tail += v * y;
        }
        out[r] = reduce(acc[r]) + tail;
    }
    out
}

/// `out = A · x` for a row-major `rows × cols` matrix `A`.
///
/// [`ROW_LANES`] rows per pass through `x` via [`dot_rows`] (leftover rows
/// fall back to plain [`dot`]); every output element is bitwise a [`dot`]
/// of its row against `x`. `out` must hold exactly `rows` elements.
pub fn gemv(a: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), rows * cols, "gemv: matrix shape mismatch");
    assert_eq!(x.len(), cols, "gemv: input length mismatch");
    assert_eq!(out.len(), rows, "gemv: output length mismatch");
    if cols == 0 {
        out.fill(0.0); // keep the gemm_nt ≡ gemv-loop contract at k = 0
        return;
    }
    let mut r = 0;
    while r + ROW_LANES <= rows {
        let vals = dot_rows::<ROW_LANES>(
            core::array::from_fn(|t| &a[(r + t) * cols..(r + t + 1) * cols]),
            x,
        );
        out[r..r + ROW_LANES].copy_from_slice(&vals);
        r += ROW_LANES;
    }
    for rr in r..rows {
        out[rr] = dot(&a[rr * cols..(rr + 1) * cols], x);
    }
}

/// `out[n × m] = X[n × k] · W[m × k]ᵀ` — the batched classifier/LSH GEMM.
///
/// `X` is task-major (one task's feature vector per row), `W` is the flat
/// weight matrix. Blocked over [`GEMM_ROW_BLOCK`] input rows so `W`
/// streams once per block instead of once per task. Each output element is
/// computed by [`dot`], so the result is bitwise identical to calling
/// [`gemv`] per input row.
pub fn gemm_nt(x: &[f32], n: usize, w: &[f32], m: usize, k: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * k, "gemm_nt: input shape mismatch");
    assert_eq!(w.len(), m * k, "gemm_nt: weight shape mismatch");
    assert_eq!(out.len(), n * m, "gemm_nt: output shape mismatch");
    if n == 0 || m == 0 {
        return; // out is empty by the shape contract
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    for (xb, ob) in x
        .chunks(GEMM_ROW_BLOCK * k)
        .zip(out.chunks_mut(GEMM_ROW_BLOCK * m))
    {
        let rows_in_block = xb.len() / k;
        for (j, wrow) in w.chunks_exact(k).enumerate() {
            let mut i = 0;
            while i + ROW_LANES <= rows_in_block {
                let vals = dot_rows::<ROW_LANES>(
                    core::array::from_fn(|t| &xb[(i + t) * k..(i + t + 1) * k]),
                    wrow,
                );
                for (t, &v) in vals.iter().enumerate() {
                    ob[(i + t) * m + j] = v;
                }
                i += ROW_LANES;
            }
            for ii in i..rows_in_block {
                ob[ii * m + j] = dot(&xb[ii * k..(ii + 1) * k], wrow);
            }
        }
    }
}

/// Index of the first maximum (ties keep the earliest index — the same
/// contract as the seed's scalar argmax over classifier scores).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dot_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| f64::from(x) * f64::from(y))
            .sum()
    }

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() - 0.5).collect()
    }

    #[test]
    fn dot_matches_f64_reference_across_lengths() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1024, 3072] {
            let a = randvec(&mut rng, n);
            let b = randvec(&mut rng, n);
            let got = f64::from(dot(&a, &b));
            let want = naive_dot_f64(&a, &b);
            let scale: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (f64::from(x) * f64::from(y)).abs())
                .sum::<f64>()
                + 1.0;
            assert!(
                (got - want).abs() <= 1e-4 * scale,
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dot_rows_is_bitwise_per_row_dot() {
        let mut rng = Rng::new(17);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 257, 3072] {
            let rows: Vec<Vec<f32>> = (0..ROW_LANES).map(|_| randvec(&mut rng, n)).collect();
            let x = randvec(&mut rng, n);
            let refs: [&[f32]; ROW_LANES] = core::array::from_fn(|t| rows[t].as_slice());
            let got = dot_rows::<ROW_LANES>(refs, &x);
            for (r, row) in rows.iter().enumerate() {
                assert_eq!(
                    got[r].to_bits(),
                    dot(row, &x).to_bits(),
                    "n={n} row={r}"
                );
            }
        }
    }

    #[test]
    fn gemv_is_per_row_dot() {
        let mut rng = Rng::new(12);
        let (rows, cols) = (5, 129);
        let a = randvec(&mut rng, rows * cols);
        let x = randvec(&mut rng, cols);
        let mut out = vec![0f32; rows];
        gemv(&a, rows, cols, &x, &mut out);
        for (r, &o) in out.iter().enumerate() {
            let d = dot(&a[r * cols..(r + 1) * cols], &x);
            assert_eq!(o.to_bits(), d.to_bits(), "row {r}");
        }
    }

    #[test]
    fn gemm_bitwise_matches_gemv_loop() {
        let mut rng = Rng::new(13);
        // deliberately not a multiple of the row block
        let (n, m, k) = (11, 3, 257);
        let x = randvec(&mut rng, n * k);
        let w = randvec(&mut rng, m * k);
        let mut got = vec![0f32; n * m];
        gemm_nt(&x, n, &w, m, k, &mut got);
        let mut want = vec![0f32; n * m];
        for i in 0..n {
            gemv(&w, m, k, &x[i * k..(i + 1) * k], &mut want[i * m..(i + 1) * m]);
        }
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn gemm_handles_empty_and_degenerate_shapes() {
        let mut out = vec![0f32; 0];
        gemm_nt(&[], 0, &[], 0, 4, &mut out);
        let mut out = vec![1f32; 6];
        gemm_nt(&[], 2, &[], 3, 0, &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "k=0 zeroes the output");
        let mut out = vec![1f32; 3];
        gemv(&[], 3, 0, &[], &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "gemv matches gemm at k=0");
    }

    #[test]
    fn argmax_prefers_first_of_equal_maxima() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[0.5]), 0);
    }
}
