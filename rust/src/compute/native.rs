//! Pure-Rust compute backend.
//!
//! Implements the same pipeline as the artifacts — 2×2 mean-pool resize,
//! BT.601 grayscale, FALCONN-style hyperplane LSH, global SSIM (eq. 12) —
//! plus a seeded random-projection classifier standing in for the baked
//! MicroGoogLeNet. It exists for three reasons: fast unit tests of the
//! coordinator, ablation sweeps that don't need PJRT, and a numeric
//! cross-check of the artifacts in the integration suite.
//!
//! The LSH hyperplanes and the classifier projection are stored as flat
//! row-major matrices and evaluated through the blocked kernels in
//! [`crate::compute::kernels`]; the batched entry points
//! ([`ComputeBackend::classify_many`], [`ComputeBackend::lsh_bucket_many`])
//! run a real GEMM over a task-major input matrix and are bitwise
//! identical to the single-task paths (the kernels share one dot-product
//! reduction order).

use crate::compute::kernels::{argmax, gemm_nt, gemv};
use crate::compute::{ComputeBackend, Preprocessed};
use crate::config::SimConfig;
use crate::error::{Error, Result};
use crate::util::rng::Rng;
use crate::workload::ImageData;

// Same SSIM constants as python/compile/kernels/ssim.py (L = 1).
const C1: f64 = 0.01 * 0.01;
const C2: f64 = 0.03 * 0.03;
const C3: f64 = C2 / 2.0;

/// Seed for the hyperplanes; independent from the artifact's PRNGKey(7) —
/// the two backends implement the same *family*, not bit-equal hashes.
const LSH_SEED: u64 = 0x5a7e111e;
/// Seed for the classifier projection.
const CLS_SEED: u64 = 0xc1a551f7;

/// Tasks per GEMM block in the batched entry points: 64 × 3072 floats of
/// input (≈ 768 KiB) amortise the weight-matrix traffic without blowing
/// the cache.
const BATCH: usize = 64;

/// Hard cap on `p_k` so LSH projections fit a stack buffer (the config
/// layer validates `p_k ∈ [1, 16]` already).
const MAX_PLANES: usize = 16;

/// Pure-Rust backend.
pub struct NativeBackend {
    pre_h: usize,
    pre_w: usize,
    p_k: usize,
    feature_dim: usize,
    num_classes: usize,
    /// `p_k × feature_dim` Gaussian hyperplanes, flat row-major.
    planes: Vec<f32>,
    /// `num_classes × feature_dim` classifier projection, flat row-major.
    proj: Vec<f32>,
}

impl NativeBackend {
    pub fn new(cfg: &SimConfig) -> Self {
        // pre dims = raw dims / 2 (the artifact's 2x2 mean pool)
        let pre_h = cfg.workload.raw_h / 2;
        let pre_w = cfg.workload.raw_w / 2;
        let feature_dim = pre_h * pre_w * 3;
        let p_k = cfg.reuse.p_k;
        assert!(p_k <= MAX_PLANES, "p_k {p_k} exceeds {MAX_PLANES}");
        let num_classes = cfg.workload.num_classes;
        let mut lsh_rng = Rng::new(LSH_SEED);
        let planes = (0..p_k * feature_dim)
            .map(|_| lsh_rng.normal() as f32)
            .collect();
        let mut cls_rng = Rng::new(CLS_SEED);
        let proj = (0..num_classes * feature_dim)
            .map(|_| cls_rng.normal() as f32)
            .collect();
        NativeBackend {
            pre_h,
            pre_w,
            p_k,
            feature_dim,
            num_classes,
            planes,
            proj,
        }
    }

    fn check_dims(&self, pre: &Preprocessed) -> Result<()> {
        if pre.h != self.pre_h || pre.w != self.pre_w {
            return Err(Error::simulation(format!(
                "preprocessed dims {}x{} != backend {}x{}",
                pre.h, pre.w, self.pre_h, self.pre_w
            )));
        }
        Ok(())
    }

    /// MSB-first bucket id from the signs of the plane projections.
    fn bucket_from_projections(&self, dots: &[f32]) -> u32 {
        let mut bucket = 0u32;
        for (i, &d) in dots.iter().enumerate() {
            if d >= 0.0 {
                bucket |= 1 << (self.p_k - 1 - i);
            }
        }
        bucket
    }
}

/// Global SSIM per eq. (12); exposed for tests and the SCRT module.
///
/// Returns [`Error::Simulation`] when the planes have different lengths
/// (the seed version `assert_eq!`-panicked, which took the whole run down
/// on a malformed record instead of surfacing a recoverable error).
pub fn ssim_global(a: &[f32], b: &[f32]) -> Result<f32> {
    if a.len() != b.len() {
        return Err(Error::simulation(format!(
            "ssim_global: mismatched plane lengths {} vs {}",
            a.len(),
            b.len()
        )));
    }
    let n = a.len() as f64;
    let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0f64, 0f64, 0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        let (x, y) = (x as f64, y as f64);
        sa += x;
        sb += y;
        saa += x * x;
        sbb += y * y;
        sab += x * y;
    }
    let ma = sa / n;
    let mb = sb / n;
    let va = (saa / n - ma * ma).max(0.0);
    let vb = (sbb / n - mb * mb).max(0.0);
    let cov = sab / n - ma * mb;
    let lum = (2.0 * ma * mb + C1) / (ma * ma + mb * mb + C1);
    let con = (2.0 * va.sqrt() * vb.sqrt() + C2) / (va + vb + C2);
    let stru = (cov + C3) / (va.sqrt() * vb.sqrt() + C3);
    Ok((lum * con * stru) as f32)
}

impl ComputeBackend for NativeBackend {
    fn preprocess(&self, raw: &ImageData) -> Result<Preprocessed> {
        if raw.h != self.pre_h * 2 || raw.w != self.pre_w * 2 {
            return Err(Error::simulation(format!(
                "raw dims {}x{} incompatible with backend {}x{}",
                raw.h, raw.w, self.pre_h, self.pre_w
            )));
        }
        let (h, w) = (self.pre_h, self.pre_w);
        let mut pd = vec![0f32; h * w * 3];
        let mut gray = vec![0f32; h * w];
        // One fused pass: 2×2 mean pool + normalise + BT.601 grayscale,
        // walking two flat raw rows per output row (no per-pixel index
        // arithmetic). The arithmetic order matches the seed exactly, so
        // pd/gray are bit-identical to the unfused version.
        let raw_row = raw.w * 3;
        for y in 0..h {
            let r0 = &raw.pixels[2 * y * raw_row..(2 * y + 1) * raw_row];
            let r1 = &raw.pixels[(2 * y + 1) * raw_row..(2 * y + 2) * raw_row];
            let pd_row = &mut pd[y * w * 3..(y + 1) * w * 3];
            let gray_row = &mut gray[y * w..(y + 1) * w];
            for x in 0..w {
                let o = 6 * x;
                let r = (r0[o] + r0[o + 3] + r1[o] + r1[o + 3]) / 4.0 / 255.0;
                let g = (r0[o + 1] + r0[o + 4] + r1[o + 1] + r1[o + 4]) / 4.0 / 255.0;
                let b = (r0[o + 2] + r0[o + 5] + r1[o + 2] + r1[o + 5]) / 4.0 / 255.0;
                pd_row[3 * x] = r;
                pd_row[3 * x + 1] = g;
                pd_row[3 * x + 2] = b;
                gray_row[x] = 0.299 * r + 0.587 * g + 0.114 * b;
            }
        }
        Ok(Preprocessed { h, w, pd, gray })
    }

    fn lsh_bucket(&self, pre: &Preprocessed) -> Result<u32> {
        self.check_dims(pre)?;
        let mut dots = [0f32; MAX_PLANES];
        gemv(
            &self.planes,
            self.p_k,
            self.feature_dim,
            &pre.pd,
            &mut dots[..self.p_k],
        );
        Ok(self.bucket_from_projections(&dots[..self.p_k]))
    }

    fn ssim(&self, a: &Preprocessed, b: &Preprocessed) -> Result<f32> {
        self.check_dims(a)?;
        self.check_dims(b)?;
        ssim_global(&a.gray, &b.gray)
    }

    fn classify(&self, pre: &Preprocessed) -> Result<u32> {
        self.check_dims(pre)?;
        let mut scores = vec![0f32; self.num_classes];
        gemv(
            &self.proj,
            self.num_classes,
            self.feature_dim,
            &pre.pd,
            &mut scores,
        );
        Ok(argmax(&scores) as u32)
    }

    /// Batched classify: one GEMM per `BATCH`-task block over a
    /// task-major input matrix. Bitwise identical to mapping
    /// [`ComputeBackend::classify`] (shared kernel reduction order).
    fn classify_many(&self, pres: &[&Preprocessed]) -> Result<Vec<u32>> {
        for p in pres {
            self.check_dims(p)?;
        }
        let k = self.feature_dim;
        let m = self.num_classes;
        let mut labels = Vec::with_capacity(pres.len());
        let mut x = vec![0f32; BATCH.min(pres.len()) * k];
        let mut scores = vec![0f32; BATCH.min(pres.len()) * m];
        for chunk in pres.chunks(BATCH) {
            let n = chunk.len();
            for (row, p) in x.chunks_exact_mut(k).zip(chunk) {
                row.copy_from_slice(&p.pd);
            }
            gemm_nt(&x[..n * k], n, &self.proj, m, k, &mut scores[..n * m]);
            labels.extend(scores[..n * m].chunks_exact(m).map(|row| argmax(row) as u32));
        }
        Ok(labels)
    }

    /// Batched LSH: the same GEMM against the hyperplane matrix.
    fn lsh_bucket_many(&self, pres: &[&Preprocessed]) -> Result<Vec<u32>> {
        for p in pres {
            self.check_dims(p)?;
        }
        let k = self.feature_dim;
        let m = self.p_k;
        let mut buckets = Vec::with_capacity(pres.len());
        let mut x = vec![0f32; BATCH.min(pres.len()) * k];
        let mut dots = vec![0f32; BATCH.min(pres.len()) * m];
        for chunk in pres.chunks(BATCH) {
            let n = chunk.len();
            for (row, p) in x.chunks_exact_mut(k).zip(chunk) {
                row.copy_from_slice(&p.pd);
            }
            gemm_nt(&x[..n * k], n, &self.planes, m, k, &mut dots[..n * m]);
            buckets.extend(
                dots[..n * m]
                    .chunks_exact(m)
                    .map(|row| self.bucket_from_projections(row)),
            );
        }
        Ok(buckets)
    }

    fn num_buckets(&self) -> usize {
        1 << self.p_k
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn backend() -> NativeBackend {
        NativeBackend::new(&SimConfig::paper_default(5))
    }

    fn image(seed: u64) -> ImageData {
        let mut rng = Rng::new(seed);
        let px = (0..64 * 64 * 3).map(|_| rng.f32() * 255.0).collect();
        ImageData::new(64, 64, px)
    }

    #[test]
    fn preprocess_mean_pool() {
        let b = backend();
        // constant image -> constant pd at v/255
        let img = ImageData::new(64, 64, vec![100.0; 64 * 64 * 3]);
        let pre = b.preprocess(&img).unwrap();
        assert!(pre.pd.iter().all(|&x| (x - 100.0 / 255.0).abs() < 1e-6));
        let g = 100.0 / 255.0; // gray of equal channels = same value
        assert!(pre.gray.iter().all(|&x| (x - g).abs() < 1e-5));
    }

    #[test]
    fn preprocess_rejects_wrong_dims() {
        let b = backend();
        let img = ImageData::new(16, 16, vec![0.0; 16 * 16 * 3]);
        assert!(b.preprocess(&img).is_err());
    }

    #[test]
    fn ssim_global_matches_identity_and_bounds() {
        let xs: Vec<f32> = (0..1024).map(|i| (i % 97) as f32 / 97.0).collect();
        assert!((ssim_global(&xs, &xs).unwrap() - 1.0).abs() < 1e-6);
        let ys: Vec<f32> = xs.iter().map(|x| 1.0 - x).collect();
        let v = ssim_global(&xs, &ys).unwrap();
        assert!((-1.0..1.0).contains(&v));
        assert!(v < 0.5, "anti-correlated ssim {v}");
    }

    #[test]
    fn ssim_global_rejects_mismatched_lengths() {
        // Regression: the seed version `assert_eq!`-panicked here.
        let a = vec![0.5f32; 16];
        let b = vec![0.5f32; 15];
        let err = ssim_global(&a, &b).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("mismatched plane lengths"),
            "unexpected error: {msg}"
        );
        assert!(msg.contains("16") && msg.contains("15"), "{msg}");
    }

    #[test]
    fn buckets_cover_range() {
        let b = backend();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..32 {
            let pre = b.preprocess(&image(seed)).unwrap();
            let bucket = b.lsh_bucket(&pre).unwrap();
            assert!((bucket as usize) < b.num_buckets());
            seen.insert(bucket);
        }
        assert!(seen.len() >= 2, "only {} buckets used", seen.len());
    }

    #[test]
    fn classifier_labels_in_range_and_varied() {
        let b = backend();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..48 {
            let pre = b.preprocess(&image(seed)).unwrap();
            let label = b.classify(&pre).unwrap();
            assert!((label as usize) < 21);
            seen.insert(label);
        }
        assert!(seen.len() >= 3, "labels too concentrated: {seen:?}");
    }

    #[test]
    fn small_perturbation_keeps_label_and_bucket() {
        let b = backend();
        let img = image(7);
        let mut img2 = img.clone();
        for p in img2.pixels.iter_mut() {
            *p = (*p + 0.5).min(255.0);
        }
        let p1 = b.preprocess(&img).unwrap();
        let p2 = b.preprocess(&img2).unwrap();
        assert_eq!(b.classify(&p1).unwrap(), b.classify(&p2).unwrap());
        assert_eq!(b.lsh_bucket(&p1).unwrap(), b.lsh_bucket(&p2).unwrap());
        assert!(b.ssim(&p1, &p2).unwrap() > 0.99);
    }

    #[test]
    fn batched_paths_match_single_task_paths_bitwise() {
        let b = backend();
        let pres: Vec<Preprocessed> = (0..7)
            .map(|seed| b.preprocess(&image(100 + seed)).unwrap())
            .collect();
        let refs: Vec<&Preprocessed> = pres.iter().collect();
        let many_labels = b.classify_many(&refs).unwrap();
        let many_buckets = b.lsh_bucket_many(&refs).unwrap();
        for (i, p) in pres.iter().enumerate() {
            assert_eq!(many_labels[i], b.classify(p).unwrap(), "label {i}");
            assert_eq!(many_buckets[i], b.lsh_bucket(p).unwrap(), "bucket {i}");
        }
        // empty batches are fine
        assert!(b.classify_many(&[]).unwrap().is_empty());
        assert!(b.lsh_bucket_many(&[]).unwrap().is_empty());
    }
}
