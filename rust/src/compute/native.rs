//! Pure-Rust compute backend.
//!
//! Implements the same pipeline as the artifacts — 2×2 mean-pool resize,
//! BT.601 grayscale, FALCONN-style hyperplane LSH, global SSIM (eq. 12) —
//! plus a seeded random-projection classifier standing in for the baked
//! MicroGoogLeNet. It exists for three reasons: fast unit tests of the
//! coordinator, ablation sweeps that don't need PJRT, and a numeric
//! cross-check of the artifacts in the integration suite.

use crate::compute::{ComputeBackend, Preprocessed};
use crate::config::SimConfig;
use crate::error::{Error, Result};
use crate::util::rng::Rng;
use crate::workload::ImageData;

// Same SSIM constants as python/compile/kernels/ssim.py (L = 1).
const C1: f64 = 0.01 * 0.01;
const C2: f64 = 0.03 * 0.03;
const C3: f64 = C2 / 2.0;

/// Seed for the hyperplanes; independent from the artifact's PRNGKey(7) —
/// the two backends implement the same *family*, not bit-equal hashes.
const LSH_SEED: u64 = 0x5a7e111e;
/// Seed for the classifier projection.
const CLS_SEED: u64 = 0xc1a551f7;

/// Pure-Rust backend.
pub struct NativeBackend {
    pre_h: usize,
    pre_w: usize,
    p_k: usize,
    /// `p_k × feature_dim` Gaussian hyperplanes.
    planes: Vec<Vec<f32>>,
    /// `num_classes × feature_dim` classifier projection.
    proj: Vec<Vec<f32>>,
}

impl NativeBackend {
    pub fn new(cfg: &SimConfig) -> Self {
        // pre dims = raw dims / 2 (the artifact's 2x2 mean pool)
        let pre_h = cfg.workload.raw_h / 2;
        let pre_w = cfg.workload.raw_w / 2;
        let feature_dim = pre_h * pre_w * 3;
        let p_k = cfg.reuse.p_k;
        let mut lsh_rng = Rng::new(LSH_SEED);
        let planes = (0..p_k)
            .map(|_| (0..feature_dim).map(|_| lsh_rng.normal() as f32).collect())
            .collect();
        let mut cls_rng = Rng::new(CLS_SEED);
        let proj = (0..cfg.workload.num_classes)
            .map(|_| (0..feature_dim).map(|_| cls_rng.normal() as f32).collect())
            .collect();
        NativeBackend {
            pre_h,
            pre_w,
            p_k,
            planes,
            proj,
        }
    }

    fn check_dims(&self, pre: &Preprocessed) -> Result<()> {
        if pre.h != self.pre_h || pre.w != self.pre_w {
            return Err(Error::simulation(format!(
                "preprocessed dims {}x{} != backend {}x{}",
                pre.h, pre.w, self.pre_h, self.pre_w
            )));
        }
        Ok(())
    }
}

/// Global SSIM per eq. (12); exposed for tests and the SCRT module.
pub fn ssim_global(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0f64, 0f64, 0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        let (x, y) = (x as f64, y as f64);
        sa += x;
        sb += y;
        saa += x * x;
        sbb += y * y;
        sab += x * y;
    }
    let ma = sa / n;
    let mb = sb / n;
    let va = (saa / n - ma * ma).max(0.0);
    let vb = (sbb / n - mb * mb).max(0.0);
    let cov = sab / n - ma * mb;
    let lum = (2.0 * ma * mb + C1) / (ma * ma + mb * mb + C1);
    let con = (2.0 * va.sqrt() * vb.sqrt() + C2) / (va + vb + C2);
    let stru = (cov + C3) / (va.sqrt() * vb.sqrt() + C3);
    (lum * con * stru) as f32
}

impl ComputeBackend for NativeBackend {
    fn preprocess(&self, raw: &ImageData) -> Result<Preprocessed> {
        if raw.h != self.pre_h * 2 || raw.w != self.pre_w * 2 {
            return Err(Error::simulation(format!(
                "raw dims {}x{} incompatible with backend {}x{}",
                raw.h, raw.w, self.pre_h, self.pre_w
            )));
        }
        let (h, w) = (self.pre_h, self.pre_w);
        let mut pd = vec![0f32; h * w * 3];
        let mut gray = vec![0f32; h * w];
        for y in 0..h {
            for x in 0..w {
                let mut px = [0f32; 3];
                for c in 0..3 {
                    let sum = raw.at(2 * y, 2 * x, c)
                        + raw.at(2 * y, 2 * x + 1, c)
                        + raw.at(2 * y + 1, 2 * x, c)
                        + raw.at(2 * y + 1, 2 * x + 1, c);
                    px[c] = sum / 4.0 / 255.0;
                    pd[(y * w + x) * 3 + c] = px[c];
                }
                gray[y * w + x] = 0.299 * px[0] + 0.587 * px[1] + 0.114 * px[2];
            }
        }
        Ok(Preprocessed { h, w, pd, gray })
    }

    fn lsh_bucket(&self, pre: &Preprocessed) -> Result<u32> {
        self.check_dims(pre)?;
        let mut bucket = 0u32;
        for (i, plane) in self.planes.iter().enumerate() {
            let dot: f32 = plane.iter().zip(&pre.pd).map(|(p, x)| p * x).sum();
            if dot >= 0.0 {
                bucket |= 1 << (self.p_k - 1 - i);
            }
        }
        Ok(bucket)
    }

    fn ssim(&self, a: &Preprocessed, b: &Preprocessed) -> Result<f32> {
        self.check_dims(a)?;
        self.check_dims(b)?;
        Ok(ssim_global(&a.gray, &b.gray))
    }

    fn classify(&self, pre: &Preprocessed) -> Result<u32> {
        self.check_dims(pre)?;
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for (c, row) in self.proj.iter().enumerate() {
            let score: f32 = row.iter().zip(&pre.pd).map(|(w, x)| w * x).sum();
            if score > best_score {
                best_score = score;
                best = c;
            }
        }
        Ok(best as u32)
    }

    fn num_buckets(&self) -> usize {
        1 << self.p_k
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn backend() -> NativeBackend {
        NativeBackend::new(&SimConfig::paper_default(5))
    }

    fn image(seed: u64) -> ImageData {
        let mut rng = Rng::new(seed);
        let px = (0..64 * 64 * 3).map(|_| rng.f32() * 255.0).collect();
        ImageData::new(64, 64, px)
    }

    #[test]
    fn preprocess_mean_pool() {
        let b = backend();
        // constant image -> constant pd at v/255
        let img = ImageData::new(64, 64, vec![100.0; 64 * 64 * 3]);
        let pre = b.preprocess(&img).unwrap();
        assert!(pre.pd.iter().all(|&x| (x - 100.0 / 255.0).abs() < 1e-6));
        let g = 100.0 / 255.0; // gray of equal channels = same value
        assert!(pre.gray.iter().all(|&x| (x - g).abs() < 1e-5));
    }

    #[test]
    fn preprocess_rejects_wrong_dims() {
        let b = backend();
        let img = ImageData::new(16, 16, vec![0.0; 16 * 16 * 3]);
        assert!(b.preprocess(&img).is_err());
    }

    #[test]
    fn ssim_global_matches_identity_and_bounds() {
        let xs: Vec<f32> = (0..1024).map(|i| (i % 97) as f32 / 97.0).collect();
        assert!((ssim_global(&xs, &xs) - 1.0).abs() < 1e-6);
        let ys: Vec<f32> = xs.iter().map(|x| 1.0 - x).collect();
        let v = ssim_global(&xs, &ys);
        assert!((-1.0..1.0).contains(&v));
        assert!(v < 0.5, "anti-correlated ssim {v}");
    }

    #[test]
    fn buckets_cover_range() {
        let b = backend();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..32 {
            let pre = b.preprocess(&image(seed)).unwrap();
            let bucket = b.lsh_bucket(&pre).unwrap();
            assert!((bucket as usize) < b.num_buckets());
            seen.insert(bucket);
        }
        assert!(seen.len() >= 2, "only {} buckets used", seen.len());
    }

    #[test]
    fn classifier_labels_in_range_and_varied() {
        let b = backend();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..48 {
            let pre = b.preprocess(&image(seed)).unwrap();
            let label = b.classify(&pre).unwrap();
            assert!((label as usize) < 21);
            seen.insert(label);
        }
        assert!(seen.len() >= 3, "labels too concentrated: {seen:?}");
    }

    #[test]
    fn small_perturbation_keeps_label_and_bucket() {
        let b = backend();
        let img = image(7);
        let mut img2 = img.clone();
        for p in img2.pixels.iter_mut() {
            *p = (*p + 0.5).min(255.0);
        }
        let p1 = b.preprocess(&img).unwrap();
        let p2 = b.preprocess(&img2).unwrap();
        assert_eq!(b.classify(&p1).unwrap(), b.classify(&p2).unwrap());
        assert_eq!(b.lsh_bucket(&p1).unwrap(), b.lsh_bucket(&p2).unwrap());
        assert!(b.ssim(&p1, &p2).unwrap() > 0.99);
    }
}
