//! TOML-subset parser for the config files.
//!
//! Supported grammar — sections, scalar assignments, comments:
//!
//! ```toml
//! # comment
//! [section]
//! int_key = 42
//! float_key = 2.5e9
//! bool_key = true
//! str_key = "hello"
//! ```
//!
//! That subset covers every key [`crate::config::SimConfig`] accepts; arrays
//! and nested tables are intentionally rejected so typos fail loudly.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A scalar config value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Int(x) => Ok(*x as f64),
            TomlValue::Float(x) => Ok(*x),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(x) if *x >= 0 => Ok(*x as u64),
            other => Err(Error::Config(format!(
                "expected unsigned integer, got {other:?}"
            ))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }
}

/// Parsed document: `(section, key) -> value`, insertion-ordered per section.
#[derive(Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn iter(&self) -> impl Iterator<Item = ((&str, &str), &TomlValue)> {
        self.entries
            .iter()
            .map(|((s, k), v)| ((s.as_str(), k.as_str()), v))
    }
}

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let at = |m: &str| Error::Config(format!("line {}: {m}", lineno + 1));
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| at("unterminated section header"))?;
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(at(&format!("bad section name '{name}'")));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| at("expected 'key = value'"))?;
        let key = key.trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(at(&format!("bad key '{key}'")));
        }
        if section.is_empty() {
            return Err(at("key outside any [section]"));
        }
        let value = parse_value(value.trim()).map_err(|m| at(&m))?;
        let prev = doc
            .entries
            .insert((section.clone(), key.to_string()), value);
        if prev.is_some() {
            return Err(at(&format!("duplicate key '{key}' in [{section}]")));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string stays; otherwise truncate.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote in {s}"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    // underscore separators allowed in numbers, as in real TOML
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
# top comment
[network]
n = 7              # inline comment
dist = 1.1e6

[reuse]
tau = 11
enabled = true
label = "sccr"
big = 1_000_000
"#,
        )
        .unwrap();
        assert_eq!(doc.get("network", "n"), Some(&TomlValue::Int(7)));
        assert_eq!(doc.get("network", "dist"), Some(&TomlValue::Float(1.1e6)));
        assert_eq!(doc.get("reuse", "enabled"), Some(&TomlValue::Bool(true)));
        assert_eq!(
            doc.get("reuse", "label"),
            Some(&TomlValue::Str("sccr".into()))
        );
        assert_eq!(doc.get("reuse", "big"), Some(&TomlValue::Int(1_000_000)));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated\nx = 1").is_err());
        assert!(parse("x = 1").is_err()); // key outside section
        assert!(parse("[s]\nx 1").is_err()); // no '='
        assert!(parse("[s]\nx = ").is_err()); // empty value
        assert!(parse("[s]\nx = 1\nx = 2").is_err()); // duplicate
        assert!(parse("[s]\nx = \"open").is_err()); // unterminated string
    }

    #[test]
    fn hash_in_string_kept() {
        let doc = parse("[s]\nx = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s", "x"), Some(&TomlValue::Str("a#b".into())));
    }

    #[test]
    fn value_conversions() {
        assert_eq!(TomlValue::Int(3).as_f64().unwrap(), 3.0);
        assert_eq!(TomlValue::Int(3).as_usize().unwrap(), 3);
        assert!(TomlValue::Int(-1).as_u64().is_err());
        assert!(TomlValue::Str("x".into()).as_f64().is_err());
        assert!(TomlValue::Bool(true).as_bool().unwrap());
    }
}
