//! Configuration system.
//!
//! [`SimConfig`] carries every knob of the reproduction: the paper's Table I
//! parameters, the communication model constants (eqs. 1–4), the analytic
//! cost model (eqs. 6–9), the workload generator and the cache budget.
//! Configs load from a TOML-subset file (`configs/*.toml`) and validate
//! before use; [`SimConfig::paper_default`] reproduces Table I exactly.

mod parser;

pub use parser::TomlValue;

use crate::error::{Error, Result};

/// Network / constellation geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Grid scale N: N orbits × N satellites per orbit (paper: 5, 7, 9).
    pub n: usize,
    /// Inter-satellite distance within an orbital plane, metres.
    pub intra_plane_distance_m: f64,
    /// Inter-satellite distance across adjacent planes, metres.
    pub inter_plane_distance_m: f64,
}

/// ISL communication model (Table I + eqs. 1–4).
#[derive(Clone, Debug, PartialEq)]
pub struct CommConfig {
    /// Channel bandwidth `B_s`, Hz (paper: 20 MHz).
    pub bandwidth_hz: f64,
    /// Carrier frequency `f_c`, Hz (Ka-band ISL, 26 GHz per [31]).
    pub carrier_hz: f64,
    /// Transmit power `Pow_t`, watts.
    pub tx_power_w: f64,
    /// Antenna gain (both ends), dBi.
    pub antenna_gain_dbi: f64,
    /// Receiver noise temperature `T`, kelvin.
    pub noise_temp_k: f64,
    /// Record input payload `D_t`, bytes (UC Merced: 12 817 MB / 625 imgs).
    pub record_input_bytes: f64,
    /// Record output payload `R_t`, bytes (a label + metadata).
    pub record_output_bytes: f64,
    /// Per-chunk-attempt loss probability on the last ISL hop (0 = ideal
    /// links, the paper's assumption and the default).
    pub loss_prob: f64,
    /// Per-chunk-attempt corruption probability. A corrupted chunk is
    /// detected at the receiver and retransmitted exactly like a lost one;
    /// it differs only in still occupying the link.
    pub corrupt_prob: f64,
    /// Hard cap on any single ISL's throughput, bits/s. `INFINITY` (the
    /// default) leaves the link-budget rate (eq. 1) uncapped.
    pub link_bandwidth_bps: f64,
    /// Transfer chunk size, bytes. Records larger than this are split into
    /// ceil(record/chunk) content-addressed chunks. `INFINITY` (the
    /// default) sends each record as a single chunk — the legacy model.
    pub chunk_bytes: f64,
    /// Retransmission attempts after the first try before a chunk is
    /// dropped for good.
    pub max_retries: usize,
    /// Multiplicative backoff applied to the retransmission delay per
    /// failed attempt (>= 1).
    pub retry_backoff: f64,
}

impl CommConfig {
    /// `true` when any fault-model knob departs from the ideal-link
    /// defaults. The engines take the legacy (byte-for-byte identical)
    /// broadcast path when this is `false`, so loss = 0 runs reproduce
    /// pre-fault-model reports exactly.
    pub fn faults_active(&self) -> bool {
        self.loss_prob != 0.0
            || self.corrupt_prob != 0.0
            || self.link_bandwidth_bps.is_finite()
            || self.chunk_bytes.is_finite()
    }

    /// Validate the fault-model knobs, returning a message naming the
    /// offending value. Called by the engines (wrapped as
    /// `Error::Simulation`, beside the degenerate-lookahead rejection)
    /// rather than by `SimConfig::validate` — a nonsensical fault model is
    /// a property of the *simulation* the engines refuse to run, exactly
    /// like a lookahead the conservative window could never cross.
    pub fn fault_check(&self) -> std::result::Result<(), String> {
        let p = self.loss_prob;
        if !(p.is_finite() && (0.0..1.0).contains(&p)) {
            return Err(format!(
                "loss_prob={p} out of range: per-attempt loss probability \
                 must lie in [0, 1) — at 1.0 no chunk could ever arrive"
            ));
        }
        let c = self.corrupt_prob;
        if !(c.is_finite() && (0.0..1.0).contains(&c)) {
            return Err(format!(
                "corrupt_prob={c} out of range: per-attempt corruption \
                 probability must lie in [0, 1)"
            ));
        }
        if p + c >= 1.0 {
            return Err(format!(
                "loss_prob={p} + corrupt_prob={c} >= 1: every attempt \
                 would fail, so no chunk could ever arrive"
            ));
        }
        let bw = self.link_bandwidth_bps;
        if bw.is_nan() || bw <= 0.0 {
            return Err(format!(
                "link_bandwidth_bps={bw} out of range: the per-link \
                 bandwidth cap must be positive (INFINITY = uncapped)"
            ));
        }
        let ch = self.chunk_bytes;
        if ch.is_nan() || ch <= 0.0 {
            return Err(format!(
                "chunk_bytes={ch} out of range: the transfer chunk size \
                 must be positive (INFINITY = one chunk per record)"
            ));
        }
        let record = self.record_input_bytes + self.record_output_bytes;
        if ch.is_finite() && record / ch > 65_536.0 {
            return Err(format!(
                "chunk_bytes={ch} splits a {record}-byte record into more \
                 than 65536 chunks — raise the chunk size"
            ));
        }
        if self.max_retries > 64 {
            return Err(format!(
                "max_retries={} out of range: more than 64 retransmission \
                 attempts per chunk is never useful",
                self.max_retries
            ));
        }
        let bo = self.retry_backoff;
        if !(bo.is_finite() && bo >= 1.0) {
            return Err(format!(
                "retry_backoff={bo} out of range: the retransmission \
                 backoff factor must be finite and >= 1"
            ));
        }
        Ok(())
    }
}

/// Which contact-plan generator drives the topology over time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyMode {
    /// The paper's fixed grid: every ISL permanently up (the default).
    Static,
    /// Walker-shell geometry: inter-plane ISLs duty-cycle with orbital
    /// motion while intra-plane ISLs stay up (neighbours in one plane
    /// keep constant separation).
    Walker,
}

/// Walker shell phasing flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalkerKind {
    /// Walker-delta: planes spread their inter-plane contact windows over
    /// the full orbital period.
    Delta,
    /// Walker-star: near-polar planes, contact windows spread over half
    /// the period (seam-adjacent planes counter-rotate).
    Star,
}

/// One scripted ISL outage: the link between satellites `a` and `b` is
/// down on the absolute virtual-time interval `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OutageSpec {
    /// One endpoint of the grid link (satellite id).
    pub a: usize,
    /// The other endpoint (must be grid-adjacent to `a`).
    pub b: usize,
    /// Outage start, virtual seconds (inclusive).
    pub start: f64,
    /// Outage end, virtual seconds (exclusive).
    pub end: f64,
}

impl OutageSpec {
    /// Parse a scripted-outage list from its string encoding:
    /// `"a-b@start..end"` entries separated by commas, e.g.
    /// `"3-4@100..200,7-8@50..80"`. The string form is what keeps the
    /// TOML-subset parser scalar-only. An empty string is an empty list.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<OutageSpec>, String> {
        let mut out = Vec::new();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let bad = || format!("outage '{entry}' is not 'a-b@start..end'");
            let (link, span) = entry.split_once('@').ok_or_else(bad)?;
            let (a, b) = link.split_once('-').ok_or_else(bad)?;
            let (start, end) = span.split_once("..").ok_or_else(bad)?;
            out.push(OutageSpec {
                a: a.trim().parse().map_err(|_| bad())?,
                b: b.trim().parse().map_err(|_| bad())?,
                start: start.trim().parse().map_err(|_| bad())?,
                end: end.trim().parse().map_err(|_| bad())?,
            });
        }
        Ok(out)
    }
}

/// Time-varying topology model (contact plans). The defaults describe the
/// paper's static always-on grid, which the engines treat as a degenerate
/// contact plan — see [`TopologyConfig::is_dynamic`].
#[derive(Clone, Debug, PartialEq)]
pub struct TopologyConfig {
    /// Plan generator: static grid or Walker shell.
    pub mode: TopologyMode,
    /// Walker phasing flavour (delta or star).
    pub kind: WalkerKind,
    /// Orbital period driving the inter-plane duty cycle, seconds.
    pub period_s: f64,
    /// Fraction of each period an inter-plane ISL is up, in (0, 1].
    /// `1.0` = always on (degenerate, reproduces the static grid).
    pub duty: f64,
    /// Walker phasing parameter F: how far consecutive planes' contact
    /// windows are offset from each other.
    pub phasing: usize,
    /// Rate multiplier applied to inter-plane links while the plan is
    /// dynamic, in (0, 1]. Slowing-only by construction: the conservative
    /// lookahead stays sound because effective edge times only grow.
    pub inter_rate_scale: f64,
    /// Extra per-chunk latency on inter-plane links while the plan is
    /// dynamic, seconds (>= 0; same slowing-only contract).
    pub inter_extra_latency_s: f64,
    /// Scripted absolute link outages.
    pub outages: Vec<OutageSpec>,
    /// Number of ground stations. During a ground-station pass the
    /// satellite's single radio points down: all its ISLs are suppressed.
    pub ground_stations: usize,
    /// Ground-pass recurrence period per (station, satellite), seconds.
    pub pass_period_s: f64,
    /// Fraction of each pass period a satellite spends in a pass, in
    /// [0, 1). `0` disables passes even with stations configured.
    pub pass_duty: f64,
    /// Declared Walker plane count; must equal the grid scale `n` when
    /// given (the reproduction only models square `n × n` shells).
    pub planes: Option<usize>,
    /// Declared satellites per plane; must equal `n` when given.
    pub sats_per_plane: Option<usize>,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            mode: TopologyMode::Static,
            kind: WalkerKind::Delta,
            period_s: 5400.0, // ~90 min LEO orbit
            duty: 1.0,
            phasing: 1,
            inter_rate_scale: 1.0,
            inter_extra_latency_s: 0.0,
            outages: Vec::new(),
            ground_stations: 0,
            pass_period_s: 5400.0,
            pass_duty: 0.05,
            planes: None,
            sats_per_plane: None,
        }
    }
}

impl TopologyConfig {
    /// `true` when the contact plan actually varies over time. The
    /// detection is *semantic*, not `mode == Walker`: a Walker config with
    /// `duty = 1`, no rate modifiers, no outages and no ground passes is
    /// an always-on plan, and the engines take the legacy static-grid
    /// broadcast path for it — which is what lets such a config reproduce
    /// pre-contact-plan goldens bit-for-bit (the degenerate-plan property
    /// test in `tests/properties.rs` pins exactly this).
    pub fn is_dynamic(&self) -> bool {
        !self.outages.is_empty()
            || (self.ground_stations > 0 && self.pass_duty > 0.0)
            || (self.mode == TopologyMode::Walker
                && (self.duty < 1.0
                    || self.inter_rate_scale != 1.0
                    || self.inter_extra_latency_s != 0.0))
    }

    /// Validate the topology knobs against grid scale `n`, returning a
    /// message naming the offending value. Engine-side like
    /// [`CommConfig::fault_check`] (wrapped as `Error::Simulation`): a
    /// nonsensical contact plan is a property of the *simulation* the
    /// engines refuse to run.
    pub fn check(&self, n: usize) -> std::result::Result<(), String> {
        let p = self.period_s;
        if !(p.is_finite() && p > 0.0) {
            return Err(format!(
                "topology period_s={p} out of range: the orbital period \
                 must be finite and positive"
            ));
        }
        let d = self.duty;
        if !(d.is_finite() && 0.0 < d && d <= 1.0) {
            return Err(format!(
                "topology duty={d} out of range: the inter-plane contact \
                 duty cycle must lie in (0, 1] — at 0 no inter-plane chunk \
                 could ever cross"
            ));
        }
        let s = self.inter_rate_scale;
        if !(s.is_finite() && 0.0 < s && s <= 1.0) {
            return Err(format!(
                "inter_rate_scale={s} out of range: the contact-window rate \
                 modifier must lie in (0, 1] — scaling a link *faster* than \
                 the link budget would break the conservative lookahead bound"
            ));
        }
        let l = self.inter_extra_latency_s;
        if !(l.is_finite() && l >= 0.0) {
            return Err(format!(
                "inter_extra_latency_s={l} out of range: extra contact \
                 latency must be finite and >= 0 (negative latency would \
                 break the conservative lookahead bound)"
            ));
        }
        if self.mode == TopologyMode::Static
            && (self.duty != 1.0
                || self.inter_rate_scale != 1.0
                || self.inter_extra_latency_s != 0.0)
        {
            return Err(format!(
                "topology duty={}/inter_rate_scale={}/inter_extra_latency_s={} \
                 have no effect in static mode — set mode = \"walker\"",
                self.duty, self.inter_rate_scale, self.inter_extra_latency_s
            ));
        }
        for spec in [self.planes, self.sats_per_plane].into_iter().flatten() {
            if spec != n {
                return Err(format!(
                    "topology planes/sats_per_plane={spec} != n={n}: this \
                     reproduction models square Walker shells only (planes \
                     = sats_per_plane = the grid scale n)"
                ));
            }
        }
        let sats = n * n;
        for o in &self.outages {
            if o.a >= sats || o.b >= sats {
                return Err(format!(
                    "outage {}-{} names a satellite outside the {n}x{n} grid",
                    o.a, o.b
                ));
            }
            let (ao, as_) = (o.a / n, o.a % n);
            let (bo, bs) = (o.b / n, o.b % n);
            let adjacent = (ao == bo && as_.abs_diff(bs) == 1)
                || (as_ == bs && ao.abs_diff(bo) == 1);
            if !adjacent {
                return Err(format!(
                    "outage {}-{} is not a grid ISL: only adjacent \
                     satellites share a link",
                    o.a, o.b
                ));
            }
            if !(o.start.is_finite() && o.end.is_finite() && o.start < o.end) {
                return Err(format!(
                    "outage {}-{}@{}..{} needs a finite interval with \
                     start < end",
                    o.a, o.b, o.start, o.end
                ));
            }
        }
        let pp = self.pass_period_s;
        if !(pp.is_finite() && pp > 0.0) {
            return Err(format!(
                "pass_period_s={pp} out of range: the ground-pass period \
                 must be finite and positive"
            ));
        }
        let pd = self.pass_duty;
        if !(pd.is_finite() && (0.0..1.0).contains(&pd)) {
            return Err(format!(
                "pass_duty={pd} out of range: the ground-pass duty cycle \
                 must lie in [0, 1) — at 1.0 a satellite would never \
                 rejoin the ISL mesh"
            ));
        }
        Ok(())
    }
}

/// One scripted node outage: satellite `sat` is down (crashed) on the
/// absolute virtual-time interval `[start, end)` and reboots at `end`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeOutageSpec {
    /// The crashing satellite (id).
    pub sat: usize,
    /// Crash instant, virtual seconds (inclusive).
    pub start: f64,
    /// Reboot instant, virtual seconds (exclusive).
    pub end: f64,
}

impl NodeOutageSpec {
    /// Parse a scripted node-outage list from its string encoding:
    /// `"sat@start..end"` entries separated by commas, e.g.
    /// `"7@100..200,12@50..80"`. The string form is what keeps the
    /// TOML-subset parser scalar-only (mirrors [`OutageSpec::parse_list`]).
    /// An empty string is an empty list.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<NodeOutageSpec>, String> {
        let mut out = Vec::new();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let bad = || format!("node outage '{entry}' is not 'sat@start..end'");
            let (sat, span) = entry.split_once('@').ok_or_else(bad)?;
            let (start, end) = span.split_once("..").ok_or_else(bad)?;
            out.push(NodeOutageSpec {
                sat: sat.trim().parse().map_err(|_| bad())?,
                start: start.trim().parse().map_err(|_| bad())?,
                end: end.trim().parse().map_err(|_| bad())?,
            });
        }
        Ok(out)
    }
}

/// Node-fault model: satellite crashes, reboots and the Alg. 2 failover
/// machinery. All defaults describe immortal satellites — the engines
/// take the legacy (byte-for-byte identical) paths when
/// [`FaultConfig::node_faults_active`] is `false`.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Mean time between random crashes per satellite, seconds.
    /// `INFINITY` (the default) disables random failures; crash gaps are
    /// drawn exponentially via the counter-hash so no fate depends on
    /// event interleaving.
    pub mtbf_s: f64,
    /// Downtime before a crashed satellite reboots, seconds.
    pub downtime_s: f64,
    /// `true`: the SCRT survives a reboot (persistent storage). `false`
    /// (default): a reboot is a cold start — the SCRT is wiped and the
    /// satellite rebuilds reuse state from scratch.
    pub scrt_persist: bool,
    /// Scripted absolute node outages (crash at `start`, reboot at `end`).
    pub node_outages: Vec<NodeOutageSpec>,
    /// Seconds a requester waits for a collaboration response before
    /// declaring the source dead and failing over.
    pub collab_timeout_s: f64,
    /// Failover re-selections after the first source attempt before the
    /// requester degrades to local compute.
    pub max_failover_retries: usize,
    /// Multiplicative backoff applied to the response timeout per failed
    /// failover attempt (>= 1).
    pub failover_backoff: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            mtbf_s: f64::INFINITY,
            downtime_s: 60.0,
            scrt_persist: false,
            node_outages: Vec::new(),
            collab_timeout_s: 5.0,
            max_failover_retries: 2,
            failover_backoff: 2.0,
        }
    }
}

impl FaultConfig {
    /// `true` when any knob can actually crash a satellite. The engines
    /// take the legacy (byte-for-byte identical) paths when this is
    /// `false`, so fault-free runs reproduce pre-fault-model reports
    /// exactly — the same gate shape as [`CommConfig::faults_active`].
    pub fn node_faults_active(&self) -> bool {
        self.mtbf_s.is_finite() || !self.node_outages.is_empty()
    }

    /// Validate the node-fault knobs against grid scale `n`, returning a
    /// message naming the offending value. Engine-side like
    /// [`CommConfig::fault_check`] (wrapped as `Error::Simulation`): a
    /// nonsensical fault model is a property of the *simulation* the
    /// engines refuse to run.
    pub fn node_fault_check(&self, n: usize) -> std::result::Result<(), String> {
        let m = self.mtbf_s;
        if m.is_nan() || m <= 0.0 {
            return Err(format!(
                "mtbf_s={m} out of range: the mean time between node \
                 failures must be positive (INFINITY = no random crashes)"
            ));
        }
        let d = self.downtime_s;
        if !(d.is_finite() && d > 0.0) {
            return Err(format!(
                "downtime_s={d} out of range: the reboot downtime must be \
                 finite and positive — a zero-length crash would be \
                 unobservable"
            ));
        }
        let t = self.collab_timeout_s;
        if !(t.is_finite() && t > 0.0) {
            return Err(format!(
                "collab_timeout_s={t} out of range: the failover response \
                 timeout must be finite and positive"
            ));
        }
        if self.max_failover_retries > 16 {
            return Err(format!(
                "max_failover_retries={} out of range: more than 16 \
                 failover re-selections per request is never useful",
                self.max_failover_retries
            ));
        }
        let bo = self.failover_backoff;
        if !(bo.is_finite() && bo >= 1.0) {
            return Err(format!(
                "failover_backoff={bo} out of range: the failover backoff \
                 factor must be finite and >= 1"
            ));
        }
        let sats = n * n;
        for o in &self.node_outages {
            if o.sat >= sats {
                return Err(format!(
                    "node outage sat={} outside the {n}x{n} grid",
                    o.sat
                ));
            }
            if !(o.start.is_finite() && o.end.is_finite() && o.start < o.end) {
                return Err(format!(
                    "node outage {}@{}..{} needs a finite interval with \
                     start < end",
                    o.sat, o.start, o.end
                ));
            }
        }
        Ok(())
    }
}

/// Analytic on-board computation cost model (eqs. 6–8).
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeConfig {
    /// Satellite computational capability `C^comp`, FLOP/s (paper: 3 GHz).
    pub capability_flops: f64,
    /// FLOPs to execute one task from scratch, `F_t` (GoogLeNet-22 scale).
    pub task_flops: f64,
    /// FLOPs of the lookup path `W` (preprocess + LSH probe + SSIM gate).
    pub lookup_flops: f64,
    /// Fixed per-lookup overhead, seconds (hash-table probe latency).
    pub lookup_fixed_s: f64,
}

/// Computation-reuse parameters (Table I).
#[derive(Clone, Debug, PartialEq)]
pub struct ReuseConfig {
    /// Number of LSH tables `p_l` (paper: 1).
    pub p_l: usize,
    /// Number of hash functions `p_k` (paper: 2).
    pub p_k: usize,
    /// Input similarity threshold `th_sim` (paper: 0.7).
    pub th_sim: f64,
    /// SRS weight `β` (paper: 0.5).
    pub beta: f64,
    /// Cooperation request threshold `th_co` (paper default: 0.5).
    pub th_co: f64,
    /// Records broadcast per collaboration `τ` (paper default: 11).
    pub tau: usize,
    /// Per-satellite SCRT storage `C^stg`, bytes.
    pub cache_bytes: f64,
    /// Minimum virtual seconds between collaboration requests from the same
    /// satellite (prevents request storms while SRS stays low).
    pub collab_cooldown_s: f64,
}

/// Synthetic remote-sensing workload (UC Merced stand-in).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Total tasks processed by the whole cluster (paper: 625 images).
    pub total_tasks: usize,
    /// Number of land-use classes (UC Merced: 21).
    pub num_classes: usize,
    /// Raw tile height/width, pixels (matches the L2 `preprocess` entry).
    pub raw_h: usize,
    pub raw_w: usize,
    /// Mean task arrival rate per satellite `λ`, tasks/s (M/M/1).
    pub arrival_rate_per_sat: f64,
    /// Per-image jitter amplitude inside one scene (0 = identical images).
    pub intra_scene_jitter: f64,
    /// Probability a satellite's next task repeats its previous scene
    /// (temporal locality of a ground track).
    pub scene_repeat_prob: f64,
    /// Per-satellite spread of the repeat probability: satellite i draws
    /// `scene_repeat_prob ± spread/2`. Ground tracks are heterogeneous
    /// (ocean passes are near-constant, coastal passes diverse); this is
    /// what creates the SRS contrast Alg. 2 exploits.
    pub repeat_prob_spread: f64,
    /// Number of distinct scenes per satellite ground track.
    pub scenes_per_satellite: usize,
    /// Probability of drawing the scene pool from the orbit-shared pool
    /// (spatial correlation between neighbouring satellites).
    pub shared_pool_prob: f64,
    /// Experiment seed.
    pub seed: u64,
}

/// Top-level simulation configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    pub network: NetworkConfig,
    pub comm: CommConfig,
    pub compute: ComputeConfig,
    pub reuse: ReuseConfig,
    pub workload: WorkloadConfig,
    /// Time-varying topology model (contact plans); defaults to the
    /// paper's static always-on grid.
    pub topology: TopologyConfig,
    /// Node-fault model (crashes, reboots, failover); defaults to
    /// immortal satellites.
    pub faults: FaultConfig,
    /// Binary weight α balancing communication vs computation cost (eq. 9).
    pub alpha: f64,
}

impl SimConfig {
    /// Table I defaults for an `n × n` network (paper: n ∈ {5, 7, 9}).
    pub fn paper_default(n: usize) -> Self {
        SimConfig {
            network: NetworkConfig {
                n,
                // A dense-constellation slice: ~1100 km in-plane separation,
                // ~800 km between adjacent planes (Leyva-Mayorga et al. [31]).
                intra_plane_distance_m: 1.1e6,
                inter_plane_distance_m: 0.8e6,
            },
            comm: CommConfig {
                bandwidth_hz: 20e6, // Table I
                carrier_hz: 26e9,
                tx_power_w: 10.0,
                antenna_gain_dbi: 37.0,
                noise_temp_k: 290.0,
                // 12 817 MB over 625 images ≈ 20.5 MB per record input.
                record_input_bytes: 12_817.0e6 / 625.0,
                record_output_bytes: 1024.0,
                loss_prob: 0.0,
                corrupt_prob: 0.0,
                link_bandwidth_bps: f64::INFINITY,
                chunk_bytes: f64::INFINITY,
                max_retries: 3,
                retry_backoff: 1.5,
            },
            compute: ComputeConfig {
                capability_flops: 3e9, // Table I: 3 GHz
                // GoogLeNet-22 forward ≈ 3 GFLOPs at 224×224; at ~1/3
                // achieved efficiency on a 3 GHz on-board CPU that is ~3 s
                // per image — the "time-consuming high-resolution image
                // processing" regime the paper motivates.
                task_flops: 27e9,
                // preprocess + hyperplane projection + SSIM on 32×32 inputs,
                // scaled to the paper's 224×224 pipeline (~60 MFLOP).
                lookup_flops: 6e7,
                lookup_fixed_s: 0.005,
            },
            reuse: ReuseConfig {
                p_l: 1,      // Table I
                p_k: 2,      // Table I
                th_sim: 0.7, // Table I
                beta: 0.5,   // Table I
                th_co: 0.5,  // Table I (default)
                tau: 11,     // Table I (default)
                cache_bytes: 640e6,
                collab_cooldown_s: 25.0,
            },
            workload: WorkloadConfig {
                total_tasks: 625,
                num_classes: 21,
                raw_h: 64,
                raw_w: 64,
                // 1 task/s against a ~3 s from-scratch service time: the
                // overload regime the paper's "resource-constrained
                // satellites" narrative implies (reuse, not capacity,
                // determines completion time).
                arrival_rate_per_sat: 0.3,
                intra_scene_jitter: 0.004,
                scene_repeat_prob: 0.45,
                repeat_prob_spread: 0.6,
                scenes_per_satellite: 6,
                shared_pool_prob: 0.9,
                seed: 2025,
            },
            topology: TopologyConfig::default(),
            faults: FaultConfig::default(),
            alpha: 1.0,
        }
    }

    /// SCRT capacity in records implied by `C^stg` and the record payload.
    pub fn cache_capacity_records(&self) -> usize {
        let record = self.comm.record_input_bytes + self.comm.record_output_bytes;
        (self.reuse.cache_bytes / record).floor() as usize
    }

    /// Tasks assigned to each satellite (paper: evenly distributed).
    pub fn tasks_per_satellite(&self) -> usize {
        let sats = self.network.n * self.network.n;
        self.workload.total_tasks.div_ceil(sats)
    }

    /// Validate every invariant the simulator assumes.
    pub fn validate(&self) -> Result<()> {
        let e = |m: String| Err(Error::Config(m));
        if self.network.n < 2 {
            return e(format!("network scale n={} must be >= 2", self.network.n));
        }
        if self.reuse.p_l != 1 {
            return e("only p_l = 1 is supported (matches Table I)".into());
        }
        if self.reuse.p_k == 0 || self.reuse.p_k > 16 {
            return e(format!("p_k={} out of range [1, 16]", self.reuse.p_k));
        }
        if !(0.0..=1.0).contains(&self.reuse.th_sim) {
            return e(format!("th_sim={} outside [0, 1]", self.reuse.th_sim));
        }
        if !(0.0..=1.0).contains(&self.reuse.beta) {
            return e(format!("beta={} outside [0, 1]", self.reuse.beta));
        }
        if !(0.0..=1.0).contains(&self.reuse.th_co) {
            return e(format!(
                "th_co={} out of range: the cooperation threshold must lie in [0, 1]",
                self.reuse.th_co
            ));
        }
        if self.reuse.tau == 0 {
            return e(format!(
                "tau={} out of range: records broadcast per collaboration must be >= 1",
                self.reuse.tau
            ));
        }
        if self.cache_capacity_records() == 0 {
            return e("cache too small to hold a single record".into());
        }
        if self.workload.total_tasks == 0 {
            return e("total_tasks must be > 0".into());
        }
        if self.workload.num_classes < 2 {
            return e("need at least 2 classes".into());
        }
        if self.workload.arrival_rate_per_sat <= 0.0 {
            return e("arrival rate must be positive".into());
        }
        if self.compute.capability_flops <= 0.0 || self.compute.task_flops <= 0.0 {
            return e("compute capabilities must be positive".into());
        }
        if self.comm.bandwidth_hz <= 0.0 || self.comm.tx_power_w <= 0.0 {
            return e("comm parameters must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.workload.scene_repeat_prob)
            || !(0.0..=1.0).contains(&self.workload.shared_pool_prob)
        {
            return e("workload probabilities outside [0, 1]".into());
        }
        Ok(())
    }

    /// Load from a TOML-subset file (see `configs/`); unknown keys error.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text, starting from paper defaults.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parser::parse(text)?;
        let n = doc
            .get("network", "n")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(5);
        let mut cfg = SimConfig::paper_default(n);
        for ((section, key), value) in doc.iter() {
            cfg.apply(section, key, value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply(&mut self, section: &str, key: &str, v: &TomlValue) -> Result<()> {
        let unknown = || {
            Err(Error::Config(format!(
                "unknown config key [{section}] {key}"
            )))
        };
        match (section, key) {
            ("network", "n") => self.network.n = v.as_usize()?,
            ("network", "intra_plane_distance_m") => {
                self.network.intra_plane_distance_m = v.as_f64()?
            }
            ("network", "inter_plane_distance_m") => {
                self.network.inter_plane_distance_m = v.as_f64()?
            }
            ("comm", "bandwidth_hz") => self.comm.bandwidth_hz = v.as_f64()?,
            ("comm", "carrier_hz") => self.comm.carrier_hz = v.as_f64()?,
            ("comm", "tx_power_w") => self.comm.tx_power_w = v.as_f64()?,
            ("comm", "antenna_gain_dbi") => self.comm.antenna_gain_dbi = v.as_f64()?,
            ("comm", "noise_temp_k") => self.comm.noise_temp_k = v.as_f64()?,
            ("comm", "record_input_bytes") => {
                self.comm.record_input_bytes = v.as_f64()?
            }
            ("comm", "record_output_bytes") => {
                self.comm.record_output_bytes = v.as_f64()?
            }
            ("comm", "loss_prob") => self.comm.loss_prob = v.as_f64()?,
            ("comm", "corrupt_prob") => self.comm.corrupt_prob = v.as_f64()?,
            ("comm", "link_bandwidth_bps") => {
                self.comm.link_bandwidth_bps = v.as_f64()?
            }
            ("comm", "chunk_bytes") => self.comm.chunk_bytes = v.as_f64()?,
            ("comm", "max_retries") => self.comm.max_retries = v.as_usize()?,
            ("comm", "retry_backoff") => self.comm.retry_backoff = v.as_f64()?,
            ("compute", "capability_flops") => {
                self.compute.capability_flops = v.as_f64()?
            }
            ("compute", "task_flops") => self.compute.task_flops = v.as_f64()?,
            ("compute", "lookup_flops") => self.compute.lookup_flops = v.as_f64()?,
            ("compute", "lookup_fixed_s") => self.compute.lookup_fixed_s = v.as_f64()?,
            ("reuse", "p_l") => self.reuse.p_l = v.as_usize()?,
            ("reuse", "p_k") => self.reuse.p_k = v.as_usize()?,
            ("reuse", "th_sim") => self.reuse.th_sim = v.as_f64()?,
            ("reuse", "beta") => self.reuse.beta = v.as_f64()?,
            ("reuse", "th_co") => self.reuse.th_co = v.as_f64()?,
            ("reuse", "tau") => self.reuse.tau = v.as_usize()?,
            ("reuse", "cache_bytes") => self.reuse.cache_bytes = v.as_f64()?,
            ("reuse", "collab_cooldown_s") => {
                self.reuse.collab_cooldown_s = v.as_f64()?
            }
            ("workload", "total_tasks") => self.workload.total_tasks = v.as_usize()?,
            ("workload", "num_classes") => self.workload.num_classes = v.as_usize()?,
            ("workload", "raw_h") => self.workload.raw_h = v.as_usize()?,
            ("workload", "raw_w") => self.workload.raw_w = v.as_usize()?,
            ("workload", "arrival_rate_per_sat") => {
                self.workload.arrival_rate_per_sat = v.as_f64()?
            }
            ("workload", "intra_scene_jitter") => {
                self.workload.intra_scene_jitter = v.as_f64()?
            }
            ("workload", "scene_repeat_prob") => {
                self.workload.scene_repeat_prob = v.as_f64()?
            }
            ("workload", "repeat_prob_spread") => {
                self.workload.repeat_prob_spread = v.as_f64()?
            }
            ("workload", "scenes_per_satellite") => {
                self.workload.scenes_per_satellite = v.as_usize()?
            }
            ("workload", "shared_pool_prob") => {
                self.workload.shared_pool_prob = v.as_f64()?
            }
            ("workload", "seed") => self.workload.seed = v.as_u64()?,
            ("topology", "mode") => {
                self.topology.mode = match v.as_str()? {
                    "static" => TopologyMode::Static,
                    "walker" => TopologyMode::Walker,
                    other => {
                        return Err(Error::Config(format!(
                            "topology mode '{other}' is not 'static' or 'walker'"
                        )))
                    }
                }
            }
            ("topology", "kind") => {
                self.topology.kind = match v.as_str()? {
                    "delta" => WalkerKind::Delta,
                    "star" => WalkerKind::Star,
                    other => {
                        return Err(Error::Config(format!(
                            "topology kind '{other}' is not 'delta' or 'star'"
                        )))
                    }
                }
            }
            ("topology", "period_s") => self.topology.period_s = v.as_f64()?,
            ("topology", "duty") => self.topology.duty = v.as_f64()?,
            ("topology", "phasing") => self.topology.phasing = v.as_usize()?,
            ("topology", "inter_rate_scale") => {
                self.topology.inter_rate_scale = v.as_f64()?
            }
            ("topology", "inter_extra_latency_s") => {
                self.topology.inter_extra_latency_s = v.as_f64()?
            }
            ("topology", "outages") => {
                self.topology.outages =
                    OutageSpec::parse_list(v.as_str()?).map_err(Error::Config)?
            }
            ("topology", "ground_stations") => {
                self.topology.ground_stations = v.as_usize()?
            }
            ("topology", "pass_period_s") => {
                self.topology.pass_period_s = v.as_f64()?
            }
            ("topology", "pass_duty") => self.topology.pass_duty = v.as_f64()?,
            ("topology", "planes") => self.topology.planes = Some(v.as_usize()?),
            ("topology", "sats_per_plane") => {
                self.topology.sats_per_plane = Some(v.as_usize()?)
            }
            ("faults", "mtbf_s") => self.faults.mtbf_s = v.as_f64()?,
            ("faults", "downtime_s") => self.faults.downtime_s = v.as_f64()?,
            ("faults", "scrt_persist") => self.faults.scrt_persist = v.as_bool()?,
            ("faults", "node_outages") => {
                self.faults.node_outages =
                    NodeOutageSpec::parse_list(v.as_str()?).map_err(Error::Config)?
            }
            ("faults", "collab_timeout_s") => {
                self.faults.collab_timeout_s = v.as_f64()?
            }
            ("faults", "max_failover_retries") => {
                self.faults.max_failover_retries = v.as_usize()?
            }
            ("faults", "failover_backoff") => {
                self.faults.failover_backoff = v.as_f64()?
            }
            ("sim", "alpha") => self.alpha = v.as_f64()?,
            _ => return unknown(),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_matches_table1() {
        let c = SimConfig::paper_default(5);
        assert_eq!(c.network.n, 5);
        assert_eq!(c.comm.bandwidth_hz, 20e6);
        assert_eq!(c.compute.capability_flops, 3e9);
        assert_eq!(c.reuse.p_l, 1);
        assert_eq!(c.reuse.p_k, 2);
        assert_eq!(c.reuse.beta, 0.5);
        assert_eq!(c.reuse.th_sim, 0.7);
        assert_eq!(c.reuse.tau, 11);
        assert_eq!(c.reuse.th_co, 0.5);
        assert_eq!(c.workload.total_tasks, 625);
        assert_eq!(c.workload.num_classes, 21);
        c.validate().unwrap();
    }

    #[test]
    fn validates_all_scales() {
        for n in [5, 7, 9] {
            SimConfig::paper_default(n).validate().unwrap();
        }
    }

    #[test]
    fn cache_capacity_positive() {
        let c = SimConfig::paper_default(5);
        let cap = c.cache_capacity_records();
        assert!(cap >= 10, "capacity {cap} too small for tau sweeps");
    }

    #[test]
    fn tasks_per_satellite_covers_total() {
        let c = SimConfig::paper_default(5);
        assert_eq!(c.tasks_per_satellite(), 25);
        let c = SimConfig::paper_default(7);
        assert!(c.tasks_per_satellite() * 49 >= 625);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = SimConfig::paper_default(5);
        c.reuse.th_sim = 1.5;
        assert!(c.validate().is_err());

        let mut c = SimConfig::paper_default(5);
        c.network.n = 1;
        assert!(c.validate().is_err());

        let mut c = SimConfig::paper_default(5);
        c.reuse.tau = 0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::paper_default(5);
        c.reuse.cache_bytes = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn tau_rejection_names_value_and_range() {
        let mut c = SimConfig::paper_default(5);
        c.reuse.tau = 0;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("tau=0"), "message must name the value: {err}");
        assert!(err.contains(">= 1"), "message must name the range: {err}");
    }

    #[test]
    fn th_co_rejection_names_value_and_range() {
        let mut c = SimConfig::paper_default(5);
        c.reuse.th_co = 1.5;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("th_co=1.5"), "message must name the value: {err}");
        assert!(err.contains("[0, 1]"), "message must name the range: {err}");

        let mut c = SimConfig::paper_default(5);
        c.reuse.th_co = -0.25;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("th_co=-0.25"), "negative value reported: {err}");
        assert!(err.contains("[0, 1]"), "range reported: {err}");
    }

    #[test]
    fn paper_default_has_ideal_links() {
        // The fault model must be off by default: loss = 0 runs take the
        // legacy broadcast path and reproduce existing goldens.
        let c = SimConfig::paper_default(5);
        assert!(!c.comm.faults_active());
        c.comm.fault_check().unwrap();
    }

    #[test]
    fn faults_active_detects_each_knob() {
        let base = SimConfig::paper_default(5);
        let mut c = base.clone();
        c.comm.loss_prob = 0.1;
        assert!(c.comm.faults_active());
        let mut c = base.clone();
        c.comm.corrupt_prob = 0.05;
        assert!(c.comm.faults_active());
        let mut c = base.clone();
        c.comm.link_bandwidth_bps = 1e8;
        assert!(c.comm.faults_active());
        let mut c = base.clone();
        c.comm.chunk_bytes = 1e6;
        assert!(c.comm.faults_active());
        // A negative loss must still route into the checker.
        let mut c = base;
        c.comm.loss_prob = -0.5;
        assert!(c.comm.faults_active());
        assert!(c.comm.fault_check().is_err());
    }

    #[test]
    fn fault_check_names_each_bad_value() {
        let base = SimConfig::paper_default(5);

        let mut c = base.clone();
        c.comm.loss_prob = 1.0;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("loss_prob=1"), "value named: {err}");
        assert!(err.contains("[0, 1)"), "range named: {err}");

        let mut c = base.clone();
        c.comm.corrupt_prob = 1.25;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("corrupt_prob=1.25"), "value named: {err}");

        let mut c = base.clone();
        c.comm.loss_prob = 0.6;
        c.comm.corrupt_prob = 0.5;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("0.6") && err.contains("0.5"), "{err}");

        let mut c = base.clone();
        c.comm.link_bandwidth_bps = 0.0;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("link_bandwidth_bps=0"), "value named: {err}");
        c.comm.link_bandwidth_bps = -5.0;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("link_bandwidth_bps=-5"), "value named: {err}");

        let mut c = base.clone();
        c.comm.chunk_bytes = 0.0;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("chunk_bytes=0"), "value named: {err}");

        let mut c = base.clone();
        c.comm.chunk_bytes = 1.0; // ~20.5M chunks per record
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("65536"), "chunk-count guard named: {err}");

        let mut c = base.clone();
        c.comm.max_retries = 1000;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("max_retries=1000"), "value named: {err}");

        let mut c = base;
        c.comm.retry_backoff = 0.5;
        let err = c.comm.fault_check().unwrap_err();
        assert!(err.contains("retry_backoff=0.5"), "value named: {err}");
    }

    #[test]
    fn toml_accepts_fault_model_keys() {
        let text = r#"
[comm]
loss_prob = 0.2
corrupt_prob = 0.01
link_bandwidth_bps = 5e7
chunk_bytes = 4e6
max_retries = 5
retry_backoff = 2.0
"#;
        let c = SimConfig::from_toml_str(text).unwrap();
        assert_eq!(c.comm.loss_prob, 0.2);
        assert_eq!(c.comm.corrupt_prob, 0.01);
        assert_eq!(c.comm.link_bandwidth_bps, 5e7);
        assert_eq!(c.comm.chunk_bytes, 4e6);
        assert_eq!(c.comm.max_retries, 5);
        assert_eq!(c.comm.retry_backoff, 2.0);
        assert!(c.comm.faults_active());
    }

    #[test]
    fn paper_default_topology_is_static() {
        // The contact plan must be degenerate by default: static configs
        // take the legacy broadcast path and reproduce existing goldens.
        let c = SimConfig::paper_default(5);
        assert_eq!(c.topology.mode, TopologyMode::Static);
        assert!(!c.topology.is_dynamic());
        c.topology.check(5).unwrap();
    }

    #[test]
    fn topology_is_dynamic_detects_each_knob() {
        let base = TopologyConfig::default();

        // Walker with full duty and no modifiers is still degenerate —
        // that is the semantic detection the degenerate-plan property
        // test relies on.
        let mut c = base.clone();
        c.mode = TopologyMode::Walker;
        assert!(!c.is_dynamic());
        c.check(5).unwrap();

        let mut c = base.clone();
        c.mode = TopologyMode::Walker;
        c.duty = 0.6;
        assert!(c.is_dynamic());

        let mut c = base.clone();
        c.mode = TopologyMode::Walker;
        c.inter_rate_scale = 0.5;
        assert!(c.is_dynamic());

        let mut c = base.clone();
        c.mode = TopologyMode::Walker;
        c.inter_extra_latency_s = 0.01;
        assert!(c.is_dynamic());

        let mut c = base.clone();
        c.outages = vec![OutageSpec {
            a: 0,
            b: 1,
            start: 10.0,
            end: 20.0,
        }];
        assert!(c.is_dynamic());

        let mut c = base.clone();
        c.ground_stations = 2;
        assert!(c.is_dynamic());

        // Stations with a zero pass duty never produce a pass.
        let mut c = base;
        c.ground_stations = 2;
        c.pass_duty = 0.0;
        assert!(!c.is_dynamic());
    }

    #[test]
    fn topology_check_names_each_bad_value() {
        let walker = || {
            let mut c = TopologyConfig::default();
            c.mode = TopologyMode::Walker;
            c
        };

        let mut c = walker();
        c.duty = 0.0;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("duty=0"), "value named: {err}");
        assert!(err.contains("(0, 1]"), "range named: {err}");

        let mut c = walker();
        c.period_s = 0.0;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("period_s=0"), "value named: {err}");

        let mut c = walker();
        c.inter_rate_scale = 2.0;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("inter_rate_scale=2"), "value named: {err}");
        assert!(err.contains("lookahead"), "soundness rationale named: {err}");

        let mut c = walker();
        c.inter_extra_latency_s = -1.0;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("inter_extra_latency_s=-1"), "value named: {err}");

        // Walker knobs are inert in static mode: reject, don't ignore.
        let mut c = TopologyConfig::default();
        c.duty = 0.5;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("static mode"), "mode conflict named: {err}");

        let mut c = walker();
        c.planes = Some(6);
        let err = c.check(5).unwrap_err();
        assert!(err.contains("planes/sats_per_plane=6"), "value named: {err}");
        assert!(err.contains("n=5"), "constraint named: {err}");

        // Outage endpoints must be an in-bounds grid ISL.
        let mut c = TopologyConfig::default();
        c.outages = vec![OutageSpec {
            a: 0,
            b: 99,
            start: 0.0,
            end: 1.0,
        }];
        let err = c.check(5).unwrap_err();
        assert!(err.contains("0-99"), "link named: {err}");

        let mut c = TopologyConfig::default();
        c.outages = vec![OutageSpec {
            a: 0,
            b: 6,
            start: 0.0,
            end: 1.0,
        }];
        let err = c.check(5).unwrap_err();
        assert!(err.contains("not a grid ISL"), "adjacency named: {err}");

        let mut c = TopologyConfig::default();
        c.outages = vec![OutageSpec {
            a: 0,
            b: 1,
            start: 5.0,
            end: 5.0,
        }];
        let err = c.check(5).unwrap_err();
        assert!(err.contains("start < end"), "interval rule named: {err}");

        let mut c = TopologyConfig::default();
        c.ground_stations = 1;
        c.pass_duty = 1.0;
        let err = c.check(5).unwrap_err();
        assert!(err.contains("pass_duty=1"), "value named: {err}");
        assert!(err.contains("[0, 1)"), "range named: {err}");
    }

    #[test]
    fn outage_list_parses_and_rejects_garbage() {
        let specs = OutageSpec::parse_list("3-4@100..200, 7-8@50..80").unwrap();
        assert_eq!(
            specs,
            vec![
                OutageSpec {
                    a: 3,
                    b: 4,
                    start: 100.0,
                    end: 200.0
                },
                OutageSpec {
                    a: 7,
                    b: 8,
                    start: 50.0,
                    end: 80.0
                },
            ]
        );
        assert!(OutageSpec::parse_list("").unwrap().is_empty());
        for bad in ["3-4", "3@100..200", "3-4@100", "a-b@x..y"] {
            let err = OutageSpec::parse_list(bad).unwrap_err();
            assert!(err.contains(bad), "bad entry echoed: {err}");
            assert!(err.contains("a-b@start..end"), "format named: {err}");
        }
    }

    #[test]
    fn toml_accepts_topology_keys() {
        let text = r#"
[topology]
mode = "walker"
kind = "star"
period_s = 600.0
duty = 0.7
phasing = 2
inter_rate_scale = 0.8
inter_extra_latency_s = 0.002
outages = "3-4@100..200"
ground_stations = 2
pass_period_s = 900.0
pass_duty = 0.1
planes = 5
sats_per_plane = 5
"#;
        let c = SimConfig::from_toml_str(text).unwrap();
        assert_eq!(c.topology.mode, TopologyMode::Walker);
        assert_eq!(c.topology.kind, WalkerKind::Star);
        assert_eq!(c.topology.period_s, 600.0);
        assert_eq!(c.topology.duty, 0.7);
        assert_eq!(c.topology.phasing, 2);
        assert_eq!(c.topology.inter_rate_scale, 0.8);
        assert_eq!(c.topology.inter_extra_latency_s, 0.002);
        assert_eq!(c.topology.outages.len(), 1);
        assert_eq!(c.topology.ground_stations, 2);
        assert_eq!(c.topology.pass_period_s, 900.0);
        assert_eq!(c.topology.pass_duty, 0.1);
        assert_eq!(c.topology.planes, Some(5));
        assert_eq!(c.topology.sats_per_plane, Some(5));
        assert!(c.topology.is_dynamic());
        c.topology.check(c.network.n).unwrap();

        let err = SimConfig::from_toml_str("[topology]\nmode = \"torus\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("torus"), "bad mode echoed: {err}");
    }

    #[test]
    fn paper_default_has_immortal_satellites() {
        // The node-fault model must be off by default: fault-free runs
        // take the legacy paths and reproduce existing goldens.
        let c = SimConfig::paper_default(5);
        assert!(!c.faults.node_faults_active());
        c.faults.node_fault_check(5).unwrap();
    }

    #[test]
    fn node_faults_active_detects_each_knob() {
        let base = FaultConfig::default();
        let mut c = base.clone();
        c.mtbf_s = 600.0;
        assert!(c.node_faults_active());
        let mut c = base.clone();
        c.node_outages = vec![NodeOutageSpec {
            sat: 3,
            start: 10.0,
            end: 40.0,
        }];
        assert!(c.node_faults_active());
        // A negative MTBF must still route into the checker.
        let mut c = base;
        c.mtbf_s = -5.0;
        assert!(c.node_faults_active());
        assert!(c.node_fault_check(5).is_err());
    }

    #[test]
    fn node_fault_check_names_each_bad_value() {
        let base = FaultConfig::default();

        let mut c = base.clone();
        c.mtbf_s = 0.0;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("mtbf_s=0"), "value named: {err}");
        c.mtbf_s = -3.0;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("mtbf_s=-3"), "value named: {err}");

        let mut c = base.clone();
        c.downtime_s = 0.0;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("downtime_s=0"), "value named: {err}");
        c.downtime_s = f64::INFINITY;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("downtime_s=inf"), "value named: {err}");

        let mut c = base.clone();
        c.collab_timeout_s = -1.0;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("collab_timeout_s=-1"), "value named: {err}");

        let mut c = base.clone();
        c.max_failover_retries = 100;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("max_failover_retries=100"), "value named: {err}");

        let mut c = base.clone();
        c.failover_backoff = 0.5;
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("failover_backoff=0.5"), "value named: {err}");

        let mut c = base.clone();
        c.node_outages = vec![NodeOutageSpec {
            sat: 99,
            start: 0.0,
            end: 1.0,
        }];
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("sat=99"), "satellite named: {err}");

        let mut c = base;
        c.node_outages = vec![NodeOutageSpec {
            sat: 0,
            start: 5.0,
            end: 5.0,
        }];
        let err = c.node_fault_check(5).unwrap_err();
        assert!(err.contains("start < end"), "interval rule named: {err}");
    }

    #[test]
    fn node_outage_list_parses_and_rejects_garbage() {
        let specs = NodeOutageSpec::parse_list("7@100..200, 12@50..80").unwrap();
        assert_eq!(
            specs,
            vec![
                NodeOutageSpec {
                    sat: 7,
                    start: 100.0,
                    end: 200.0
                },
                NodeOutageSpec {
                    sat: 12,
                    start: 50.0,
                    end: 80.0
                },
            ]
        );
        assert!(NodeOutageSpec::parse_list("").unwrap().is_empty());
        for bad in ["7", "7@100", "x@1..2", "7@a..b"] {
            let err = NodeOutageSpec::parse_list(bad).unwrap_err();
            assert!(err.contains(bad), "bad entry echoed: {err}");
            assert!(err.contains("sat@start..end"), "format named: {err}");
        }
    }

    #[test]
    fn toml_accepts_node_fault_keys() {
        let text = r#"
[faults]
mtbf_s = 900.0
downtime_s = 45.0
scrt_persist = true
node_outages = "7@100..200"
collab_timeout_s = 3.0
max_failover_retries = 4
failover_backoff = 1.5
"#;
        let c = SimConfig::from_toml_str(text).unwrap();
        assert_eq!(c.faults.mtbf_s, 900.0);
        assert_eq!(c.faults.downtime_s, 45.0);
        assert!(c.faults.scrt_persist);
        assert_eq!(c.faults.node_outages.len(), 1);
        assert_eq!(c.faults.collab_timeout_s, 3.0);
        assert_eq!(c.faults.max_failover_retries, 4);
        assert_eq!(c.faults.failover_backoff, 1.5);
        assert!(c.faults.node_faults_active());
        c.faults.node_fault_check(c.network.n).unwrap();
    }

    #[test]
    fn toml_overrides() {
        let text = r#"
# comment
[network]
n = 7

[reuse]
tau = 5
th_co = 0.3

[workload]
seed = 99
"#;
        let c = SimConfig::from_toml_str(text).unwrap();
        assert_eq!(c.network.n, 7);
        assert_eq!(c.reuse.tau, 5);
        assert_eq!(c.reuse.th_co, 0.3);
        assert_eq!(c.workload.seed, 99);
        // untouched values keep paper defaults
        assert_eq!(c.reuse.th_sim, 0.7);
    }

    #[test]
    fn toml_unknown_key_rejected() {
        assert!(SimConfig::from_toml_str("[reuse]\nbogus = 1\n").is_err());
        assert!(SimConfig::from_toml_str("[bogus]\ntau = 1\n").is_err());
    }
}
