//! Constellation substrate: grid topology, time-varying contact plans and
//! the ISL communication model.
//!
//! * [`topology`] — the N×N constellation grid of the paper's Fig. 1:
//!   row-major satellite ids, 4-neighbour inter-satellite links, Manhattan
//!   routing distances, and the Chebyshev collaboration areas Alg. 2
//!   searches ([`GridTopology::area`] / [`GridTopology::expand_area`]);
//!   plus the [`ContactPlan`] that says *when* each of those links is
//!   actually up (Walker-shell duty cycling, scripted outages,
//!   ground-station passes), with the static grid as its degenerate
//!   always-on case;
//! * [`comm`] — the link-budget physics of eqs. (1)–(5): free-space path
//!   loss, SNR and Shannon rate per link class, and the spanning-tree
//!   broadcast planner ([`CommModel::plan_broadcast`]) that prices every
//!   record share in bytes and airtime for the data-transfer criterion.
//!   Its lossy sibling gates every chunk on the contact plan, and
//!   [`CommModel::lookahead_at`] is the per-window conservative bound the
//!   sharded engine runs on.
//! * [`faults`] — the deterministic node-fault plan: when each satellite
//!   is crashed, resolved entirely before the run from scripted outages
//!   and counter-hash MTBF draws so both engines see identical fates.

#![deny(missing_docs)]

pub mod comm;
pub mod faults;
pub mod topology;

pub use comm::{CommModel, LinkState, LossyPlan};
pub use faults::NodeFaultPlan;
pub use topology::{ContactPlan, ContactWindow, GridTopology};
