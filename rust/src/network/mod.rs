//! Constellation substrate: grid topology and the ISL communication model.

pub mod comm;
pub mod topology;

pub use comm::CommModel;
pub use topology::GridTopology;
