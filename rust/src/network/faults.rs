//! Deterministic node-fault plan: when each satellite is down.
//!
//! The whole fault schedule is resolved **before the run starts** from
//! pure inputs — the scripted [`NodeOutageSpec`] list plus MTBF-style
//! random crashes drawn from the counter-hash ([`hash_unit`]) — so both
//! engines derive bit-identical crash/reboot instants regardless of event
//! interleaving or shard count. That is the same determinism pattern the
//! lossy comm layer uses for chunk fates (PR 6), lifted from links to
//! nodes.
//!
//! Random crash gaps are exponential with mean `mtbf_s`, drawn per
//! `(satellite, crash index)` under the reserved stream id
//! [`NODE_FAULT_STREAM`] (a transfer counter can never reach `u64::MAX`,
//! so node-fault draws and chunk-fate draws can never collide even though
//! they share a seed). Generation is bounded by the workload horizon (the
//! last task arrival): a satellite that would next crash after the final
//! arrival simply never does, which both guarantees termination and keeps
//! the plan identical across engines (the horizon is a pure function of
//! the workload).

use crate::config::FaultConfig;
use crate::util::rng::hash_unit;
use crate::workload::SatId;

/// Reserved first hash coordinate for node-fault draws. Chunk-fate draws
/// key their first coordinate by a transfer counter that starts at 0 and
/// increments per broadcast; it can never reach `u64::MAX`, so the two
/// draw families are disjoint by construction.
pub const NODE_FAULT_STREAM: u64 = u64::MAX;

/// The resolved fault schedule: per-satellite sorted, coalesced
/// `[crash, reboot)` down intervals. Pure and engine-independent — every
/// query is a function of `(sat, t)` only.
#[derive(Clone, Debug, Default)]
pub struct NodeFaultPlan {
    /// `intervals[sat]` = sorted, non-overlapping `[crash, reboot)` spans.
    intervals: Vec<Vec<(f64, f64)>>,
}

impl NodeFaultPlan {
    /// Resolve the fault schedule for `sats` satellites up to `horizon`
    /// (the last task arrival). Scripted outages are taken verbatim;
    /// random crashes chain exponential gaps after the previous reboot,
    /// stopping once a crash would land past the horizon. Overlapping
    /// spans (scripted × random) are coalesced so each crash/reboot pair
    /// is observable exactly once.
    pub fn new(cfg: &FaultConfig, seed: u64, sats: usize, horizon: f64) -> Self {
        let mut intervals: Vec<Vec<(f64, f64)>> = vec![Vec::new(); sats];
        for o in &cfg.node_outages {
            if o.sat < sats && o.start <= horizon {
                intervals[o.sat].push((o.start, o.end));
            }
        }
        if cfg.mtbf_s.is_finite() {
            for (sat, spans) in intervals.iter_mut().enumerate() {
                let mut t = 0.0;
                let mut k: u64 = 0;
                loop {
                    let u = hash_unit(seed, NODE_FAULT_STREAM, sat as u64, k, 0);
                    // Exponential gap with mean mtbf_s; u < 1 always, so
                    // ln(1 - u) is finite and the gap is positive.
                    let gap = cfg.mtbf_s * -(1.0 - u).ln();
                    let crash = t + gap;
                    if !(crash <= horizon) {
                        break;
                    }
                    spans.push((crash, crash + cfg.downtime_s));
                    t = crash + cfg.downtime_s;
                    k += 1;
                }
            }
        }
        for spans in &mut intervals {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
            // Coalesce overlapping/adjacent spans so a satellite is never
            // "crashed while already down".
            let mut merged: Vec<(f64, f64)> = Vec::with_capacity(spans.len());
            for &(s, e) in spans.iter() {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            *spans = merged;
        }
        NodeFaultPlan { intervals }
    }

    /// A plan with no faults at all (the legacy immortal constellation).
    pub fn none(sats: usize) -> Self {
        NodeFaultPlan {
            intervals: vec![Vec::new(); sats],
        }
    }

    /// Every coalesced `[crash, reboot)` interval of `sat`, in time order.
    pub fn spans(&self, sat: SatId) -> &[(f64, f64)] {
        &self.intervals[sat]
    }

    /// Is `sat` down (crashed, not yet rebooted) at virtual time `t`?
    /// Crash instants are inclusive, reboot instants exclusive — a
    /// satellite rebooting at `t` is up at `t`.
    pub fn is_down(&self, sat: SatId, t: f64) -> bool {
        self.intervals[sat]
            .iter()
            .any(|&(s, e)| s <= t && t < e)
    }

    /// Does `sat` crash at any instant in the half-open window
    /// `[t0, t1)`? Used to invalidate chunk possession across a wipe and
    /// to detect a source dying inside a failover response window.
    pub fn crashes_within(&self, sat: SatId, t0: f64, t1: f64) -> bool {
        self.intervals[sat]
            .iter()
            .any(|&(s, _)| t0 <= s && s < t1)
    }

    /// `true` when no satellite ever goes down.
    pub fn is_empty(&self) -> bool {
        self.intervals.iter().all(Vec::is_empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeOutageSpec;

    fn cfg() -> FaultConfig {
        FaultConfig::default()
    }

    #[test]
    fn scripted_outages_appear_verbatim() {
        let mut c = cfg();
        c.node_outages = vec![
            NodeOutageSpec {
                sat: 3,
                start: 10.0,
                end: 40.0,
            },
            NodeOutageSpec {
                sat: 7,
                start: 5.0,
                end: 8.0,
            },
        ];
        let plan = NodeFaultPlan::new(&c, 1, 25, 1000.0);
        assert_eq!(plan.spans(3), &[(10.0, 40.0)]);
        assert_eq!(plan.spans(7), &[(5.0, 8.0)]);
        assert!(plan.is_down(3, 10.0), "crash instant inclusive");
        assert!(plan.is_down(3, 39.999));
        assert!(!plan.is_down(3, 40.0), "reboot instant exclusive");
        assert!(!plan.is_down(0, 10.0));
        assert!(plan.crashes_within(3, 0.0, 20.0));
        assert!(!plan.crashes_within(3, 10.5, 20.0));
        assert!(!plan.is_empty());
    }

    #[test]
    fn mtbf_draws_are_pure_and_bounded_by_the_horizon() {
        let mut c = cfg();
        c.mtbf_s = 100.0;
        c.downtime_s = 10.0;
        let a = NodeFaultPlan::new(&c, 42, 25, 500.0);
        let b = NodeFaultPlan::new(&c, 42, 25, 500.0);
        for sat in 0..25 {
            assert_eq!(a.spans(sat), b.spans(sat), "draws must be pure");
        }
        assert!(!a.is_empty(), "mtbf 100 over a 500 s horizon must crash");
        for sat in 0..25 {
            for &(s, e) in a.spans(sat) {
                assert!(s <= 500.0, "crash {s} past the horizon");
                assert!((e - s - 10.0).abs() < 1e-12 || e - s > 10.0);
            }
            // Spans are sorted and disjoint.
            for w in a.spans(sat).windows(2) {
                assert!(w[0].1 < w[1].0, "overlap: {:?}", w);
            }
        }
        // A different seed draws a different schedule somewhere.
        let other = NodeFaultPlan::new(&c, 43, 25, 500.0);
        assert!((0..25).any(|s| a.spans(s) != other.spans(s)));
    }

    #[test]
    fn overlapping_scripted_and_random_spans_coalesce() {
        let mut c = cfg();
        c.node_outages = vec![
            NodeOutageSpec {
                sat: 0,
                start: 10.0,
                end: 30.0,
            },
            NodeOutageSpec {
                sat: 0,
                start: 20.0,
                end: 50.0,
            },
        ];
        let plan = NodeFaultPlan::new(&c, 1, 4, 1000.0);
        assert_eq!(plan.spans(0), &[(10.0, 50.0)]);
    }

    #[test]
    fn infinite_mtbf_and_no_outages_is_empty() {
        let plan = NodeFaultPlan::new(&cfg(), 7, 25, 1e6);
        assert!(plan.is_empty());
        for sat in 0..25 {
            assert!(!plan.is_down(sat, 0.0));
        }
    }
}
