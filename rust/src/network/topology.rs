//! N×N constellation grid (Fig. 1 of the paper).
//!
//! Row = orbital plane, column = slot along the plane. Satellite ids are
//! row-major (`orbit * n + slot`). ISLs connect the four grid neighbours
//! (two intra-plane, two inter-plane); no wrap-around — the grid is a
//! window onto a larger constellation, exactly like the paper's 5×5 / 7×7 /
//! 9×9 scenes. Collaboration areas (Alg. 2) are Chebyshev neighbourhoods.

use crate::workload::SatId;

/// The constellation grid.
#[derive(Clone, Debug)]
pub struct GridTopology {
    n: usize,
}

impl GridTopology {
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "grid needs n >= 2");
        GridTopology { n }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Total number of satellites.
    pub fn len(&self) -> usize {
        self.n * self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// (orbit, slot) of a satellite id.
    #[inline]
    pub fn coords(&self, sat: SatId) -> (usize, usize) {
        debug_assert!(sat < self.len());
        (sat / self.n, sat % self.n)
    }

    /// Satellite id at (orbit, slot).
    #[inline]
    pub fn sat_at(&self, orbit: usize, slot: usize) -> SatId {
        debug_assert!(orbit < self.n && slot < self.n);
        orbit * self.n + slot
    }

    /// The 2–4 ISL neighbours of a satellite.
    pub fn neighbours(&self, sat: SatId) -> Vec<SatId> {
        let (o, s) = self.coords(sat);
        let mut out = Vec::with_capacity(4);
        if o > 0 {
            out.push(self.sat_at(o - 1, s));
        }
        if o + 1 < self.n {
            out.push(self.sat_at(o + 1, s));
        }
        if s > 0 {
            out.push(self.sat_at(o, s - 1));
        }
        if s + 1 < self.n {
            out.push(self.sat_at(o, s + 1));
        }
        out
    }

    /// Is the link (a, b) a single-hop ISL?
    pub fn adjacent(&self, a: SatId, b: SatId) -> bool {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        (ao == bo && as_.abs_diff(bs) == 1) || (as_ == bs && ao.abs_diff(bo) == 1)
    }

    /// Manhattan hop count between two satellites (ISL routing distance —
    /// grid shortest path since only grid links exist).
    pub fn hops(&self, a: SatId, b: SatId) -> usize {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        ao.abs_diff(bo) + as_.abs_diff(bs)
    }

    /// Chebyshev distance (collaboration areas are square rings).
    pub fn chebyshev(&self, a: SatId, b: SatId) -> usize {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        ao.abs_diff(bo).max(as_.abs_diff(bs))
    }

    /// Collaboration area of radius `r` around `center`: all satellites with
    /// Chebyshev distance ≤ r, clamped at the grid boundary.
    ///
    /// * `r = 1` → the paper's **initial** area (center + surrounding);
    /// * `r = 2` → the **expanded** area (surrounding of all members).
    pub fn area(&self, center: SatId, r: usize) -> Vec<SatId> {
        let (o, s) = self.coords(center);
        let o_lo = o.saturating_sub(r);
        let o_hi = (o + r).min(self.n - 1);
        let s_lo = s.saturating_sub(r);
        let s_hi = (s + r).min(self.n - 1);
        let mut out = Vec::with_capacity((o_hi - o_lo + 1) * (s_hi - s_lo + 1));
        for oo in o_lo..=o_hi {
            for ss in s_lo..=s_hi {
                out.push(self.sat_at(oo, ss));
            }
        }
        out
    }

    /// Expand an existing area by one ring: the union of radius-1 areas of
    /// every member (`GetExpandedCoArea` in Alg. 2).
    pub fn expand_area(&self, area: &[SatId]) -> Vec<SatId> {
        let mut mask = vec![false; self.len()];
        for &sat in area {
            for member in self.area(sat, 1) {
                mask[member] = true;
            }
        }
        (0..self.len()).filter(|&i| mask[i]).collect()
    }

    /// All satellite ids.
    pub fn all(&self) -> impl Iterator<Item = SatId> {
        0..self.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let g = GridTopology::new(5);
        for sat in g.all() {
            let (o, s) = g.coords(sat);
            assert_eq!(g.sat_at(o, s), sat);
        }
    }

    #[test]
    fn corner_has_two_neighbours_interior_four() {
        let g = GridTopology::new(5);
        assert_eq!(g.neighbours(0).len(), 2);
        assert_eq!(g.neighbours(g.sat_at(2, 2)).len(), 4);
        assert_eq!(g.neighbours(g.sat_at(0, 2)).len(), 3);
    }

    #[test]
    fn adjacency_symmetric_and_matches_hops() {
        let g = GridTopology::new(4);
        for a in g.all() {
            for b in g.all() {
                assert_eq!(g.adjacent(a, b), g.adjacent(b, a));
                assert_eq!(g.adjacent(a, b), g.hops(a, b) == 1);
            }
        }
    }

    #[test]
    fn area_radius1_center_is_3x3() {
        let g = GridTopology::new(5);
        let area = g.area(g.sat_at(2, 2), 1);
        assert_eq!(area.len(), 9);
        assert!(area.contains(&g.sat_at(2, 2)));
        assert!(area.contains(&g.sat_at(1, 1)));
        assert!(!area.contains(&g.sat_at(0, 0)));
    }

    #[test]
    fn area_clamps_at_boundary() {
        let g = GridTopology::new(5);
        assert_eq!(g.area(0, 1).len(), 4); // corner: 2x2
        assert_eq!(g.area(g.sat_at(0, 2), 1).len(), 6); // edge: 2x3
    }

    #[test]
    fn expand_area_equals_radius2_for_interior() {
        let g = GridTopology::new(7);
        let c = g.sat_at(3, 3);
        let mut expanded = g.expand_area(&g.area(c, 1));
        let mut radius2 = g.area(c, 2);
        expanded.sort_unstable();
        radius2.sort_unstable();
        assert_eq!(expanded, radius2);
    }

    #[test]
    fn expand_area_monotone() {
        let g = GridTopology::new(5);
        let initial = g.area(0, 1);
        let expanded = g.expand_area(&initial);
        assert!(expanded.len() > initial.len());
        for sat in &initial {
            assert!(expanded.contains(sat));
        }
    }

    #[test]
    fn hops_triangle_inequality() {
        let g = GridTopology::new(5);
        for a in g.all() {
            for b in g.all() {
                for c in g.all() {
                    assert!(g.hops(a, b) + g.hops(b, c) >= g.hops(a, c));
                }
            }
        }
    }

    #[test]
    fn chebyshev_le_hops() {
        let g = GridTopology::new(6);
        for a in g.all() {
            for b in g.all() {
                assert!(g.chebyshev(a, b) <= g.hops(a, b));
            }
        }
    }
}
