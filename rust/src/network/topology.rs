//! N×N constellation grid (Fig. 1 of the paper) and the time-varying
//! contact plan layered on top of it.
//!
//! Row = orbital plane, column = slot along the plane. Satellite ids are
//! row-major (`orbit * n + slot`). ISLs connect the four grid neighbours
//! (two intra-plane, two inter-plane); no wrap-around — the grid is a
//! window onto a larger constellation, exactly like the paper's 5×5 / 7×7 /
//! 9×9 scenes. Collaboration areas (Alg. 2) are Chebyshev neighbourhoods.
//!
//! The *connectivity* of that grid is no longer assumed permanent: a
//! [`ContactPlan`] says when each ISL is actually up. Three ingredients
//! compose (a link is up iff none of them blocks it):
//!
//! * **Walker-shell duty cycling** — in `walker` mode, inter-plane ISLs
//!   follow a periodic gate (up for `duty · period_s` of each orbital
//!   period, with per-link phase from the Walker delta/star phasing),
//!   while intra-plane ISLs stay up: neighbours within one plane keep
//!   constant separation, neighbours across planes drift with the
//!   relative phasing of the planes.
//! * **Scripted outages** — absolute `[start, end)` intervals from the
//!   config during which a named ISL is down.
//! * **Ground-station passes** — while a satellite is in a pass its
//!   single radio points down, suppressing *all* its ISLs.
//!
//! The plan is queried in closed form (`link_up`, `next_fit`), and can be
//! materialised as the sorted contact-interval view the contact-plan
//! literature uses (`windows`). A plan whose gates never actually fire is
//! *degenerate* ([`ContactPlan::is_dynamic`] is false): the engines detect
//! this and take the legacy always-on broadcast arithmetic verbatim, which
//! is what keeps static-grid goldens bit-for-bit reproducible.
//!
//! The conservative-window lookahead contract lives in
//! [`CommModel::lookahead_at`](crate::network::CommModel::lookahead_at):
//! the plan's rate modifiers are slowing-only (`inter_rate_scale ≤ 1`,
//! `inter_extra_latency_s ≥ 0`), so the per-window minimum edge time the
//! sharded engine uses as its lookahead never shrinks below what a
//! scheduled chunk can achieve.

use crate::config::{TopologyConfig, TopologyMode, WalkerKind};
use crate::workload::SatId;

/// The constellation grid.
#[derive(Clone, Debug)]
pub struct GridTopology {
    n: usize,
}

impl GridTopology {
    /// Build an `n × n` grid (panics when `n < 2` — a single satellite
    /// has no ISLs to model).
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "grid needs n >= 2");
        GridTopology { n }
    }

    /// Grid scale `n` (planes = slots per plane = `n`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total number of satellites.
    pub fn len(&self) -> usize {
        self.n * self.n
    }

    /// Always false: `new` rejects grids below 2×2.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (orbit, slot) of a satellite id.
    #[inline]
    pub fn coords(&self, sat: SatId) -> (usize, usize) {
        debug_assert!(sat < self.len());
        (sat / self.n, sat % self.n)
    }

    /// Satellite id at (orbit, slot).
    #[inline]
    pub fn sat_at(&self, orbit: usize, slot: usize) -> SatId {
        debug_assert!(orbit < self.n && slot < self.n);
        orbit * self.n + slot
    }

    /// The 2–4 ISL neighbours of a satellite.
    pub fn neighbours(&self, sat: SatId) -> Vec<SatId> {
        let (o, s) = self.coords(sat);
        let mut out = Vec::with_capacity(4);
        if o > 0 {
            out.push(self.sat_at(o - 1, s));
        }
        if o + 1 < self.n {
            out.push(self.sat_at(o + 1, s));
        }
        if s > 0 {
            out.push(self.sat_at(o, s - 1));
        }
        if s + 1 < self.n {
            out.push(self.sat_at(o, s + 1));
        }
        out
    }

    /// Is the link (a, b) a single-hop ISL?
    pub fn adjacent(&self, a: SatId, b: SatId) -> bool {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        (ao == bo && as_.abs_diff(bs) == 1) || (as_ == bs && ao.abs_diff(bo) == 1)
    }

    /// Manhattan hop count between two satellites (ISL routing distance —
    /// grid shortest path since only grid links exist).
    pub fn hops(&self, a: SatId, b: SatId) -> usize {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        ao.abs_diff(bo) + as_.abs_diff(bs)
    }

    /// The ISL neighbour of `dst` on the grid route from `src`: the
    /// satellite a broadcast chunk crosses its *last* hop from. Routing is
    /// slot-corrected first (intra-plane), then orbit-corrected
    /// (inter-plane), matching the last-hop classification the chunked
    /// planner in [`comm`](crate::network::comm) uses for its link-rate
    /// and contact-window lookups.
    pub fn route_parent(&self, src: SatId, dst: SatId) -> SatId {
        debug_assert!(src != dst, "route_parent needs distinct endpoints");
        let (so, ss) = self.coords(src);
        let (mo, ms) = self.coords(dst);
        if ms != ss {
            // Last hop is intra-plane: step back along the slot axis.
            self.sat_at(mo, if ms > ss { ms - 1 } else { ms + 1 })
        } else {
            // Slots aligned: the last hop crosses planes.
            self.sat_at(if mo > so { mo - 1 } else { mo + 1 }, ms)
        }
    }

    /// Chebyshev distance (collaboration areas are square rings).
    pub fn chebyshev(&self, a: SatId, b: SatId) -> usize {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        ao.abs_diff(bo).max(as_.abs_diff(bs))
    }

    /// Collaboration area of radius `r` around `center`: all satellites with
    /// Chebyshev distance ≤ r, clamped at the grid boundary.
    ///
    /// * `r = 1` → the paper's **initial** area (center + surrounding);
    /// * `r = 2` → the **expanded** area (surrounding of all members).
    pub fn area(&self, center: SatId, r: usize) -> Vec<SatId> {
        let (o, s) = self.coords(center);
        let o_lo = o.saturating_sub(r);
        let o_hi = (o + r).min(self.n - 1);
        let s_lo = s.saturating_sub(r);
        let s_hi = (s + r).min(self.n - 1);
        let mut out = Vec::with_capacity((o_hi - o_lo + 1) * (s_hi - s_lo + 1));
        for oo in o_lo..=o_hi {
            for ss in s_lo..=s_hi {
                out.push(self.sat_at(oo, ss));
            }
        }
        out
    }

    /// Expand an existing area by one ring: the union of radius-1 areas of
    /// every member (`GetExpandedCoArea` in Alg. 2).
    pub fn expand_area(&self, area: &[SatId]) -> Vec<SatId> {
        let mut mask = vec![false; self.len()];
        for &sat in area {
            for member in self.area(sat, 1) {
                mask[member] = true;
            }
        }
        (0..self.len()).filter(|&i| mask[i]).collect()
    }

    /// All satellite ids.
    pub fn all(&self) -> impl Iterator<Item = SatId> {
        0..self.len()
    }
}

/// Iteration cap for the contact-search fixpoint: a chunk that cannot be
/// placed within this many window transitions is declared stranded. Far
/// beyond any plan the config validator accepts (a few periodic gates plus
/// a bounded outage list), so hitting it means genuine infeasibility, not
/// a tight budget.
const MAX_FIT_STEPS: usize = 4096;

/// One contact interval of a link, as materialised by
/// [`ContactPlan::windows`]: the link is continuously up on
/// `[start, end)` with the stated rate modifiers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContactWindow {
    /// One endpoint of the ISL.
    pub a: SatId,
    /// The other endpoint.
    pub b: SatId,
    /// Window start, virtual seconds (inclusive).
    pub start: f64,
    /// Window end, virtual seconds (exclusive).
    pub end: f64,
    /// Link-rate multiplier in effect during the window (≤ 1).
    pub rate_scale: f64,
    /// Extra per-chunk latency in effect during the window, seconds.
    pub extra_latency_s: f64,
}

/// When every ISL of the grid is actually up, and at what effective rate.
///
/// Built from a validated [`TopologyConfig`]; see the module docs for the
/// three composing ingredients (Walker duty gates, scripted outages,
/// ground passes) and for the degeneracy contract that keeps static
/// configs on the legacy broadcast path.
#[derive(Clone, Debug)]
pub struct ContactPlan {
    n: usize,
    cfg: TopologyConfig,
    dynamic: bool,
}

/// Periodic duty gate: phase-shifted sawtooth `u = t / period + phase`,
/// "on" while `fract(u) < duty`. Returns `(on_now, boundary)` where
/// `boundary` is the end of the current on-window when on, or the start of
/// the next on-window when off. Assumes `0 < duty < 1` (a full duty cycle
/// never gates and must be short-circuited by the caller).
fn periodic_gate(t: f64, period: f64, phase: f64, duty: f64) -> (bool, f64) {
    let u = t / period + phase;
    let k = u.floor();
    if u - k < duty {
        (true, (k - phase + duty) * period)
    } else {
        (false, (k + 1.0 - phase) * period)
    }
}

impl ContactPlan {
    /// Build the plan for an `n × n` grid from validated topology knobs.
    /// Outages are re-sorted by start time so interval queries can
    /// early-exit.
    pub fn new(n: usize, cfg: &TopologyConfig) -> Self {
        let mut cfg = cfg.clone();
        cfg.outages.sort_by(|x, y| {
            x.start
                .total_cmp(&y.start)
                .then(x.a.cmp(&y.a))
                .then(x.b.cmp(&y.b))
        });
        ContactPlan {
            n,
            dynamic: cfg.is_dynamic(),
            cfg,
        }
    }

    /// The degenerate always-on plan: every ISL permanently up — the
    /// static grid of the paper expressed as a contact plan.
    pub fn always_on(n: usize) -> Self {
        Self::new(n, &TopologyConfig::default())
    }

    /// Grid scale this plan was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// `true` when some link is ever down or rate-modified; `false` for
    /// plans whose gates can never fire (see
    /// [`TopologyConfig::is_dynamic`]). The engines branch on this to keep
    /// degenerate plans on the legacy static-grid arithmetic.
    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    /// Rate multiplier applied to inter-plane hops while the plan is
    /// dynamic (1.0 otherwise). Constant over time for the current plan
    /// families; `window_start`-dependent modifiers would surface here.
    pub fn inter_rate_scale(&self) -> f64 {
        if self.dynamic {
            self.cfg.inter_rate_scale
        } else {
            1.0
        }
    }

    /// Extra per-chunk latency on inter-plane hops while the plan is
    /// dynamic (0.0 otherwise).
    pub fn inter_extra_latency_s(&self) -> f64 {
        if self.dynamic {
            self.cfg.inter_extra_latency_s
        } else {
            0.0
        }
    }

    fn coords(&self, sat: SatId) -> (usize, usize) {
        (sat / self.n, sat % self.n)
    }

    /// Does the link cross planes? (Inter-plane links are the ones the
    /// Walker gate and the rate modifiers apply to.)
    pub fn is_inter(&self, a: SatId, b: SatId) -> bool {
        let (ao, as_) = self.coords(a);
        let (bo, bs) = self.coords(b);
        debug_assert!(
            (ao == bo && as_.abs_diff(bs) == 1) || (as_ == bs && ao.abs_diff(bo) == 1),
            "contact queries are defined on grid ISLs only ({a}-{b})"
        );
        as_ == bs
    }

    /// Is this link subject to the Walker duty gate?
    fn walker_gated(&self, a: SatId, b: SatId) -> bool {
        self.cfg.mode == TopologyMode::Walker && self.cfg.duty < 1.0 && self.is_inter(a, b)
    }

    /// Are ground passes configured at all?
    fn pass_gated(&self) -> bool {
        self.cfg.ground_stations > 0 && self.cfg.pass_duty > 0.0
    }

    /// Phase of the Walker gate for the inter-plane link between plane `o`
    /// and `o + 1` at slot `s`. Delta shells spread consecutive planes'
    /// windows by `F / n` of a period; star shells (counter-rotating
    /// seam) by half that. The `s / n` term staggers slots within a plane.
    fn inter_phase(&self, o: usize, s: usize) -> f64 {
        let n = self.n as f64;
        let f = self.cfg.phasing as f64;
        let raw = match self.cfg.kind {
            WalkerKind::Delta => (o as f64) * f / n + (s as f64) / n,
            WalkerKind::Star => 0.5 * (o as f64) * f / n + (s as f64) / n,
        };
        raw - raw.floor()
    }

    /// Phase of the pass gate for (station `g`, satellite `sat`):
    /// deterministic golden-ratio spread so passes don't synchronise
    /// across the constellation.
    fn pass_phase(&self, g: usize, sat: SatId) -> f64 {
        let x = (g as f64) * 0.618_033_988_749_895 + (sat as f64) * 0.381_966_011_250_105;
        x - x.floor()
    }

    /// If some constraint blocks the link at instant `t`, the time that
    /// constraint clears (strictly greater than `t`); `None` when the
    /// link is up at `t`.
    fn blocked_until(&self, a: SatId, b: SatId, t: f64) -> Option<f64> {
        for o in &self.cfg.outages {
            if o.start > t {
                break; // sorted by start: nothing later can cover t
            }
            if o.end > t && ((o.a == a && o.b == b) || (o.a == b && o.b == a)) {
                return Some(o.end);
            }
        }
        if self.walker_gated(a, b) {
            let (ao, as_) = self.coords(a);
            let (bo, _) = self.coords(b);
            let (up, boundary) =
                periodic_gate(t, self.cfg.period_s, self.inter_phase(ao.min(bo), as_), self.cfg.duty);
            if !up {
                return Some(boundary);
            }
        }
        if self.pass_gated() {
            for &e in &[a, b] {
                for g in 0..self.cfg.ground_stations {
                    let (in_pass, boundary) = periodic_gate(
                        t,
                        self.cfg.pass_period_s,
                        self.pass_phase(g, e),
                        self.cfg.pass_duty,
                    );
                    if in_pass {
                        return Some(boundary); // pass ends at the boundary
                    }
                }
            }
        }
        None
    }

    /// Is the ISL `(a, b)` up at instant `t`?
    pub fn link_up(&self, a: SatId, b: SatId, t: f64) -> bool {
        !self.dynamic || self.blocked_until(a, b, t).is_none()
    }

    /// Assuming the link is up at `t`, the end of the current contact
    /// (possibly `+inf` for an unconstrained link).
    fn up_until(&self, a: SatId, b: SatId, t: f64) -> f64 {
        let mut end = f64::INFINITY;
        for o in &self.cfg.outages {
            if o.start > t && ((o.a == a && o.b == b) || (o.a == b && o.b == a)) {
                end = end.min(o.start);
                break; // sorted by start: the first future outage is the nearest
            }
        }
        if self.walker_gated(a, b) {
            let (ao, as_) = self.coords(a);
            let (bo, _) = self.coords(b);
            let (up, boundary) =
                periodic_gate(t, self.cfg.period_s, self.inter_phase(ao.min(bo), as_), self.cfg.duty);
            debug_assert!(up);
            end = end.min(boundary);
        }
        if self.pass_gated() {
            for &e in &[a, b] {
                for g in 0..self.cfg.ground_stations {
                    let (in_pass, boundary) = periodic_gate(
                        t,
                        self.cfg.pass_period_s,
                        self.pass_phase(g, e),
                        self.cfg.pass_duty,
                    );
                    debug_assert!(!in_pass);
                    end = end.min(boundary); // next pass starts here
                }
            }
        }
        end
    }

    /// First constraint that prevents a transmission occupying the link
    /// for `[t, t + dur]`, and when it clears. `None` = the transmission
    /// fits starting at `t`. A contact that *ends* exactly at `t + dur`
    /// still fits (occupancy is closed-open).
    fn first_conflict(&self, a: SatId, b: SatId, t: f64, dur: f64) -> Option<f64> {
        let end = t + dur;
        for o in &self.cfg.outages {
            if o.start >= end {
                break;
            }
            if o.end > t && ((o.a == a && o.b == b) || (o.a == b && o.b == a)) {
                return Some(o.end);
            }
        }
        if self.walker_gated(a, b) {
            let (ao, as_) = self.coords(a);
            let (bo, _) = self.coords(b);
            let period = self.cfg.period_s;
            let duty = self.cfg.duty;
            let (up, boundary) =
                periodic_gate(t, period, self.inter_phase(ao.min(bo), as_), duty);
            if !up {
                return Some(boundary); // next window start
            }
            if boundary < end {
                // Window closes mid-transmission: retry at the next one.
                return Some(boundary + (1.0 - duty) * period);
            }
        }
        if self.pass_gated() {
            let period = self.cfg.pass_period_s;
            let duty = self.cfg.pass_duty;
            for &e in &[a, b] {
                for g in 0..self.cfg.ground_stations {
                    let (in_pass, boundary) =
                        periodic_gate(t, period, self.pass_phase(g, e), duty);
                    if in_pass {
                        return Some(boundary); // wait out the current pass
                    }
                    if boundary < end {
                        // A pass would interrupt the transmission: wait
                        // until that pass is over.
                        return Some(boundary + duty * period);
                    }
                }
            }
        }
        None
    }

    /// Earliest `start ≥ t0` such that the link is continuously up over
    /// `[start, start + dur]`, or `None` when no contact window can ever
    /// carry the transmission (e.g. a duty window shorter than the chunk).
    ///
    /// For a degenerate plan this is the identity (`Some(t0)`) — crucial
    /// for golden reproduction: the static path never even observes the
    /// plan's arithmetic.
    pub fn next_fit(&self, a: SatId, b: SatId, t0: f64, dur: f64) -> Option<f64> {
        if !self.dynamic {
            return Some(t0);
        }
        if self.walker_gated(a, b) && dur > self.cfg.duty * self.cfg.period_s {
            return None; // no duty window is ever long enough
        }
        let mut t = t0;
        for _ in 0..MAX_FIT_STEPS {
            match self.first_conflict(a, b, t, dur) {
                None => return Some(t),
                Some(clear) => {
                    debug_assert!(clear > t, "contact search must make progress");
                    t = clear;
                }
            }
        }
        None
    }

    /// Materialise the sorted contact-interval view of one link over
    /// `[t0, t1)` — the `(link, start, end, latency, bandwidth)` tuple
    /// list of the contact-plan literature. Diagnostic/test surface; the
    /// engines use the closed-form queries above instead.
    pub fn windows(&self, a: SatId, b: SatId, t0: f64, t1: f64) -> Vec<ContactWindow> {
        let (rate_scale, extra) = if self.is_inter(a, b) {
            (self.inter_rate_scale(), self.inter_extra_latency_s())
        } else {
            (1.0, 0.0)
        };
        let mut out = Vec::new();
        let mut t = t0;
        for _ in 0..MAX_FIT_STEPS {
            if t >= t1 {
                break;
            }
            match self.blocked_until(a, b, t) {
                Some(clear) => t = clear,
                None => {
                    let end = self.up_until(a, b, t).min(t1);
                    if end <= t {
                        break; // float-degenerate window; stop rather than spin
                    }
                    out.push(ContactWindow {
                        a,
                        b,
                        start: t,
                        end,
                        rate_scale,
                        extra_latency_s: extra,
                    });
                    t = end;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let g = GridTopology::new(5);
        for sat in g.all() {
            let (o, s) = g.coords(sat);
            assert_eq!(g.sat_at(o, s), sat);
        }
    }

    #[test]
    fn corner_has_two_neighbours_interior_four() {
        let g = GridTopology::new(5);
        assert_eq!(g.neighbours(0).len(), 2);
        assert_eq!(g.neighbours(g.sat_at(2, 2)).len(), 4);
        assert_eq!(g.neighbours(g.sat_at(0, 2)).len(), 3);
    }

    #[test]
    fn adjacency_symmetric_and_matches_hops() {
        let g = GridTopology::new(4);
        for a in g.all() {
            for b in g.all() {
                assert_eq!(g.adjacent(a, b), g.adjacent(b, a));
                assert_eq!(g.adjacent(a, b), g.hops(a, b) == 1);
            }
        }
    }

    #[test]
    fn area_radius1_center_is_3x3() {
        let g = GridTopology::new(5);
        let area = g.area(g.sat_at(2, 2), 1);
        assert_eq!(area.len(), 9);
        assert!(area.contains(&g.sat_at(2, 2)));
        assert!(area.contains(&g.sat_at(1, 1)));
        assert!(!area.contains(&g.sat_at(0, 0)));
    }

    #[test]
    fn area_clamps_at_boundary() {
        let g = GridTopology::new(5);
        assert_eq!(g.area(0, 1).len(), 4); // corner: 2x2
        assert_eq!(g.area(g.sat_at(0, 2), 1).len(), 6); // edge: 2x3
    }

    #[test]
    fn expand_area_equals_radius2_for_interior() {
        let g = GridTopology::new(7);
        let c = g.sat_at(3, 3);
        let mut expanded = g.expand_area(&g.area(c, 1));
        let mut radius2 = g.area(c, 2);
        expanded.sort_unstable();
        radius2.sort_unstable();
        assert_eq!(expanded, radius2);
    }

    #[test]
    fn expand_area_monotone() {
        let g = GridTopology::new(5);
        let initial = g.area(0, 1);
        let expanded = g.expand_area(&initial);
        assert!(expanded.len() > initial.len());
        for sat in &initial {
            assert!(expanded.contains(sat));
        }
    }

    #[test]
    fn hops_triangle_inequality() {
        let g = GridTopology::new(5);
        for a in g.all() {
            for b in g.all() {
                for c in g.all() {
                    assert!(g.hops(a, b) + g.hops(b, c) >= g.hops(a, c));
                }
            }
        }
    }

    #[test]
    fn chebyshev_le_hops() {
        let g = GridTopology::new(6);
        for a in g.all() {
            for b in g.all() {
                assert!(g.chebyshev(a, b) <= g.hops(a, b));
            }
        }
    }

    #[test]
    fn route_parent_steps_one_hop_toward_the_source() {
        let g = GridTopology::new(5);
        for src in g.all() {
            for dst in g.all() {
                if src == dst {
                    continue;
                }
                let p = g.route_parent(src, dst);
                assert!(g.adjacent(p, dst), "parent must own the last hop");
                assert_eq!(g.hops(src, p) + 1, g.hops(src, dst));
                // The last hop is inter-plane exactly when the chunked
                // planner classifies it so: slots aligned, orbits not.
                let (so, ss) = g.coords(src);
                let (mo, ms) = g.coords(dst);
                let last_hop_inter = if ms != ss { false } else { mo != so };
                let (po, ps) = g.coords(p);
                assert_eq!(ps == ms && po != mo, last_hop_inter);
            }
        }
    }

    fn walker_cfg(duty: f64, period: f64) -> TopologyConfig {
        TopologyConfig {
            mode: TopologyMode::Walker,
            duty,
            period_s: period,
            ..TopologyConfig::default()
        }
    }

    #[test]
    fn always_on_plan_is_degenerate_and_transparent() {
        let plan = ContactPlan::always_on(5);
        assert!(!plan.is_dynamic());
        assert_eq!(plan.inter_rate_scale(), 1.0);
        assert_eq!(plan.inter_extra_latency_s(), 0.0);
        assert!(plan.link_up(0, 1, 0.0));
        assert!(plan.link_up(0, 5, 1e9));
        // next_fit is the identity — the value the static path would use,
        // untouched by any plan arithmetic.
        assert_eq!(plan.next_fit(0, 5, 123.456, 7.89), Some(123.456));
        let w = plan.windows(0, 5, 0.0, 100.0);
        assert_eq!(w.len(), 1);
        assert_eq!((w[0].start, w[0].end), (0.0, 100.0));
    }

    #[test]
    fn full_duty_walker_is_degenerate() {
        let plan = ContactPlan::new(5, &walker_cfg(1.0, 600.0));
        assert!(!plan.is_dynamic());
        assert_eq!(plan.next_fit(0, 5, 10.0, 5.0), Some(10.0));
    }

    #[test]
    fn walker_duty_gates_inter_but_not_intra_links() {
        let plan = ContactPlan::new(5, &walker_cfg(0.5, 100.0));
        assert!(plan.is_dynamic());
        // Intra-plane link (same orbit): always up.
        let w = plan.windows(0, 1, 0.0, 1000.0);
        assert_eq!(w.len(), 1);
        assert_eq!((w[0].start, w[0].end), (0.0, 1000.0));
        // Inter-plane link (0-5): alternates 50 s up / 50 s down.
        let w = plan.windows(0, 5, 0.0, 1000.0);
        assert!(w.len() >= 9, "expected ~10 windows, got {}", w.len());
        for win in &w {
            assert!((win.end - win.start) <= 50.0 + 1e-9);
            assert!(plan.link_up(0, 5, win.start));
            assert!(plan.link_up(0, 5, (win.start + win.end) / 2.0));
        }
        for pair in w.windows(2) {
            assert!(pair[0].end < pair[1].start, "windows sorted and disjoint");
            let gap = (pair[0].end + pair[1].start) / 2.0;
            assert!(!plan.link_up(0, 5, gap));
        }
    }

    #[test]
    fn next_fit_defers_into_a_window_and_respects_its_length() {
        let plan = ContactPlan::new(5, &walker_cfg(0.5, 100.0));
        let w = plan.windows(0, 5, 0.0, 500.0);
        let first = w[0];
        // Asking from inside a window with room: identity.
        assert_eq!(plan.next_fit(0, 5, first.start, 1.0), Some(first.start));
        // Asking mid-gap: deferred to the next window start.
        let gap = first.end + 1.0;
        let start = plan.next_fit(0, 5, gap, 1.0).unwrap();
        assert!(start > gap);
        assert!(plan.link_up(0, 5, start));
        // A transmission longer than any duty window can never fit.
        assert_eq!(plan.next_fit(0, 5, 0.0, 51.0), None);
        // A fit that ends exactly at the window boundary is allowed.
        let fit = plan.next_fit(0, 5, first.start, first.end - first.start);
        assert_eq!(fit, Some(first.start));
    }

    #[test]
    fn scripted_outage_splits_windows_and_defers_fits() {
        let cfg = TopologyConfig {
            outages: vec![crate::config::OutageSpec {
                a: 3,
                b: 4,
                start: 100.0,
                end: 200.0,
            }],
            ..TopologyConfig::default()
        };
        let plan = ContactPlan::new(5, &cfg);
        assert!(plan.is_dynamic());
        // The named link goes down on [100, 200); others are untouched.
        assert!(plan.link_up(3, 4, 99.0));
        assert!(!plan.link_up(3, 4, 100.0));
        assert!(!plan.link_up(4, 3, 150.0));
        assert!(plan.link_up(3, 4, 200.0));
        assert!(plan.link_up(0, 1, 150.0));
        let w = plan.windows(3, 4, 0.0, 300.0);
        assert_eq!(w.len(), 2);
        assert_eq!((w[0].start, w[0].end), (0.0, 100.0));
        assert_eq!((w[1].start, w[1].end), (200.0, 300.0));
        // A transmission queued just before the outage that would overlap
        // it resumes at the outage end.
        assert_eq!(plan.next_fit(3, 4, 95.0, 10.0), Some(200.0));
        assert_eq!(plan.next_fit(3, 4, 95.0, 5.0), Some(95.0));
    }

    #[test]
    fn ground_pass_suppresses_every_isl_of_the_satellite() {
        let cfg = TopologyConfig {
            ground_stations: 1,
            pass_period_s: 100.0,
            pass_duty: 0.2,
            ..TopologyConfig::default()
        };
        let plan = ContactPlan::new(5, &cfg);
        assert!(plan.is_dynamic());
        // Find an instant where sat 6 is in a pass, via its link going down.
        let w = plan.windows(6, 7, 0.0, 300.0);
        assert!(w.len() >= 2, "passes must interrupt the link: {w:?}");
        let gap = (w[0].end + w[1].start) / 2.0;
        // During the gap at least one endpoint is in a pass; every ISL of
        // that endpoint must be down. Identify which endpoint by probing.
        let six_down = !plan.link_up(6, 1, gap) && !plan.link_up(6, 5, gap);
        let seven_down = !plan.link_up(7, 2, gap) && !plan.link_up(7, 8, gap);
        assert!(
            six_down || seven_down,
            "a pass must silence all ISLs of the satellite in pass"
        );
    }

    #[test]
    fn next_fit_lands_inside_a_materialised_window() {
        // Cross-check the closed-form search against the interval view.
        let cfg = TopologyConfig {
            outages: vec![crate::config::OutageSpec {
                a: 0,
                b: 5,
                start: 40.0,
                end: 60.0,
            }],
            ..walker_cfg(0.6, 100.0)
        };
        let plan = ContactPlan::new(5, &cfg);
        let windows = plan.windows(0, 5, 0.0, 1000.0);
        for t0 in [0.0, 10.0, 45.0, 59.0, 61.0, 70.0, 123.0] {
            let dur = 7.5;
            let start = plan.next_fit(0, 5, t0, dur).unwrap();
            assert!(start >= t0);
            let host = windows
                .iter()
                .find(|w| w.start <= start && start + dur <= w.end);
            assert!(
                host.is_some(),
                "fit at {start} (+{dur}) not inside any window: {windows:?}"
            );
            // And no earlier placement exists: either t0 itself fits, or
            // the chosen start is a window start.
            if start > t0 {
                assert!(
                    windows.iter().any(|w| w.start == start),
                    "deferred fit must begin exactly at a contact start"
                );
            }
        }
    }
}
