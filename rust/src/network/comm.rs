//! ISL communication model — eqs. (1)–(5) of the paper.
//!
//! * eq. (3): free-space path loss `L = (4π f_c d / c)²`
//! * eq. (4): noise PSD `N₀ = k_B T B_s`
//! * eq. (2): `SNR = Pow_t G_tx G_rx / (N₀ L)`
//! * eq. (1): `r = B_s log₂(1 + SNR)`
//! * eq. (5): record-sharing cost aggregated per collaboration event
//!
//! Satellites only talk to grid neighbours (Sec. III-B), so record
//! broadcasts propagate hop-by-hop; the data-transfer volume criterion
//! counts every byte crossing every link.

use crate::config::{CommConfig, NetworkConfig};
use crate::network::topology::GridTopology;
use crate::workload::SatId;

/// Boltzmann constant, J/K.
pub const BOLTZMANN: f64 = 1.380_649e-23;
/// Speed of light, m/s.
pub const SPEED_OF_LIGHT: f64 = 2.997_924_58e8;

/// A planned spanning-tree broadcast (see [`CommModel::plan_broadcast`]).
#[derive(Clone, Debug)]
pub struct BroadcastPlan {
    /// Total bytes crossing ISLs (records × tree edges × record size).
    pub bytes: f64,
    /// Total link airtime Ψ contribution, seconds.
    pub airtime_s: f64,
    /// Slowest single-hop record transmission time, seconds.
    pub bottleneck_s: f64,
    /// `(member, tree depth)` for every receiving area member.
    pub arrivals: Vec<(crate::workload::SatId, usize)>,
}

impl BroadcastPlan {
    /// Virtual arrival offset of record `k` at a member of depth `h`.
    pub fn arrival_offset(&self, k: usize, depth: usize) -> f64 {
        (k + depth) as f64 * self.bottleneck_s
    }

    /// When the last record reaches the deepest member.
    pub fn completion_offset(&self, records: usize) -> f64 {
        let max_depth = self.arrivals.iter().map(|&(_, d)| d).max().unwrap_or(0);
        self.arrival_offset(records.saturating_sub(1), max_depth)
    }
}

/// Evaluated ISL link budget.
#[derive(Clone, Copy, Debug)]
pub struct LinkBudget {
    pub distance_m: f64,
    pub path_loss: f64,
    pub noise_w: f64,
    pub snr: f64,
    /// Achievable data rate, bits/s (eq. 1).
    pub rate_bps: f64,
}

/// The communication model over a grid topology.
#[derive(Clone, Debug)]
pub struct CommModel {
    cfg: CommConfig,
    intra_rate_bps: f64,
    inter_rate_bps: f64,
}

impl CommModel {
    pub fn new(net: &NetworkConfig, cfg: &CommConfig) -> Self {
        let intra = Self::link_budget(cfg, net.intra_plane_distance_m);
        let inter = Self::link_budget(cfg, net.inter_plane_distance_m);
        CommModel {
            cfg: cfg.clone(),
            intra_rate_bps: intra.rate_bps,
            inter_rate_bps: inter.rate_bps,
        }
    }

    /// Full link-budget evaluation at a distance (eqs. 1–4).
    pub fn link_budget(cfg: &CommConfig, distance_m: f64) -> LinkBudget {
        let gain = 10f64.powf(cfg.antenna_gain_dbi / 10.0);
        let path_loss = (4.0 * std::f64::consts::PI * cfg.carrier_hz * distance_m
            / SPEED_OF_LIGHT)
            .powi(2);
        let noise_w = BOLTZMANN * cfg.noise_temp_k * cfg.bandwidth_hz;
        let snr = cfg.tx_power_w * gain * gain / (noise_w * path_loss);
        let rate_bps = cfg.bandwidth_hz * (1.0 + snr).log2();
        LinkBudget {
            distance_m,
            path_loss,
            noise_w,
            snr,
            rate_bps,
        }
    }

    /// Data rate of the direct link between two *adjacent* satellites.
    pub fn link_rate_bps(&self, topo: &GridTopology, a: SatId, b: SatId) -> f64 {
        debug_assert!(topo.adjacent(a, b), "link_rate on non-adjacent pair");
        let (ao, _) = topo.coords(a);
        let (bo, _) = topo.coords(b);
        if ao == bo {
            self.intra_rate_bps // same orbital plane
        } else {
            self.inter_rate_bps
        }
    }

    /// Bytes of one shared record (`D_t + R_t`).
    pub fn record_bytes(&self) -> f64 {
        self.cfg.record_input_bytes + self.cfg.record_output_bytes
    }

    /// Seconds to push `bytes` over one intra-plane hop.
    pub fn hop_seconds(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.intra_rate_bps
    }

    /// Conservative broadcast lookahead: the time one shared record needs
    /// to cross the *fastest* ISL hop. Every [`BroadcastPlan`] delivery
    /// lands at `(k + depth) · bottleneck` past its collaboration instant
    /// with `depth ≥ 1` and `bottleneck` the slowest of the plan's edge
    /// times — both edge kinds are bounded below by this value — so no
    /// broadcast scheduled at virtual time `t` can reach any satellite
    /// before `t + min_hop_seconds()`. That bound is exactly the window a
    /// sharded conservative event engine may process without cross-shard
    /// exchange. Degenerate configs (zero-byte records, non-finite link
    /// rates) make this zero/NaN; the sharded engine rejects those.
    pub fn min_hop_seconds(&self) -> f64 {
        let bits = self.record_bytes() * 8.0;
        (bits / self.intra_rate_bps).min(bits / self.inter_rate_bps)
    }

    /// Seconds to deliver `records` records from `src` to `dst` hop-by-hop
    /// along a grid shortest path (links traversed sequentially, eq. 5).
    pub fn delivery_seconds(
        &self,
        topo: &GridTopology,
        src: SatId,
        dst: SatId,
        records: usize,
    ) -> f64 {
        if src == dst {
            return 0.0;
        }
        let payload = records as f64 * self.record_bytes();
        let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
        payload * 8.0
            * (hops_intra as f64 / self.intra_rate_bps
                + hops_inter as f64 / self.inter_rate_bps)
    }

    /// Plan a broadcast as a **spanning-tree flood** over the collaboration
    /// area: each record crosses each tree edge exactly once (intermediate
    /// satellites relay and keep a copy — they are area members), so the
    /// transferred volume is `records × (|area| − 1) × record_bytes`. This
    /// is how constellation multicast actually works and is the only
    /// accounting consistent with the paper's Table III volumes.
    ///
    /// Returns `(total_bytes, airtime_seconds, arrivals)` where `arrivals`
    /// gives each member's tree depth (records pipeline hop-by-hop: record
    /// `k` reaches depth `h` at `(k + h) · t_bottleneck`).
    pub fn plan_broadcast(
        &self,
        topo: &GridTopology,
        src: SatId,
        area: &[SatId],
        records: usize,
    ) -> BroadcastPlan {
        let t_intra = self.record_bytes() * 8.0 / self.intra_rate_bps;
        let t_inter = self.record_bytes() * 8.0 / self.inter_rate_bps;
        // BFS tree over area members: parent = an area neighbour one grid
        // hop closer to the source (grid Manhattan metric, which is exact
        // for rectangular areas).
        let mut arrivals = Vec::with_capacity(area.len());
        let mut edge_airtime = 0.0;
        let mut bottleneck: f64 = 0.0;
        for &m in area {
            if m == src {
                continue;
            }
            let depth = topo.hops(src, m);
            // edge into `m`: from the neighbour one hop closer; classify by
            // whether the last hop crosses planes. Walk: reduce the larger
            // coordinate difference first; the final hop type depends on
            // which difference remains.
            let (so, ss) = topo.coords(src);
            let (mo, ms) = topo.coords(m);
            let last_hop_inter = if ms != ss { false } else { mo != so };
            let t_edge = if last_hop_inter { t_inter } else { t_intra };
            edge_airtime += t_edge * records as f64;
            bottleneck = bottleneck.max(t_edge);
            arrivals.push((m, depth));
        }
        BroadcastPlan {
            bytes: records as f64
                * self.record_bytes()
                * arrivals.len() as f64,
            airtime_s: edge_airtime,
            bottleneck_s: bottleneck,
            arrivals,
        }
    }

    /// Arrival time offset of the `k`-th record of a streamed broadcast at
    /// `dst` (store-and-forward pipelining): the first record takes the full
    /// path time; each subsequent record lands one bottleneck-hop
    /// transmission later.
    pub fn streamed_arrival_seconds(
        &self,
        topo: &GridTopology,
        src: SatId,
        dst: SatId,
        k: usize,
    ) -> f64 {
        if src == dst {
            return 0.0;
        }
        let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
        let path = self.delivery_seconds(topo, src, dst, 1);
        let per_hop_intra = self.record_bytes() * 8.0 / self.intra_rate_bps;
        let per_hop_inter = self.record_bytes() * 8.0 / self.inter_rate_bps;
        let bottleneck = match (hops_intra > 0, hops_inter > 0) {
            (true, true) => per_hop_intra.max(per_hop_inter),
            (true, false) => per_hop_intra,
            _ => per_hop_inter,
        };
        path + k as f64 * bottleneck
    }

    /// Cost of delivering `records` records from `src` to every *other*
    /// member of `area`, hop-by-hop along grid shortest paths.
    ///
    /// Returns `(total_bytes_transferred, completion_seconds)`:
    /// * bytes count every link crossing (a 2-hop delivery moves the
    ///   payload twice) — this is what Table III accumulates;
    /// * completion time is the slowest receiver's path time, links
    ///   traversed sequentially per eq. (5) (`τ · (D_t + R_t) / r`).
    pub fn broadcast_cost(
        &self,
        topo: &GridTopology,
        src: SatId,
        area: &[SatId],
        records: usize,
    ) -> (f64, f64) {
        let payload = records as f64 * self.record_bytes();
        let mut total_bytes = 0.0;
        let mut worst_seconds: f64 = 0.0;
        for &dst in area {
            if dst == src {
                continue;
            }
            let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
            let hops = hops_intra + hops_inter;
            total_bytes += payload * hops as f64;
            worst_seconds =
                worst_seconds.max(self.delivery_seconds(topo, src, dst, records));
        }
        (total_bytes, worst_seconds)
    }

    /// Decompose the grid shortest path into intra-/inter-plane hops.
    fn split_hops(&self, topo: &GridTopology, a: SatId, b: SatId) -> (usize, usize) {
        let (ao, as_) = topo.coords(a);
        let (bo, bs) = topo.coords(b);
        (as_.abs_diff(bs), ao.abs_diff(bo))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn model() -> (GridTopology, CommModel) {
        let cfg = SimConfig::paper_default(5);
        (
            GridTopology::new(5),
            CommModel::new(&cfg.network, &cfg.comm),
        )
    }

    #[test]
    fn link_budget_physics_sane() {
        let cfg = SimConfig::paper_default(5);
        let lb = CommModel::link_budget(&cfg.comm, 1.1e6);
        // 26 GHz over 1100 km: FSPL ≈ 182 dB
        let fspl_db = 10.0 * lb.path_loss.log10();
        assert!((180.0..185.0).contains(&fspl_db), "FSPL {fspl_db} dB");
        assert!(lb.snr > 1.0, "link must close: snr {}", lb.snr);
        // rate must be in the tens-to-hundreds of Mbps for a 20 MHz channel
        assert!(
            (2e7..4e8).contains(&lb.rate_bps),
            "rate {} bps",
            lb.rate_bps
        );
    }

    #[test]
    fn shorter_link_is_faster() {
        let cfg = SimConfig::paper_default(5);
        let near = CommModel::link_budget(&cfg.comm, 0.8e6);
        let far = CommModel::link_budget(&cfg.comm, 1.1e6);
        assert!(near.rate_bps > far.rate_bps);
    }

    #[test]
    fn record_bytes_matches_uc_merced_scaling() {
        let (_, m) = model();
        // 12817 MB / 625 ≈ 20.5 MB
        assert!((m.record_bytes() - 20.508e6).abs() < 0.1e6);
    }

    #[test]
    fn broadcast_to_adjacent_one_hop() {
        let (topo, m) = model();
        let src = topo.sat_at(2, 2);
        let dst = topo.sat_at(2, 3);
        let (bytes, secs) = m.broadcast_cost(&topo, src, &[src, dst], 1);
        assert!((bytes - m.record_bytes()).abs() < 1.0);
        assert!(secs > 0.0);
    }

    #[test]
    fn broadcast_bytes_scale_with_hops_and_records() {
        let (topo, m) = model();
        let src = topo.sat_at(0, 0);
        let far = topo.sat_at(2, 2); // 4 hops
        let (b1, _) = m.broadcast_cost(&topo, src, &[src, far], 1);
        assert!((b1 - 4.0 * m.record_bytes()).abs() < 1.0);
        let (b3, _) = m.broadcast_cost(&topo, src, &[src, far], 3);
        assert!((b3 - 3.0 * b1).abs() < 1.0);
    }

    #[test]
    fn broadcast_area_cost_superset_monotone() {
        let (topo, m) = model();
        let src = topo.sat_at(2, 2);
        let small = topo.area(src, 1);
        let large = topo.area(src, 2);
        let (bs, ts) = m.broadcast_cost(&topo, src, &small, 5);
        let (bl, tl) = m.broadcast_cost(&topo, src, &large, 5);
        assert!(bl > bs);
        assert!(tl >= ts);
    }

    #[test]
    fn min_hop_lookahead_bounds_every_broadcast_arrival() {
        let (topo, m) = model();
        let lookahead = m.min_hop_seconds();
        assert!(lookahead.is_finite() && lookahead > 0.0, "{lookahead}");
        // No arrival of any plan may land before `t + lookahead`.
        for src in [topo.sat_at(0, 0), topo.sat_at(2, 2)] {
            for r in [1usize, 2] {
                let area = topo.area(src, r);
                let plan = m.plan_broadcast(&topo, src, &area, 5);
                for &(_, depth) in &plan.arrivals {
                    for k in 0..5 {
                        assert!(
                            plan.arrival_offset(k, depth) >= lookahead,
                            "offset {} < lookahead {lookahead}",
                            plan.arrival_offset(k, depth)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_byte_records_collapse_the_lookahead() {
        let mut cfg = SimConfig::paper_default(5);
        cfg.comm.record_input_bytes = 0.0;
        cfg.comm.record_output_bytes = 0.0;
        let m = CommModel::new(&cfg.network, &cfg.comm);
        assert_eq!(m.min_hop_seconds(), 0.0);
    }

    #[test]
    fn src_not_counted_as_receiver() {
        let (topo, m) = model();
        let src = topo.sat_at(1, 1);
        let (bytes, secs) = m.broadcast_cost(&topo, src, &[src], 7);
        assert_eq!(bytes, 0.0);
        assert_eq!(secs, 0.0);
    }
}
