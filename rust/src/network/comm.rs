//! ISL communication model — eqs. (1)–(5) of the paper.
//!
//! * eq. (3): free-space path loss `L = (4π f_c d / c)²`
//! * eq. (4): noise PSD `N₀ = k_B T B_s`
//! * eq. (2): `SNR = Pow_t G_tx G_rx / (N₀ L)`
//! * eq. (1): `r = B_s log₂(1 + SNR)`
//! * eq. (5): record-sharing cost aggregated per collaboration event
//!
//! Satellites only talk to grid neighbours (Sec. III-B), so record
//! broadcasts propagate hop-by-hop; the data-transfer volume criterion
//! counts every byte crossing every link.
//!
//! Under a time-varying [`ContactPlan`], the chunked planner additionally
//! gates every last-hop transmission on a contact window: a chunk whose
//! link is down (outage, Walker duty gap, ground pass) waits for the next
//! window (`handover`), and a chunk no window can ever carry is
//! `stranded`. The conservative lookahead the sharded engine needs is the
//! per-window query [`CommModel::lookahead_at`]; its soundness rests on
//! the plan's modifiers being slowing-only — see that method's docs.

use std::collections::HashMap;

use crate::config::{CommConfig, NetworkConfig};
use crate::network::topology::{ContactPlan, GridTopology};
use crate::util::rng::hash_unit;
use crate::workload::SatId;

/// Boltzmann constant, J/K.
pub const BOLTZMANN: f64 = 1.380_649e-23;
/// Speed of light, m/s.
pub const SPEED_OF_LIGHT: f64 = 2.997_924_58e8;

/// A planned spanning-tree broadcast (see [`CommModel::plan_broadcast`]).
#[derive(Clone, Debug)]
pub struct BroadcastPlan {
    /// Total bytes crossing ISLs (records × tree edges × record size).
    pub bytes: f64,
    /// Total link airtime Ψ contribution, seconds.
    pub airtime_s: f64,
    /// Slowest single-hop record transmission time, seconds.
    pub bottleneck_s: f64,
    /// `(member, tree depth)` for every receiving area member.
    pub arrivals: Vec<(crate::workload::SatId, usize)>,
}

impl BroadcastPlan {
    /// Virtual arrival offset of record `k` at a member of depth `h`.
    pub fn arrival_offset(&self, k: usize, depth: usize) -> f64 {
        (k + depth) as f64 * self.bottleneck_s
    }

    /// When the last record reaches the deepest member.
    pub fn completion_offset(&self, records: usize) -> f64 {
        let max_depth = self.arrivals.iter().map(|&(_, d)| d).max().unwrap_or(0);
        self.arrival_offset(records.saturating_sub(1), max_depth)
    }
}

/// One scheduled chunk arrival of a lossy broadcast.
#[derive(Clone, Copy, Debug)]
pub struct ChunkDelivery {
    /// Virtual arrival time at the destination.
    pub time: f64,
    /// Receiving satellite.
    pub dst: SatId,
    /// Index into the broadcast's record list (plan order).
    pub rec_slot: usize,
    /// Chunk index within the record.
    pub chunk_seq: usize,
    /// Chunks per record of this transfer.
    pub total_chunks: usize,
}

/// One scheduled retransmission timeout (a lost or corrupted attempt
/// detected at the sender). `dropped` marks the final attempt: the chunk
/// is abandoned and its record stays incomplete at that destination.
#[derive(Clone, Copy, Debug)]
pub struct ChunkTimeout {
    /// Virtual time the sender detects the failure.
    pub time: f64,
    /// Broadcasting satellite (where the timeout event fires).
    pub src: SatId,
    /// Final attempt: the chunk is abandoned rather than retried.
    pub dropped: bool,
}

/// A fully resolved lossy broadcast (see
/// [`CommModel::plan_lossy_broadcast`]): every chunk fate, retransmission
/// and queueing delay is decided at plan time, so replaying the schedule
/// is engine-independent by construction.
#[derive(Clone, Debug)]
pub struct LossyPlan {
    /// Bytes actually put on ingest links (every attempt pays).
    pub bytes: f64,
    /// Link airtime Ψ contribution, seconds (every attempt pays).
    pub airtime_s: f64,
    /// Scheduled chunk arrivals, in plan order.
    pub deliveries: Vec<ChunkDelivery>,
    /// Scheduled sender-side failure detections, in plan order.
    pub timeouts: Vec<ChunkTimeout>,
    /// Failed attempts that were retried.
    pub retransmits: u64,
    /// Chunks abandoned after exhausting retries.
    pub dropped_chunks: u64,
    /// Bytes *not* re-sent because the destination already held the chunk
    /// from an earlier broadcast (content-id dedup).
    pub dedup_saved_bytes: f64,
    /// Chunk sends deferred to a later contact window of their last-hop
    /// link (always 0 under a degenerate plan).
    pub handovers: u64,
    /// Total seconds deferred chunks spent waiting for a contact window.
    pub contact_wait_s: f64,
    /// Chunks abandoned because no contact window can ever carry them
    /// (e.g. a Walker duty window shorter than one chunk transmission).
    /// Unlike drops these never touch the wire: no bytes, no airtime, no
    /// timeout event.
    pub stranded_chunks: u64,
    /// Chunks abandoned because the broadcasting satellite was down at
    /// their transmit start (node-fault model): a dead sender puts nothing
    /// on the wire and detects nothing, so — like stranding — these
    /// schedule neither a delivery nor a timeout. Always 0 on the
    /// fault-free path.
    pub crash_dropped_chunks: u64,
    /// When the network falls quiet: the latest scheduled delivery or
    /// timeout (`now` if every chunk deduped away).
    pub quiet_until: f64,
}

/// Shared transfer-cache + link-contention state threaded through every
/// lossy broadcast of a run.
///
/// * `possession` is a content-addressed cache keyed by `(holder,
///   record id)`: the earliest scheduled arrival of each chunk at each
///   satellite. It never forgets — SCRT eviction is a *compute*-side
///   policy, while possession models the transfer layer's knowledge of
///   which bytes already crossed which link. A record evicted and
///   re-broadcast therefore re-pays only chunks the holder never
///   received, which is also what makes resume-after-drop work: the
///   delivered prefix of a partially dropped record is skipped by the
///   next broadcast and only the missing chunks are re-sent.
/// * `busy_until` is each satellite's ingest-link FIFO horizon —
///   concurrent broadcasts contend for it in resolution order.
///
/// Chunk fates are *not* drawn from this state: they come from the pure
/// counter-hash [`hash_unit`] keyed by `(seed, transfer, dst, chunk,
/// attempt)`, so no draw depends on event interleaving. Maps are only
/// ever indexed by key (never iterated), keeping the plan deterministic.
#[derive(Clone, Debug)]
pub struct LinkState {
    seed: u64,
    next_transfer: u64,
    possession: HashMap<(SatId, usize), Vec<f64>>,
    busy_until: HashMap<SatId, f64>,
}

impl LinkState {
    /// Fresh transfer-layer state for a run seeded with `seed` (the seed
    /// keys every chunk-fate hash draw).
    pub fn new(seed: u64) -> Self {
        LinkState {
            seed,
            next_transfer: 0,
            possession: HashMap::new(),
            busy_until: HashMap::new(),
        }
    }

    /// Does `sat` hold chunk `chunk` of `record_id` at virtual time `t`
    /// (i.e. its scheduled arrival is no later than `t`)?
    pub fn holds(&self, sat: SatId, record_id: usize, chunk: usize, t: f64) -> bool {
        self.possession
            .get(&(sat, record_id))
            .and_then(|v| v.get(chunk))
            .is_some_and(|&arr| arr <= t)
    }
}

/// Evaluated ISL link budget.
#[derive(Clone, Copy, Debug)]
pub struct LinkBudget {
    /// Link distance, metres.
    pub distance_m: f64,
    /// Free-space path loss `L` (eq. 3), linear.
    pub path_loss: f64,
    /// Noise power `N₀` (eq. 4), watts.
    pub noise_w: f64,
    /// Signal-to-noise ratio (eq. 2), linear.
    pub snr: f64,
    /// Achievable data rate, bits/s (eq. 1).
    pub rate_bps: f64,
}

/// The communication model over a grid topology.
#[derive(Clone, Debug)]
pub struct CommModel {
    cfg: CommConfig,
    intra_rate_bps: f64,
    inter_rate_bps: f64,
}

impl CommModel {
    /// Evaluate the link budgets for the configured intra-/inter-plane
    /// distances and freeze the resulting rates.
    pub fn new(net: &NetworkConfig, cfg: &CommConfig) -> Self {
        let intra = Self::link_budget(cfg, net.intra_plane_distance_m);
        let inter = Self::link_budget(cfg, net.inter_plane_distance_m);
        CommModel {
            cfg: cfg.clone(),
            intra_rate_bps: intra.rate_bps,
            inter_rate_bps: inter.rate_bps,
        }
    }

    /// Full link-budget evaluation at a distance (eqs. 1–4).
    pub fn link_budget(cfg: &CommConfig, distance_m: f64) -> LinkBudget {
        let gain = 10f64.powf(cfg.antenna_gain_dbi / 10.0);
        let path_loss = (4.0 * std::f64::consts::PI * cfg.carrier_hz * distance_m
            / SPEED_OF_LIGHT)
            .powi(2);
        let noise_w = BOLTZMANN * cfg.noise_temp_k * cfg.bandwidth_hz;
        let snr = cfg.tx_power_w * gain * gain / (noise_w * path_loss);
        let rate_bps = cfg.bandwidth_hz * (1.0 + snr).log2();
        LinkBudget {
            distance_m,
            path_loss,
            noise_w,
            snr,
            rate_bps,
        }
    }

    /// Data rate of the direct link between two *adjacent* satellites.
    pub fn link_rate_bps(&self, topo: &GridTopology, a: SatId, b: SatId) -> f64 {
        debug_assert!(topo.adjacent(a, b), "link_rate on non-adjacent pair");
        let (ao, _) = topo.coords(a);
        let (bo, _) = topo.coords(b);
        if ao == bo {
            self.intra_rate_bps // same orbital plane
        } else {
            self.inter_rate_bps
        }
    }

    /// Bytes of one shared record (`D_t + R_t`).
    pub fn record_bytes(&self) -> f64 {
        self.cfg.record_input_bytes + self.cfg.record_output_bytes
    }

    /// Seconds to push `bytes` over one intra-plane hop.
    pub fn hop_seconds(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.intra_rate_bps
    }

    /// Intra-plane rate with the per-link bandwidth cap applied.
    /// `x.min(INFINITY)` is exactly `x`, so an uncapped config reproduces
    /// the raw link-budget rate bit-for-bit.
    #[inline]
    fn eff_intra_rate_bps(&self) -> f64 {
        self.intra_rate_bps.min(self.cfg.link_bandwidth_bps)
    }

    /// Inter-plane rate with the per-link bandwidth cap applied.
    #[inline]
    fn eff_inter_rate_bps(&self) -> f64 {
        self.inter_rate_bps.min(self.cfg.link_bandwidth_bps)
    }

    /// Wire size of one transfer chunk: the configured chunk size clamped
    /// to the record payload (`INFINITY` chunking = whole-record chunks,
    /// the legacy model — the clamp makes that exact, not approximate).
    /// Every chunk, including a partial tail, occupies a full chunk slot
    /// on the wire; the padding is what keeps the per-chunk hop time a
    /// uniform lower-boundable quantity (see [`Self::min_hop_seconds`]).
    pub fn chunk_bytes_effective(&self) -> f64 {
        self.cfg.chunk_bytes.min(self.record_bytes())
    }

    /// Chunks per shared record.
    pub fn chunks_per_record(&self) -> usize {
        let eff = self.chunk_bytes_effective();
        if eff > 0.0 {
            (self.record_bytes() / eff).ceil().max(1.0) as usize
        } else {
            1 // zero-byte records: degenerate, rejected by the sharded engine
        }
    }

    /// Conservative broadcast lookahead: the time one transfer chunk needs
    /// to cross the *fastest* (bandwidth-capped) ISL hop. Every delivery
    /// and retransmission timeout of either plan flavour is scheduled at
    /// least one last-hop chunk transmission past its collaboration
    /// instant, and that transmission time is one of the two operands of
    /// this `min` — so no scheduled event of a broadcast resolved at
    /// virtual time `t` can land before `t + min_hop_seconds()`, and the
    /// bound survives retransmission (later attempts only push times
    /// further out). That is exactly the window a sharded conservative
    /// event engine may process without cross-shard exchange. With the
    /// fault model off this reduces bit-for-bit to the pre-fault value
    /// (`record_bytes` over the raw rates): `chunk.min(INFINITY)` and
    /// `rate.min(INFINITY)` are exact identities. Degenerate configs
    /// (zero-byte records, non-finite link rates) make this zero/NaN; the
    /// sharded engine rejects those. Under a contact plan the per-window
    /// generalisation is [`Self::lookahead_at`]; this is its always-on
    /// specialisation.
    pub fn min_hop_seconds(&self) -> f64 {
        let bits = self.chunk_bytes_effective() * 8.0;
        (bits / self.eff_intra_rate_bps()).min(bits / self.eff_inter_rate_bps())
    }

    /// Per-window conservative lookahead over a contact plan: a lower
    /// bound on how far past `window_start` any event scheduled by a
    /// broadcast resolved inside the window `[window_start, window_start +
    /// lookahead)` can land.
    ///
    /// **Soundness.** Every scheduled delivery or timeout lies at least
    /// one *effective* last-hop chunk transmission past its collaboration
    /// instant, and contact gating only moves transmissions later
    /// (`next_fit` defers, never advances; stranded chunks schedule
    /// nothing at all). The plan's rate modifiers are slowing-only by
    /// validation (`inter_rate_scale ∈ (0, 1]`, `inter_extra_latency_s ≥
    /// 0`), so the effective inter-plane edge time `t_inter /
    /// inter_rate_scale + inter_extra_latency_s` is computed here with the
    /// *same* IEEE operations the planner uses — the bound is float-exact,
    /// not approximate. For a degenerate plan this returns
    /// [`Self::min_hop_seconds`] bit-for-bit (same expression, untouched
    /// operands), which is what keeps static-grid window boundaries — and
    /// therefore whole runs — identical to the pre-contact-plan engine.
    ///
    /// The bound is constant in `window_start` for the current plan
    /// families (periodic gates and outages change *availability*, not
    /// rates); a plan with time-varying rate modifiers would tighten the
    /// value per window here, which is why the engines query per window
    /// rather than hoisting the value out of the loop.
    pub fn lookahead_at(&self, contacts: &ContactPlan, window_start: f64) -> f64 {
        debug_assert!(
            window_start.is_finite(),
            "conservative windows start at finite times"
        );
        if !contacts.is_dynamic() {
            return self.min_hop_seconds();
        }
        let bits = self.chunk_bytes_effective() * 8.0;
        let t_intra = bits / self.eff_intra_rate_bps();
        let t_inter = bits / self.eff_inter_rate_bps() / contacts.inter_rate_scale()
            + contacts.inter_extra_latency_s();
        t_intra.min(t_inter)
    }

    /// Seconds to deliver `records` records from `src` to `dst` hop-by-hop
    /// along a grid shortest path (links traversed sequentially, eq. 5).
    pub fn delivery_seconds(
        &self,
        topo: &GridTopology,
        src: SatId,
        dst: SatId,
        records: usize,
    ) -> f64 {
        if src == dst {
            return 0.0;
        }
        let payload = records as f64 * self.record_bytes();
        let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
        payload * 8.0
            * (hops_intra as f64 / self.intra_rate_bps
                + hops_inter as f64 / self.inter_rate_bps)
    }

    /// Plan a broadcast as a **spanning-tree flood** over the collaboration
    /// area: each record crosses each tree edge exactly once (intermediate
    /// satellites relay and keep a copy — they are area members), so the
    /// transferred volume is `records × (|area| − 1) × record_bytes`. This
    /// is how constellation multicast actually works and is the only
    /// accounting consistent with the paper's Table III volumes.
    ///
    /// Returns `(total_bytes, airtime_seconds, arrivals)` where `arrivals`
    /// gives each member's tree depth (records pipeline hop-by-hop: record
    /// `k` reaches depth `h` at `(k + h) · t_bottleneck`).
    pub fn plan_broadcast(
        &self,
        topo: &GridTopology,
        src: SatId,
        area: &[SatId],
        records: usize,
    ) -> BroadcastPlan {
        let t_intra = self.record_bytes() * 8.0 / self.intra_rate_bps;
        let t_inter = self.record_bytes() * 8.0 / self.inter_rate_bps;
        // BFS tree over area members: parent = an area neighbour one grid
        // hop closer to the source (grid Manhattan metric, which is exact
        // for rectangular areas).
        let mut arrivals = Vec::with_capacity(area.len());
        let mut edge_airtime = 0.0;
        let mut bottleneck: f64 = 0.0;
        for &m in area {
            if m == src {
                continue;
            }
            let depth = topo.hops(src, m);
            // edge into `m`: from the neighbour one hop closer; classify by
            // whether the last hop crosses planes. Walk: reduce the larger
            // coordinate difference first; the final hop type depends on
            // which difference remains.
            let (so, ss) = topo.coords(src);
            let (mo, ms) = topo.coords(m);
            let last_hop_inter = if ms != ss { false } else { mo != so };
            let t_edge = if last_hop_inter { t_inter } else { t_intra };
            edge_airtime += t_edge * records as f64;
            bottleneck = bottleneck.max(t_edge);
            arrivals.push((m, depth));
        }
        BroadcastPlan {
            bytes: records as f64
                * self.record_bytes()
                * arrivals.len() as f64,
            airtime_s: edge_airtime,
            bottleneck_s: bottleneck,
            arrivals,
        }
    }

    /// Plan a broadcast over lossy, bandwidth-contended links: the
    /// chunked, loss/corruption/retransmission-aware sibling of
    /// [`Self::plan_broadcast`].
    ///
    /// The entire transfer is resolved *now*, at the collaboration
    /// instant: per-destination ingest-queue contention, every chunk's
    /// loss/corruption fate (pure counter-hash draws keyed by the draw's
    /// identity, not by generator state), bounded retries with
    /// multiplicative backoff, and content-id dedup against the
    /// possession cache. The output is a fixed schedule of chunk
    /// deliveries and retransmission timeouts. Because collaboration
    /// instants resolve in an identical global order in the
    /// single-threaded and sharded engines (the Phase-2 gate ordering),
    /// and nothing here reads other mutable simulation state, the
    /// schedule — and hence the whole run — is engine-independent by
    /// construction.
    ///
    /// Upstream relay hops are folded into each chunk's ready time via
    /// the pipelined bottleneck (the legacy `(k + depth) · bottleneck`
    /// shape, at chunk granularity); loss and contention are modelled on
    /// the last hop into each member, whose ingest link is the resource
    /// concurrent broadcasts fight over.
    ///
    /// Under a dynamic `contacts` plan, the same last hop is additionally
    /// the link that must be *up*: each attempt is deferred to the next
    /// contact window fitting the whole chunk (a `handover`, accumulating
    /// `contact_wait_s`), inter-plane hops pay the plan's slowing-only
    /// rate modifiers, and a chunk no window can ever carry is counted
    /// `stranded` without touching the wire — it cannot schedule a
    /// timeout, because no event time would respect the conservative
    /// lookahead bound. A degenerate plan leaves every computation here
    /// bit-for-bit identical to the plain lossy path.
    pub fn plan_lossy_broadcast(
        &self,
        topo: &GridTopology,
        contacts: &ContactPlan,
        link: &mut LinkState,
        src: SatId,
        area: &[SatId],
        record_ids: &[usize],
        now: f64,
    ) -> LossyPlan {
        self.plan_lossy_broadcast_with_faults(
            topo,
            contacts,
            &crate::network::faults::NodeFaultPlan::none(topo.len()),
            false,
            link,
            src,
            area,
            record_ids,
            now,
        )
    }

    /// [`Self::plan_lossy_broadcast`] under the node-fault model. Three
    /// additional rules, all pure queries of the pre-resolved `faults`
    /// plan (so the schedule stays engine-independent):
    ///
    /// * a chunk whose transmit would start while the **source** is down
    ///   is abandoned without touching the wire (`crash_dropped_chunks`) —
    ///   a dead sender can neither transmit nor detect, so like stranding
    ///   it schedules no event and the lookahead bound holds trivially;
    /// * a chunk arriving while its **destination** is down is a failed
    ///   attempt exactly like a wire loss: the bytes and airtime are paid,
    ///   the sender times out at the arrival instant and retries with
    ///   backoff (the retry may outlive the downtime and succeed);
    /// * under the cold-start storage policy (`wipe_possession`, i.e.
    ///   `scrt_persist = false`) a destination crash **invalidates** the
    ///   possession stamps of chunks delivered before it — the stamp is
    ///   mutated back to "never held" so the next broadcast re-sends them.
    ///   The mutation matters: a query-side exclusion would leave the old
    ///   arrival stamp in place and re-send on every subsequent broadcast
    ///   forever. With `scrt_persist = true` the buffers live in
    ///   non-volatile storage and possession survives reboots untouched.
    ///
    /// With an empty fault plan every added predicate is `false` and the
    /// computation is bit-for-bit the plain lossy path — which is how the
    /// wrapper above keeps the fault-free goldens frozen.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_lossy_broadcast_with_faults(
        &self,
        topo: &GridTopology,
        contacts: &ContactPlan,
        faults: &crate::network::faults::NodeFaultPlan,
        wipe_possession: bool,
        link: &mut LinkState,
        src: SatId,
        area: &[SatId],
        record_ids: &[usize],
        now: f64,
    ) -> LossyPlan {
        let chunk = self.chunk_bytes_effective();
        let chunk_bits = chunk * 8.0;
        let t_intra = chunk_bits / self.eff_intra_rate_bps();
        let t_inter = chunk_bits / self.eff_inter_rate_bps();
        let dynamic = contacts.is_dynamic();
        let total_chunks = self.chunks_per_record();
        let loss = self.cfg.loss_prob;
        let fail_p = loss + (1.0 - loss) * self.cfg.corrupt_prob;
        let LinkState {
            seed,
            next_transfer,
            possession,
            busy_until,
        } = link;
        let transfer = *next_transfer;
        *next_transfer += 1;

        // Member edges + pipelining bottleneck, as in `plan_broadcast`.
        let (so, ss) = topo.coords(src);
        let mut members = Vec::with_capacity(area.len());
        let mut bottleneck: f64 = 0.0;
        for &m in area {
            if m == src {
                continue;
            }
            let depth = topo.hops(src, m);
            let (mo, ms) = topo.coords(m);
            let last_hop_inter = if ms != ss { false } else { mo != so };
            let t_edge = if last_hop_inter { t_inter } else { t_intra };
            // Contact-plan rate modifiers are slowing-only (scale ≤ 1,
            // extra ≥ 0), so the effective edge time only grows — the
            // lookahead bound survives. Degenerate plans leave `t_edge`
            // untouched (same f64, not just same value).
            let t_edge = if dynamic && last_hop_inter {
                t_edge / contacts.inter_rate_scale() + contacts.inter_extra_latency_s()
            } else {
                t_edge
            };
            bottleneck = bottleneck.max(t_edge);
            members.push((m, depth, t_edge, topo.route_parent(src, m)));
        }

        let mut plan = LossyPlan {
            bytes: 0.0,
            airtime_s: 0.0,
            deliveries: Vec::new(),
            timeouts: Vec::new(),
            retransmits: 0,
            dropped_chunks: 0,
            dedup_saved_bytes: 0.0,
            handovers: 0,
            contact_wait_s: 0.0,
            stranded_chunks: 0,
            crash_dropped_chunks: 0,
            quiet_until: now,
        };
        for &(dst, depth, t_edge, parent) in &members {
            let busy = busy_until.entry(dst).or_insert(0.0);
            for (slot, &rid) in record_ids.iter().enumerate() {
                let held = possession
                    .entry((dst, rid))
                    .or_insert_with(|| vec![f64::INFINITY; total_chunks]);
                if held.len() < total_chunks {
                    held.resize(total_chunks, f64::INFINITY);
                }
                for c in 0..total_chunks {
                    let j = slot * total_chunks + c;
                    if wipe_possession
                        && held[c] <= now
                        && faults.crashes_within(dst, held[c], now)
                    {
                        // The destination crashed after this chunk landed
                        // and its storage wipes across reboots: the
                        // possession stamp is stale. Reset it so the
                        // chunk is re-sent below.
                        held[c] = f64::INFINITY;
                    }
                    if held[c] <= now {
                        // Content-id dedup: the destination already holds
                        // this chunk from an earlier broadcast.
                        plan.dedup_saved_bytes += chunk;
                        continue;
                    }
                    // Pipelined availability at the last-hop relay: global
                    // chunk j clears depth-1 upstream hops after
                    // (depth-1+j) bottleneck slots.
                    let mut ready = now + (depth - 1 + j) as f64 * bottleneck;
                    for attempt in 0..=self.cfg.max_retries {
                        let queued = ready.max(*busy);
                        let start = if dynamic {
                            match contacts.next_fit(parent, dst, queued, t_edge) {
                                Some(s) => s,
                                None => {
                                    // No contact window can ever carry this
                                    // chunk: it never touches the wire and
                                    // schedules nothing (a timeout here
                                    // would violate the lookahead bound).
                                    plan.stranded_chunks += 1;
                                    break;
                                }
                            }
                        } else {
                            queued
                        };
                        if faults.is_down(src, start) {
                            // Dead sender: the chunk never touches the
                            // wire and nothing can detect its absence, so
                            // no event is scheduled (see the method docs).
                            plan.crash_dropped_chunks += 1;
                            break;
                        }
                        if start > queued {
                            plan.handovers += 1;
                            plan.contact_wait_s += start - queued;
                        }
                        let arr = start + t_edge;
                        *busy = arr;
                        plan.bytes += chunk;
                        plan.airtime_s += t_edge;
                        plan.quiet_until = plan.quiet_until.max(arr);
                        let u = hash_unit(
                            *seed,
                            transfer,
                            dst as u64,
                            j as u64,
                            attempt as u64,
                        );
                        if u < fail_p || faults.is_down(dst, arr) {
                            let dropped = attempt == self.cfg.max_retries;
                            plan.timeouts.push(ChunkTimeout {
                                time: arr,
                                src,
                                dropped,
                            });
                            if dropped {
                                plan.dropped_chunks += 1;
                            } else {
                                plan.retransmits += 1;
                                ready = arr
                                    + t_edge
                                        * self
                                            .cfg
                                            .retry_backoff
                                            .powi(attempt as i32);
                            }
                        } else {
                            plan.deliveries.push(ChunkDelivery {
                                time: arr,
                                dst,
                                rec_slot: slot,
                                chunk_seq: c,
                                total_chunks,
                            });
                            held[c] = held[c].min(arr);
                            break;
                        }
                    }
                }
            }
        }
        plan
    }

    /// Arrival time offset of the `k`-th record of a streamed broadcast at
    /// `dst` (store-and-forward pipelining): the first record takes the full
    /// path time; each subsequent record lands one bottleneck-hop
    /// transmission later.
    pub fn streamed_arrival_seconds(
        &self,
        topo: &GridTopology,
        src: SatId,
        dst: SatId,
        k: usize,
    ) -> f64 {
        if src == dst {
            return 0.0;
        }
        let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
        let path = self.delivery_seconds(topo, src, dst, 1);
        let per_hop_intra = self.record_bytes() * 8.0 / self.intra_rate_bps;
        let per_hop_inter = self.record_bytes() * 8.0 / self.inter_rate_bps;
        let bottleneck = match (hops_intra > 0, hops_inter > 0) {
            (true, true) => per_hop_intra.max(per_hop_inter),
            (true, false) => per_hop_intra,
            _ => per_hop_inter,
        };
        path + k as f64 * bottleneck
    }

    /// Cost of delivering `records` records from `src` to every *other*
    /// member of `area`, hop-by-hop along grid shortest paths.
    ///
    /// Returns `(total_bytes_transferred, completion_seconds)`:
    /// * bytes count every link crossing (a 2-hop delivery moves the
    ///   payload twice) — this is what Table III accumulates;
    /// * completion time is the slowest receiver's path time, links
    ///   traversed sequentially per eq. (5) (`τ · (D_t + R_t) / r`).
    pub fn broadcast_cost(
        &self,
        topo: &GridTopology,
        src: SatId,
        area: &[SatId],
        records: usize,
    ) -> (f64, f64) {
        let payload = records as f64 * self.record_bytes();
        let mut total_bytes = 0.0;
        let mut worst_seconds: f64 = 0.0;
        for &dst in area {
            if dst == src {
                continue;
            }
            let (hops_intra, hops_inter) = self.split_hops(topo, src, dst);
            let hops = hops_intra + hops_inter;
            total_bytes += payload * hops as f64;
            worst_seconds =
                worst_seconds.max(self.delivery_seconds(topo, src, dst, records));
        }
        (total_bytes, worst_seconds)
    }

    /// Decompose the grid shortest path into intra-/inter-plane hops.
    fn split_hops(&self, topo: &GridTopology, a: SatId, b: SatId) -> (usize, usize) {
        let (ao, as_) = topo.coords(a);
        let (bo, bs) = topo.coords(b);
        (as_.abs_diff(bs), ao.abs_diff(bo))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn model() -> (GridTopology, CommModel) {
        let cfg = SimConfig::paper_default(5);
        (
            GridTopology::new(5),
            CommModel::new(&cfg.network, &cfg.comm),
        )
    }

    #[test]
    fn link_budget_physics_sane() {
        let cfg = SimConfig::paper_default(5);
        let lb = CommModel::link_budget(&cfg.comm, 1.1e6);
        // 26 GHz over 1100 km: FSPL ≈ 182 dB
        let fspl_db = 10.0 * lb.path_loss.log10();
        assert!((180.0..185.0).contains(&fspl_db), "FSPL {fspl_db} dB");
        assert!(lb.snr > 1.0, "link must close: snr {}", lb.snr);
        // rate must be in the tens-to-hundreds of Mbps for a 20 MHz channel
        assert!(
            (2e7..4e8).contains(&lb.rate_bps),
            "rate {} bps",
            lb.rate_bps
        );
    }

    #[test]
    fn shorter_link_is_faster() {
        let cfg = SimConfig::paper_default(5);
        let near = CommModel::link_budget(&cfg.comm, 0.8e6);
        let far = CommModel::link_budget(&cfg.comm, 1.1e6);
        assert!(near.rate_bps > far.rate_bps);
    }

    #[test]
    fn record_bytes_matches_uc_merced_scaling() {
        let (_, m) = model();
        // 12817 MB / 625 ≈ 20.5 MB
        assert!((m.record_bytes() - 20.508e6).abs() < 0.1e6);
    }

    #[test]
    fn broadcast_to_adjacent_one_hop() {
        let (topo, m) = model();
        let src = topo.sat_at(2, 2);
        let dst = topo.sat_at(2, 3);
        let (bytes, secs) = m.broadcast_cost(&topo, src, &[src, dst], 1);
        assert!((bytes - m.record_bytes()).abs() < 1.0);
        assert!(secs > 0.0);
    }

    #[test]
    fn broadcast_bytes_scale_with_hops_and_records() {
        let (topo, m) = model();
        let src = topo.sat_at(0, 0);
        let far = topo.sat_at(2, 2); // 4 hops
        let (b1, _) = m.broadcast_cost(&topo, src, &[src, far], 1);
        assert!((b1 - 4.0 * m.record_bytes()).abs() < 1.0);
        let (b3, _) = m.broadcast_cost(&topo, src, &[src, far], 3);
        assert!((b3 - 3.0 * b1).abs() < 1.0);
    }

    #[test]
    fn broadcast_area_cost_superset_monotone() {
        let (topo, m) = model();
        let src = topo.sat_at(2, 2);
        let small = topo.area(src, 1);
        let large = topo.area(src, 2);
        let (bs, ts) = m.broadcast_cost(&topo, src, &small, 5);
        let (bl, tl) = m.broadcast_cost(&topo, src, &large, 5);
        assert!(bl > bs);
        assert!(tl >= ts);
    }

    #[test]
    fn min_hop_lookahead_bounds_every_broadcast_arrival() {
        let (topo, m) = model();
        let lookahead = m.min_hop_seconds();
        assert!(lookahead.is_finite() && lookahead > 0.0, "{lookahead}");
        // No arrival of any plan may land before `t + lookahead`.
        for src in [topo.sat_at(0, 0), topo.sat_at(2, 2)] {
            for r in [1usize, 2] {
                let area = topo.area(src, r);
                let plan = m.plan_broadcast(&topo, src, &area, 5);
                for &(_, depth) in &plan.arrivals {
                    for k in 0..5 {
                        assert!(
                            plan.arrival_offset(k, depth) >= lookahead,
                            "offset {} < lookahead {lookahead}",
                            plan.arrival_offset(k, depth)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_byte_records_collapse_the_lookahead() {
        let mut cfg = SimConfig::paper_default(5);
        cfg.comm.record_input_bytes = 0.0;
        cfg.comm.record_output_bytes = 0.0;
        let m = CommModel::new(&cfg.network, &cfg.comm);
        assert_eq!(m.min_hop_seconds(), 0.0);
    }

    #[test]
    fn src_not_counted_as_receiver() {
        let (topo, m) = model();
        let src = topo.sat_at(1, 1);
        let (bytes, secs) = m.broadcast_cost(&topo, src, &[src], 7);
        assert_eq!(bytes, 0.0);
        assert_eq!(secs, 0.0);
    }

    /// A 5×5 model with the fault knobs set: ~20.5 MB records in 6 MB
    /// chunks (4 chunks/record).
    fn lossy_model(loss: f64, max_retries: usize) -> (GridTopology, CommModel) {
        let mut cfg = SimConfig::paper_default(5);
        cfg.comm.loss_prob = loss;
        cfg.comm.chunk_bytes = 6e6;
        cfg.comm.max_retries = max_retries;
        (
            GridTopology::new(5),
            CommModel::new(&cfg.network, &cfg.comm),
        )
    }

    #[test]
    fn lossless_chunked_plan_covers_every_chunk() {
        let (topo, m) = lossy_model(0.0, 3);
        let mut link = LinkState::new(42);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let ids = [10usize, 11];
        let plan = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &ids, 5.0);
        let per_rec = m.chunks_per_record();
        let receivers = area.len() - 1;
        assert!(per_rec > 1, "6 MB chunks must split a ~20.5 MB record");
        assert_eq!(plan.deliveries.len(), receivers * ids.len() * per_rec);
        assert!(plan.timeouts.is_empty());
        assert_eq!(plan.retransmits, 0);
        assert_eq!(plan.dropped_chunks, 0);
        assert_eq!(plan.dedup_saved_bytes, 0.0);
        let expect_bytes =
            (receivers * ids.len() * per_rec) as f64 * m.chunk_bytes_effective();
        assert!((plan.bytes - expect_bytes).abs() < 1.0);
        let lookahead = m.min_hop_seconds();
        for d in &plan.deliveries {
            assert!(d.time >= 5.0 + lookahead, "{} too early", d.time);
            assert!(d.time <= plan.quiet_until);
            assert_eq!(d.total_chunks, per_rec);
        }
    }

    #[test]
    fn every_lossy_event_lands_past_the_lookahead() {
        let (topo, m) = lossy_model(0.3, 3);
        let mut link = LinkState::new(7);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(0, 0);
        let area = topo.area(src, 2);
        let now = 123.25;
        let plan =
            m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[0, 1, 2], now);
        assert!(plan.retransmits > 0, "loss 0.3 over this many draws must fail some");
        let lookahead = m.min_hop_seconds();
        for d in &plan.deliveries {
            assert!(d.time >= now + lookahead, "delivery {} < lookahead", d.time);
        }
        for t in &plan.timeouts {
            assert!(t.time >= now + lookahead, "timeout {} < lookahead", t.time);
            assert_eq!(t.src, src);
        }
        assert_eq!(
            plan.timeouts.len() as u64,
            plan.retransmits + plan.dropped_chunks
        );
    }

    #[test]
    fn dedup_skips_chunks_already_held() {
        let (topo, m) = lossy_model(0.0, 3);
        let mut link = LinkState::new(9);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let first = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[3, 4], 0.0);
        assert_eq!(first.dedup_saved_bytes, 0.0);

        // In-flight chunks don't dedup: a second overlapping broadcast at
        // the same instant re-sends record 3 in full (possession records
        // *scheduled arrivals*, none of which have happened yet).
        let mut inflight = link.clone();
        let mid = m.plan_lossy_broadcast(&topo, &cp, &mut inflight, src, &area, &[3], 0.0);
        assert_eq!(mid.dedup_saved_bytes, 0.0);
        assert!(!mid.deliveries.is_empty());

        // After the first transfer settles, records 3 and 4 are held
        // everywhere: a broadcast of {3, 4, 5} moves only record 5.
        let later = first.quiet_until + 1.0;
        let second =
            m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[3, 4, 5], later);
        let per_rec = m.chunks_per_record();
        let receivers = area.len() - 1;
        assert_eq!(second.deliveries.len(), receivers * per_rec);
        assert!(second.deliveries.iter().all(|d| d.rec_slot == 2));
        let saved = (receivers * 2 * per_rec) as f64 * m.chunk_bytes_effective();
        assert!((second.dedup_saved_bytes - saved).abs() < 1.0);
        for &mbr in &area {
            if mbr == src {
                continue;
            }
            assert!(link.holds(mbr, 3, 0, later));
        }
    }

    #[test]
    fn resume_resends_only_the_dropped_chunks() {
        // First pass over heavily lossy links with no retries drops chunks
        // mid-record; the next broadcast of the same record over clean
        // links (same shared LinkState) resumes, re-sending exactly the
        // missing chunks while the delivered prefix dedups away.
        let mut cfg = SimConfig::paper_default(5);
        cfg.comm.loss_prob = 0.6;
        cfg.comm.chunk_bytes = 6e6;
        cfg.comm.max_retries = 0;
        let lossy = CommModel::new(&cfg.network, &cfg.comm);
        cfg.comm.loss_prob = 0.0;
        let clean = CommModel::new(&cfg.network, &cfg.comm);
        let topo = GridTopology::new(5);
        let mut link = LinkState::new(1);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let first = lossy.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[8], 0.0);
        assert!(first.dropped_chunks > 0, "loss 0.6 with no retries must drop");
        assert_eq!(first.retransmits, 0);
        assert!(first.timeouts.iter().all(|t| t.dropped));
        let per_rec = lossy.chunks_per_record();
        let receivers = area.len() - 1;
        let delivered = first.deliveries.len();
        assert!(delivered < receivers * per_rec);

        let later = first.quiet_until + 1.0;
        let second =
            clean.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[8], later);
        assert_eq!(second.deliveries.len(), receivers * per_rec - delivered);
        let saved = delivered as f64 * clean.chunk_bytes_effective();
        assert!((second.dedup_saved_bytes - saved).abs() < 1.0);
        assert!(second.timeouts.is_empty());
        for &mbr in &area {
            if mbr == src {
                continue;
            }
            for c in 0..per_rec {
                assert!(link.holds(mbr, 8, c, second.quiet_until));
            }
        }
    }

    #[test]
    fn retries_exhaustion_splits_retransmits_from_drops() {
        let (topo, m) = lossy_model(0.95, 2);
        let mut link = LinkState::new(3);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(1, 1);
        let area = topo.area(src, 1);
        let plan = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[0], 0.0);
        assert!(plan.retransmits > 0);
        assert!(plan.dropped_chunks > 0, "0.95³ per-chunk drop odds must hit");
        assert_eq!(
            plan.timeouts.len() as u64,
            plan.retransmits + plan.dropped_chunks
        );
        assert_eq!(
            plan.timeouts.iter().filter(|t| t.dropped).count() as u64,
            plan.dropped_chunks
        );
    }

    #[test]
    fn ingest_contention_serializes_per_destination_arrivals() {
        let (topo, m) = lossy_model(0.0, 3);
        let mut link = LinkState::new(5);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(1, 1);
        let area = topo.area(src, 1);
        let plan1 = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[0], 0.0);
        // Distinct record at the same instant: the per-destination ingest
        // FIFO queues the whole second transfer behind the first instead
        // of overlapping them.
        let plan2 = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[1], 0.0);
        let mut last: HashMap<SatId, f64> = HashMap::new();
        for d in plan1.deliveries.iter().chain(&plan2.deliveries) {
            let prev = last.insert(d.dst, d.time);
            if let Some(prev) = prev {
                assert!(d.time > prev, "arrivals at {} overlap: {} then {}", d.dst, prev, d.time);
            }
        }
        assert!(plan2.quiet_until > plan1.quiet_until);
    }

    fn walker_topology(duty: f64, period: f64) -> crate::config::TopologyConfig {
        crate::config::TopologyConfig {
            mode: crate::config::TopologyMode::Walker,
            duty,
            period_s: period,
            ..crate::config::TopologyConfig::default()
        }
    }

    #[test]
    fn degenerate_walker_plan_reproduces_the_static_schedule() {
        // Walker mode with full duty and no modifiers must leave every
        // f64 of the plan untouched — this is the bit-identity the
        // static-golden reproduction rests on.
        let (topo, m) = lossy_model(0.3, 3);
        let always = ContactPlan::always_on(5);
        let walker = ContactPlan::new(5, &walker_topology(1.0, 600.0));
        assert!(!walker.is_dynamic());
        let mut la = LinkState::new(17);
        let mut lb = la.clone();
        let src = topo.sat_at(1, 2);
        let area = topo.area(src, 2);
        let a = m.plan_lossy_broadcast(&topo, &always, &mut la, src, &area, &[0, 1], 2.5);
        let b = m.plan_lossy_broadcast(&topo, &walker, &mut lb, src, &area, &[0, 1], 2.5);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.handovers, 0);
        assert_eq!(a.stranded_chunks, 0);
        assert_eq!(a.contact_wait_s, 0.0);
    }

    #[test]
    fn dynamic_plan_events_respect_the_per_window_lookahead() {
        // The lookahead-soundness contract under a time-varying plan:
        // every scheduled event of a broadcast resolved at `now` lands at
        // least `lookahead_at(plan, now)` later, even with duty cycling,
        // rate scaling, extra latency and retransmissions all active.
        let mut cfg = SimConfig::paper_default(5);
        cfg.comm.loss_prob = 0.3;
        cfg.comm.chunk_bytes = 6e6;
        cfg.topology = walker_topology(0.5, 100.0);
        cfg.topology.inter_rate_scale = 0.8;
        cfg.topology.inter_extra_latency_s = 0.002;
        let m = CommModel::new(&cfg.network, &cfg.comm);
        let topo = GridTopology::new(5);
        let cp = ContactPlan::new(5, &cfg.topology);
        assert!(cp.is_dynamic());
        let lookahead = m.lookahead_at(&cp, 0.0);
        assert!(lookahead >= m.min_hop_seconds());
        let mut link = LinkState::new(23);
        for (i, now) in [0.0, 31.25, 77.5].into_iter().enumerate() {
            let src = topo.sat_at(i, i);
            let area = topo.area(src, 2);
            let plan =
                m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[i], now);
            let bound = now + m.lookahead_at(&cp, now);
            for d in &plan.deliveries {
                assert!(d.time >= bound, "delivery {} < bound {bound}", d.time);
            }
            for t in &plan.timeouts {
                assert!(t.time >= bound, "timeout {} < bound {bound}", t.time);
            }
        }
    }

    #[test]
    fn outage_defers_chunks_to_the_window_end_and_counts_the_handover() {
        let (topo, m) = lossy_model(0.0, 3);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let t_intra = m.hop_seconds(m.chunk_bytes_effective());
        let blocked = topo.sat_at(2, 3); // last hop src -> (2,3) is intra
        let outage_end = 10.0 * t_intra;
        let cfg = crate::config::TopologyConfig {
            outages: vec![crate::config::OutageSpec {
                a: src,
                b: blocked,
                start: 0.0,
                end: outage_end,
            }],
            ..crate::config::TopologyConfig::default()
        };
        let cp = ContactPlan::new(5, &cfg);
        let mut link = LinkState::new(11);
        let plan = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[0], 0.0);
        // Only the first chunk into the blocked member waits (the ingest
        // FIFO carries the later ones past the outage on its own).
        assert_eq!(plan.handovers, 1);
        assert!(plan.contact_wait_s > 0.0);
        assert_eq!(plan.stranded_chunks, 0);
        for d in &plan.deliveries {
            if d.dst == blocked {
                assert!(
                    d.time >= outage_end + t_intra,
                    "chunk into the outage window: {}",
                    d.time
                );
            }
        }
        // Members on other links are not disturbed: bit-identical to the
        // always-on schedule.
        let mut clean_link = LinkState::new(11);
        let clean = m.plan_lossy_broadcast(
            &topo,
            &ContactPlan::always_on(5),
            &mut clean_link,
            src,
            &area,
            &[0],
            0.0,
        );
        for (d, c) in plan
            .deliveries
            .iter()
            .filter(|d| d.dst != blocked)
            .zip(clean.deliveries.iter().filter(|d| d.dst != blocked))
        {
            assert_eq!(d.time, c.time);
            assert_eq!(d.dst, c.dst);
        }
    }

    /// A scripted-only fault plan (mtbf off) over a 5×5 grid.
    fn fault_plan(outages: &[(usize, f64, f64)]) -> crate::network::NodeFaultPlan {
        let fc = crate::config::FaultConfig {
            node_outages: outages
                .iter()
                .map(|&(sat, start, end)| crate::config::NodeOutageSpec {
                    sat,
                    start,
                    end,
                })
                .collect(),
            ..crate::config::FaultConfig::default()
        };
        crate::network::NodeFaultPlan::new(&fc, 0, 25, f64::INFINITY)
    }

    #[test]
    fn empty_fault_plan_reproduces_the_plain_lossy_schedule() {
        // The wrapper's bit-identity claim: with no faults every added
        // predicate is false, even under the wipe policy.
        let (topo, m) = lossy_model(0.3, 3);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(1, 2);
        let area = topo.area(src, 2);
        let mut a = LinkState::new(77);
        let mut b = a.clone();
        let pa = m.plan_lossy_broadcast(&topo, &cp, &mut a, src, &area, &[0, 1], 1.5);
        let pb = m.plan_lossy_broadcast_with_faults(
            &topo,
            &cp,
            &fault_plan(&[]),
            true,
            &mut b,
            src,
            &area,
            &[0, 1],
            1.5,
        );
        assert_eq!(format!("{pa:?}"), format!("{pb:?}"));
        assert_eq!(pb.crash_dropped_chunks, 0);
    }

    #[test]
    fn dead_source_abandons_untransmitted_chunks() {
        let (topo, m) = lossy_model(0.0, 3);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let per_rec = m.chunks_per_record();
        let receivers = area.len() - 1;
        let total = (receivers * 2 * per_rec) as u64;

        // Source down for the whole transfer: nothing touches the wire.
        let faults = fault_plan(&[(src, 0.0, 1e9)]);
        let mut link = LinkState::new(21);
        let plan = m.plan_lossy_broadcast_with_faults(
            &topo, &cp, &faults, false, &mut link, src, &area, &[0, 1], 0.0,
        );
        assert_eq!(plan.crash_dropped_chunks, total);
        assert!(plan.deliveries.is_empty() && plan.timeouts.is_empty());
        assert_eq!(plan.bytes, 0.0);
        assert_eq!(plan.quiet_until, 0.0, "a silent transfer leaves no quiet period");

        // Crash mid-transfer: the early chunks go out, the tail is
        // abandoned, and (loss 0, fresh link) every chunk is exactly one
        // of delivered / crash-dropped.
        let t_intra = m.hop_seconds(m.chunk_bytes_effective());
        let faults = fault_plan(&[(src, 5.0 * t_intra, 1e9)]);
        let mut link = LinkState::new(21);
        let plan = m.plan_lossy_broadcast_with_faults(
            &topo, &cp, &faults, false, &mut link, src, &area, &[0, 1], 0.0,
        );
        assert!(plan.crash_dropped_chunks > 0, "the tail must be abandoned");
        assert!(!plan.deliveries.is_empty(), "the head must have been sent");
        assert_eq!(plan.deliveries.len() as u64 + plan.crash_dropped_chunks, total);
    }

    #[test]
    fn dead_destination_arrivals_time_out_and_retry_past_the_reboot() {
        let (topo, m) = lossy_model(0.0, 3);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let dead = topo.sat_at(2, 3); // intra-plane last hop
        let t_intra = m.hop_seconds(m.chunk_bytes_effective());
        let reboot = 3.0 * t_intra;
        let faults = fault_plan(&[(dead, 0.0, reboot)]);
        let mut link = LinkState::new(33);
        let area = topo.area(src, 1);
        let plan = m.plan_lossy_broadcast_with_faults(
            &topo, &cp, &faults, false, &mut link, src, &area, &[0], 0.0,
        );
        assert!(plan.retransmits > 0, "arrivals during the downtime must fail");
        assert_eq!(plan.dropped_chunks, 0, "retries outlive a 3-slot downtime");
        let per_rec = m.chunks_per_record();
        assert_eq!(plan.deliveries.len(), (area.len() - 1) * per_rec);
        for d in plan.deliveries.iter().filter(|d| d.dst == dead) {
            assert!(d.time >= reboot, "delivered into the downtime: {}", d.time);
        }
        assert_eq!(
            plan.timeouts.len() as u64,
            plan.retransmits + plan.dropped_chunks
        );
    }

    #[test]
    fn wipe_policy_invalidates_possession_across_a_destination_crash() {
        let (topo, m) = lossy_model(0.0, 3);
        let cp = ContactPlan::always_on(5);
        let src = topo.sat_at(2, 2);
        let victim = topo.sat_at(2, 3);
        let area = topo.area(src, 1);
        let per_rec = m.chunks_per_record();
        let mut link = LinkState::new(55);
        let first = m.plan_lossy_broadcast_with_faults(
            &topo,
            &cp,
            &fault_plan(&[]),
            true,
            &mut link,
            src,
            &area,
            &[0],
            0.0,
        );
        assert_eq!(first.deliveries.len(), (area.len() - 1) * per_rec);
        let crash = first.quiet_until + 1.0;
        let faults = fault_plan(&[(victim, crash, crash + 5.0)]);
        let later = crash + 10.0;

        // persist policy: possession lives in non-volatile storage — the
        // whole re-broadcast dedups away, crash or no crash.
        let mut persist = link.clone();
        let p = m.plan_lossy_broadcast_with_faults(
            &topo, &cp, &faults, false, &mut persist, src, &area, &[0], later,
        );
        assert!(p.deliveries.is_empty());

        // wipe policy: exactly the victim's chunks are re-sent...
        let mut wipe = link.clone();
        let w = m.plan_lossy_broadcast_with_faults(
            &topo, &cp, &faults, true, &mut wipe, src, &area, &[0], later,
        );
        assert_eq!(w.deliveries.len(), per_rec);
        assert!(w.deliveries.iter().all(|d| d.dst == victim));
        // ...and the stamp was genuinely reset (not excluded per query): a
        // third broadcast after the re-delivery dedups everything again.
        let third = m.plan_lossy_broadcast_with_faults(
            &topo,
            &cp,
            &faults,
            true,
            &mut wipe,
            src,
            &area,
            &[0],
            w.quiet_until + 1.0,
        );
        assert!(third.deliveries.is_empty());
        assert!(third.dedup_saved_bytes > 0.0);
    }

    #[test]
    fn too_short_duty_windows_strand_inter_plane_chunks() {
        // Duty windows of 1 ms can never carry a multi-second chunk: the
        // inter-plane members' chunks are stranded (never sent, never
        // timed out), while intra-plane members are served normally.
        let (topo, m) = lossy_model(0.0, 3);
        let cp = ContactPlan::new(5, &walker_topology(0.001, 1.0));
        let mut link = LinkState::new(13);
        let src = topo.sat_at(2, 2);
        let area = topo.area(src, 1);
        let plan = m.plan_lossy_broadcast(&topo, &cp, &mut link, src, &area, &[0], 0.0);
        let per_rec = m.chunks_per_record();
        // Radius-1 area: two inter-plane last hops ((1,2) and (3,2)).
        assert_eq!(plan.stranded_chunks, 2 * per_rec as u64);
        assert_eq!(plan.dropped_chunks, 0);
        assert!(plan.timeouts.is_empty());
        let inter_members = [topo.sat_at(1, 2), topo.sat_at(3, 2)];
        for d in &plan.deliveries {
            assert!(
                !inter_members.contains(&d.dst),
                "stranded member {} must receive nothing",
                d.dst
            );
        }
        // Six intra-last-hop members still get every chunk.
        assert_eq!(plan.deliveries.len(), 6 * per_rec);
        // Stranded chunks never touch the wire.
        let sent = plan.deliveries.len() as f64 * m.chunk_bytes_effective();
        assert!((plan.bytes - sent).abs() < 1.0);
    }
}
