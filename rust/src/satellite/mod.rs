//! Per-satellite server state: an M/M/1-style FIFO server on the virtual
//! clock, plus the counters the SRS metric (eq. 11) reads.
//!
//! Each satellite in the simulated constellation is a single-server FIFO
//! queue: tasks arrive (Poisson per satellite), wait for the on-board CPU,
//! are served for either the full-compute or the reuse-lookup cost, and
//! complete. [`SatelliteState`] tracks the server clock (`next_free`), the
//! accumulated busy time, and the reuse counters from which
//! [`SatelliteState::reuse_rate`] and [`SatelliteState::cpu_occupancy`]
//! derive — the two inputs of the SRS metric ([`crate::coordinator::srs`]).
//! The collaboration bookkeeping (`last_collab_request`,
//! `collab_requests`, `times_source`) feeds Alg. 2's trigger and the
//! per-satellite diagnostics in [`crate::metrics::SatSummary`].
//!
//! [`SatNode`] is the full per-satellite aggregate the simulator engine
//! owns: the server state above plus the satellite's SCRT, its FIFO task
//! queue, the task currently in flight and the Alg. 2 hysteresis flag —
//! previously five parallel per-satellite `Vec`s inside the simulator's
//! event loop.

use std::collections::{HashMap, VecDeque};

use crate::coordinator::scrt::Scrt;
use crate::workload::SatId;

/// Reassembly progress of one chunked record transfer.
///
/// Entries persist for the rest of the run once created: a completed
/// assembly keeps absorbing late in-flight duplicates of its chunks
/// (returning `false`, so the record is merged exactly once), and a
/// partially received assembly keeps its delivered prefix so a later
/// re-broadcast only has to supply the missing chunks.
#[derive(Clone, Debug)]
pub struct ChunkAssembly {
    received: Vec<bool>,
    complete: bool,
}

impl ChunkAssembly {
    /// Chunks received so far.
    pub fn received_count(&self) -> usize {
        self.received.iter().filter(|&&r| r).count()
    }

    pub fn is_complete(&self) -> bool {
        self.complete
    }
}

/// What one satellite is currently executing.
#[derive(Clone, Debug)]
pub struct InFlight {
    /// Index of the task in the workload's task vec.
    pub task_idx: usize,
    /// Virtual time service started.
    pub start: f64,
    /// Was the task served via computation reuse?
    pub reused: bool,
    /// Did the (reused or computed) result match the oracle label?
    pub correct: bool,
    /// SSIM against the serving candidate, when one was gated.
    pub ssim: Option<f32>,
    /// Scene of the serving record (provenance diagnostics).
    pub reused_from_scene: Option<u32>,
    /// Satellite that originally computed the serving record.
    pub reused_from_sat: Option<usize>,
}

/// One satellite of the constellation, as the engine sees it: server
/// state, reuse cache, FIFO queue, in-flight task, hysteresis flag.
#[derive(Clone, Debug)]
pub struct SatNode {
    /// FIFO server clock + SRS counters.
    pub state: SatelliteState,
    /// The satellite's reuse table.
    pub scrt: Scrt,
    /// Queued task indices, FIFO (indices into the workload task vec).
    pub queue: VecDeque<usize>,
    /// The task currently being served, if any.
    pub in_flight: Option<InFlight>,
    /// Hysteresis: once this satellite's request triggered a broadcast, it
    /// may not request again until its SRS has recovered above th_co — a
    /// satellite that keeps benefiting never re-requests, and one that did
    /// not benefit waits for the situation to change.
    pub collab_armed: bool,
    /// Partial-record reassembly state of chunked lossy transfers, keyed
    /// by record id. Only ever indexed by key (never iterated), so the
    /// map's internal order cannot leak into results.
    pub reassembly: HashMap<usize, ChunkAssembly>,
    /// Crashed and not yet rebooted: arrivals are lost, service and
    /// collaboration are suspended. Driven by the pre-resolved
    /// [`crate::network::NodeFaultPlan`]; always `false` on the
    /// fault-free path.
    pub down: bool,
}

impl SatNode {
    /// A fresh, idle satellite with an empty SCRT.
    pub fn new(id: SatId, num_buckets: usize, cache_capacity: usize) -> Self {
        SatNode {
            state: SatelliteState::new(id),
            scrt: Scrt::new(num_buckets, cache_capacity),
            queue: VecDeque::new(),
            in_flight: None,
            collab_armed: true,
            reassembly: HashMap::new(),
            down: false,
        }
    }

    /// Crash at virtual time `now`: the in-flight task and every queued
    /// task are lost (returns how many), and under the cold-start policy
    /// (`wipe_scrt`) the SCRT and partial-transfer reassembly buffers are
    /// cleared — the persist policy models non-volatile storage holding
    /// both. The server clock (`next_free`) and accumulated `busy_time`
    /// are deliberately *not* rewound: the dropped task's service was
    /// already accounted when it started, and both engines share this
    /// choice through the common `SatelliteState` (see
    /// `docs/ARCHITECTURE.md`, "Node faults & recovery").
    pub fn crash(&mut self, now: f64, wipe_scrt: bool) -> u64 {
        let mut lost = self.queue.len() as u64;
        self.queue.clear();
        if self.in_flight.take().is_some() {
            lost += 1;
        }
        if wipe_scrt {
            self.scrt.wipe(now);
            self.reassembly.clear();
        }
        self.down = true;
        lost
    }

    /// Reboot: resume accepting tasks. The Alg. 2 hysteresis re-arms so a
    /// (possibly cold) satellite may request collaboration again.
    pub fn reboot(&mut self) {
        self.down = false;
        self.collab_armed = true;
    }

    /// Register one delivered chunk of `record_id`. Returns `true` exactly
    /// once: on the delivery that completes the record, which is when the
    /// engine merges it into the SCRT. Out-of-order arrivals, duplicates,
    /// and late chunks of an already-completed assembly all return `false`.
    pub fn accept_chunk(
        &mut self,
        record_id: usize,
        chunk_seq: usize,
        total_chunks: usize,
    ) -> bool {
        let asm = self
            .reassembly
            .entry(record_id)
            .or_insert_with(|| ChunkAssembly {
                received: vec![false; total_chunks],
                complete: false,
            });
        if asm.complete {
            return false;
        }
        if asm.received.len() < total_chunks {
            asm.received.resize(total_chunks, false);
        }
        if chunk_seq < asm.received.len() {
            asm.received[chunk_seq] = true;
        }
        if asm.received.iter().all(|&r| r) {
            asm.complete = true;
            true
        } else {
            false
        }
    }
}

/// Mutable state of one satellite during a simulation run.
#[derive(Clone, Debug)]
pub struct SatelliteState {
    pub id: SatId,
    /// Virtual time at which the on-board server frees up.
    next_free: f64,
    /// Accumulated service (busy) time.
    busy_time: f64,
    /// Completed tasks.
    pub tasks_processed: usize,
    /// Tasks served via computation reuse (local or collaborative).
    pub tasks_reused: usize,
    /// Of the reused tasks, how many matched the oracle label.
    pub reused_correct: usize,
    /// Completion time of the most recent task.
    pub last_completion: f64,
    /// Virtual time of the last collaboration request this satellite made.
    pub last_collab_request: f64,
    /// Collaboration requests issued.
    pub collab_requests: usize,
    /// Broadcasts served as the data-source satellite.
    pub times_source: usize,
}

impl SatelliteState {
    pub fn new(id: SatId) -> Self {
        SatelliteState {
            id,
            next_free: 0.0,
            busy_time: 0.0,
            tasks_processed: 0,
            tasks_reused: 0,
            reused_correct: 0,
            last_completion: 0.0,
            last_collab_request: f64::NEG_INFINITY,
            collab_requests: 0,
            times_source: 0,
        }
    }

    /// Serve a task arriving at `arrival` needing `service_s` seconds of
    /// on-board compute. FIFO, single server. Returns `(start, completion)`.
    pub fn serve(&mut self, arrival: f64, service_s: f64) -> (f64, f64) {
        debug_assert!(service_s >= 0.0, "negative service time");
        let start = arrival.max(self.next_free);
        let completion = start + service_s;
        self.next_free = completion;
        self.busy_time += service_s;
        self.tasks_processed += 1;
        self.last_completion = completion;
        (start, completion)
    }

    /// Delay the server (e.g. the satellite spends time relaying/receiving a
    /// broadcast payload; counted as busy for occupancy purposes).
    pub fn occupy_until(&mut self, until: f64) {
        if until > self.next_free {
            self.busy_time += until - self.next_free;
            self.next_free = until;
        }
    }

    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// `rr_S` as a pure function of the raw counters (0 before the first
    /// task). The canonical formula behind [`SatelliteState::reuse_rate`]
    /// — the sharded engine's checkpoint reconstruction calls this with
    /// journaled counters, so the two paths cannot drift.
    pub fn reuse_rate_of(tasks_reused: usize, tasks_processed: usize) -> f64 {
        if tasks_processed == 0 {
            0.0
        } else {
            tasks_reused as f64 / tasks_processed as f64
        }
    }

    /// `C_S` as a pure function of accumulated busy seconds and the
    /// clock, clamped to [0, 1]. The canonical formula behind
    /// [`SatelliteState::cpu_occupancy`] — shared with the sharded
    /// engine's checkpoint reconstruction.
    pub fn occupancy_of(busy_s: f64, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (busy_s / now).clamp(0.0, 1.0)
        }
    }

    /// Reuse rate `rr_S`: reused / processed (0 before the first task).
    pub fn reuse_rate(&self) -> f64 {
        Self::reuse_rate_of(self.tasks_reused, self.tasks_processed)
    }

    /// CPU occupancy `C_S`: busy time over elapsed time (task receipt to
    /// now), clamped to [0, 1].
    pub fn cpu_occupancy(&self, now: f64) -> f64 {
        Self::occupancy_of(self.busy_time, now)
    }

    /// Accuracy over the reused tasks (1.0 when nothing was reused — the
    /// paper reports `w/o CR` and `SLCR-never-matched` as accuracy 1).
    pub fn reuse_accuracy(&self) -> f64 {
        if self.tasks_reused == 0 {
            1.0
        } else {
            self.reused_correct as f64 / self.tasks_reused as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_queueing() {
        let mut s = SatelliteState::new(0);
        let (st1, c1) = s.serve(0.0, 2.0);
        assert_eq!((st1, c1), (0.0, 2.0));
        // arrives while busy -> queues
        let (st2, c2) = s.serve(1.0, 1.0);
        assert_eq!((st2, c2), (2.0, 3.0));
        // arrives after idle gap -> starts at arrival
        let (st3, c3) = s.serve(10.0, 0.5);
        assert_eq!((st3, c3), (10.0, 10.5));
        assert_eq!(s.busy_time(), 3.5);
        assert_eq!(s.tasks_processed, 3);
    }

    #[test]
    fn occupancy_reflects_idle_time() {
        let mut s = SatelliteState::new(0);
        s.serve(0.0, 2.0);
        assert!((s.cpu_occupancy(4.0) - 0.5).abs() < 1e-12);
        assert_eq!(s.cpu_occupancy(0.0), 0.0);
    }

    #[test]
    fn reuse_rate_and_accuracy() {
        let mut s = SatelliteState::new(0);
        assert_eq!(s.reuse_rate(), 0.0);
        assert_eq!(s.reuse_accuracy(), 1.0);
        s.serve(0.0, 1.0);
        s.serve(0.0, 0.1);
        s.tasks_reused = 1;
        s.reused_correct = 1;
        assert_eq!(s.reuse_rate(), 0.5);
        assert_eq!(s.reuse_accuracy(), 1.0);
        s.tasks_reused = 2;
        assert_eq!(s.reuse_accuracy(), 0.5);
    }

    #[test]
    fn sat_node_starts_idle_and_armed() {
        let n = SatNode::new(3, 4, 8);
        assert_eq!(n.state.id, 3);
        assert!(n.queue.is_empty());
        assert!(n.in_flight.is_none());
        assert!(n.collab_armed, "hysteresis starts armed");
        assert!(n.scrt.is_empty());
        assert_eq!(n.scrt.capacity(), 8);
    }

    #[test]
    fn accept_chunk_completes_exactly_once() {
        let mut n = SatNode::new(0, 4, 8);
        assert!(!n.accept_chunk(7, 0, 3));
        assert!(!n.accept_chunk(7, 1, 3));
        assert!(n.accept_chunk(7, 2, 3), "last chunk completes");
        // Late duplicates of a completed assembly are absorbed silently.
        assert!(!n.accept_chunk(7, 0, 3));
        assert!(!n.accept_chunk(7, 2, 3));
        assert!(n.reassembly[&7].is_complete());
    }

    #[test]
    fn accept_chunk_single_chunk_record() {
        let mut n = SatNode::new(0, 4, 8);
        assert!(n.accept_chunk(1, 0, 1));
        assert!(!n.accept_chunk(1, 0, 1));
    }

    #[test]
    fn accept_chunk_keeps_partial_progress() {
        // A mid-transfer drop leaves the delivered prefix behind; a later
        // transfer only needs to supply the missing chunks.
        let mut n = SatNode::new(0, 4, 8);
        assert!(!n.accept_chunk(9, 0, 4));
        assert!(!n.accept_chunk(9, 2, 4));
        assert_eq!(n.reassembly[&9].received_count(), 2);
        assert!(!n.reassembly[&9].is_complete());
        assert!(!n.accept_chunk(9, 1, 4));
        assert!(n.accept_chunk(9, 3, 4));
    }

    #[test]
    fn occupy_until_extends_busy() {
        let mut s = SatelliteState::new(0);
        s.serve(0.0, 1.0);
        s.occupy_until(3.0);
        assert_eq!(s.next_free(), 3.0);
        assert_eq!(s.busy_time(), 3.0);
        // no-op when already past
        s.occupy_until(2.0);
        assert_eq!(s.next_free(), 3.0);
    }
}
