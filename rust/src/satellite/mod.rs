//! Per-satellite server state: an M/M/1-style FIFO server on the virtual
//! clock, plus the counters the SRS metric (eq. 11) reads.
//!
//! Each satellite in the simulated constellation is a single-server FIFO
//! queue: tasks arrive (Poisson per satellite), wait for the on-board CPU,
//! are served for either the full-compute or the reuse-lookup cost, and
//! complete. [`SatelliteState`] tracks the server clock (`next_free`), the
//! accumulated busy time, and the reuse counters from which
//! [`SatelliteState::reuse_rate`] and [`SatelliteState::cpu_occupancy`]
//! derive — the two inputs of the SRS metric ([`crate::coordinator::srs`]).
//! The collaboration bookkeeping (`last_collab_request`,
//! `collab_requests`, `times_source`) feeds Alg. 2's trigger and the
//! per-satellite diagnostics in [`crate::metrics::SatSummary`].

use crate::workload::SatId;

/// Mutable state of one satellite during a simulation run.
#[derive(Clone, Debug)]
pub struct SatelliteState {
    pub id: SatId,
    /// Virtual time at which the on-board server frees up.
    next_free: f64,
    /// Accumulated service (busy) time.
    busy_time: f64,
    /// Completed tasks.
    pub tasks_processed: usize,
    /// Tasks served via computation reuse (local or collaborative).
    pub tasks_reused: usize,
    /// Of the reused tasks, how many matched the oracle label.
    pub reused_correct: usize,
    /// Completion time of the most recent task.
    pub last_completion: f64,
    /// Virtual time of the last collaboration request this satellite made.
    pub last_collab_request: f64,
    /// Collaboration requests issued.
    pub collab_requests: usize,
    /// Broadcasts served as the data-source satellite.
    pub times_source: usize,
}

impl SatelliteState {
    pub fn new(id: SatId) -> Self {
        SatelliteState {
            id,
            next_free: 0.0,
            busy_time: 0.0,
            tasks_processed: 0,
            tasks_reused: 0,
            reused_correct: 0,
            last_completion: 0.0,
            last_collab_request: f64::NEG_INFINITY,
            collab_requests: 0,
            times_source: 0,
        }
    }

    /// Serve a task arriving at `arrival` needing `service_s` seconds of
    /// on-board compute. FIFO, single server. Returns `(start, completion)`.
    pub fn serve(&mut self, arrival: f64, service_s: f64) -> (f64, f64) {
        debug_assert!(service_s >= 0.0, "negative service time");
        let start = arrival.max(self.next_free);
        let completion = start + service_s;
        self.next_free = completion;
        self.busy_time += service_s;
        self.tasks_processed += 1;
        self.last_completion = completion;
        (start, completion)
    }

    /// Delay the server (e.g. the satellite spends time relaying/receiving a
    /// broadcast payload; counted as busy for occupancy purposes).
    pub fn occupy_until(&mut self, until: f64) {
        if until > self.next_free {
            self.busy_time += until - self.next_free;
            self.next_free = until;
        }
    }

    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// Reuse rate `rr_S`: reused / processed (0 before the first task).
    pub fn reuse_rate(&self) -> f64 {
        if self.tasks_processed == 0 {
            0.0
        } else {
            self.tasks_reused as f64 / self.tasks_processed as f64
        }
    }

    /// CPU occupancy `C_S`: busy time over elapsed time (task receipt to
    /// now), clamped to [0, 1].
    pub fn cpu_occupancy(&self, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (self.busy_time / now).clamp(0.0, 1.0)
        }
    }

    /// Accuracy over the reused tasks (1.0 when nothing was reused — the
    /// paper reports `w/o CR` and `SLCR-never-matched` as accuracy 1).
    pub fn reuse_accuracy(&self) -> f64 {
        if self.tasks_reused == 0 {
            1.0
        } else {
            self.reused_correct as f64 / self.tasks_reused as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_queueing() {
        let mut s = SatelliteState::new(0);
        let (st1, c1) = s.serve(0.0, 2.0);
        assert_eq!((st1, c1), (0.0, 2.0));
        // arrives while busy -> queues
        let (st2, c2) = s.serve(1.0, 1.0);
        assert_eq!((st2, c2), (2.0, 3.0));
        // arrives after idle gap -> starts at arrival
        let (st3, c3) = s.serve(10.0, 0.5);
        assert_eq!((st3, c3), (10.0, 10.5));
        assert_eq!(s.busy_time(), 3.5);
        assert_eq!(s.tasks_processed, 3);
    }

    #[test]
    fn occupancy_reflects_idle_time() {
        let mut s = SatelliteState::new(0);
        s.serve(0.0, 2.0);
        assert!((s.cpu_occupancy(4.0) - 0.5).abs() < 1e-12);
        assert_eq!(s.cpu_occupancy(0.0), 0.0);
    }

    #[test]
    fn reuse_rate_and_accuracy() {
        let mut s = SatelliteState::new(0);
        assert_eq!(s.reuse_rate(), 0.0);
        assert_eq!(s.reuse_accuracy(), 1.0);
        s.serve(0.0, 1.0);
        s.serve(0.0, 0.1);
        s.tasks_reused = 1;
        s.reused_correct = 1;
        assert_eq!(s.reuse_rate(), 0.5);
        assert_eq!(s.reuse_accuracy(), 1.0);
        s.tasks_reused = 2;
        assert_eq!(s.reuse_accuracy(), 0.5);
    }

    #[test]
    fn occupy_until_extends_busy() {
        let mut s = SatelliteState::new(0);
        s.serve(0.0, 1.0);
        s.occupy_until(3.0);
        assert_eq!(s.next_free(), 3.0);
        assert_eq!(s.busy_time(), 3.0);
        // no-op when already past
        s.occupy_until(2.0);
        assert_eq!(s.next_free(), 3.0);
    }
}
