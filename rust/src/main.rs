//! `ccrsat` — CLI launcher for the CCRSat reproduction.
//!
//! ```text
//! ccrsat run        --scenario sccr [--config F] [--n 5] [--backend pjrt|native]
//! ccrsat reproduce  --experiment table2|table3|fig3|fig4|fig5|all [...]
//! ccrsat sweep      --param tau|thco [...]
//! ccrsat bench      [--scale] [--check] [--out F]   # hot-path perf suite
//! ccrsat bench-report [--measured F] [--baseline F] [--snapshot F] # perf table
//! ccrsat inspect    [--artifacts DIR]        # artifact/manifest report
//! ccrsat selftest   [--artifacts DIR]        # cross-check pjrt vs native
//! ```
//!
//! Argument parsing is hand-rolled (offline image: no clap); every
//! subcommand accepts `--help`.

use std::collections::HashMap;
use std::process::ExitCode;

use ccrsat::compute::{ComputeBackend, NativeBackend, PjrtBackend};
use ccrsat::config::{
    NodeOutageSpec, OutageSpec, SimConfig, TopologyMode, WalkerKind,
};
use ccrsat::coordinator::Scenario;
use ccrsat::harness::experiments as exp;
use ccrsat::harness::hotpath;
use ccrsat::metrics::reports_to_csv;
use ccrsat::simulator::{
    PreparedSource, ShardPartition, Simulation, StreamConfig, StreamingSource,
};
use ccrsat::util::json::Json;
use ccrsat::workload::build_workload;
use ccrsat::{Error, Result};

const USAGE: &str = "\
ccrsat — CCRSat: collaborative computation reuse for satellite edge networks

USAGE:
    ccrsat <COMMAND> [OPTIONS]

COMMANDS:
    run         run one scenario and print the report
    reproduce   regenerate a paper table/figure (table2|table3|fig3|fig4|fig5|all)
    sweep       parameter sensitivity sweep (tau | thco)
    bench       run the hot-path benchmark suite, write BENCH_hotpath.json
    bench-report  print a markdown before/after table of a bench artifact
                  vs the committed baseline (no benches are run)
    inspect     print the artifact manifest summary
    selftest    cross-check the PJRT artifacts against the native backend

BENCH OPTIONS:
    --warmup-ms <MS>     per-bench warmup budget (default 150)
    --budget-ms <MS>     per-bench measurement budget (default 700)
    --scale              add production-scale SCRT tables + 11x11/15x15 grids
    --out <FILE>         JSON artifact path (default BENCH_hotpath.json)
    --check              compare against the committed baseline, fail on regression
    --baseline <FILE>    baseline to check/report against (default benches/baseline.json)
    --factor <X>         regression factor for --check (default 2.0)
    --measured <FILE>    bench-report: measured artifact (default BENCH_hotpath.json)
    --snapshot <FILE>    bench-report: also render a per-case Δ column vs a
                         committed snapshot of the artifact (e.g. the
                         repo-root BENCH_hotpath.json at HEAD)
    --validate           bench-report: additionally require the measured
                         artifact to carry the ccrsat-bench-v1 schema and
                         every baseline case (CI lint smoke for the
                         committed BENCH_hotpath.json snapshot)

RUN SCALE OPTIONS:
    --streaming          prepare task inputs in on-demand chunks with a
                         bounded residency window (constellation-scale runs)
    --stream-window <T>  streaming window budget in tasks (default 256)
    --aggregate-only     keep only aggregate metrics (no per-task logs)
    --threads <K>        run the sharded conservative event engine with K
                         worker shards (bit-identical report; default:
                         single-threaded engine)
    --partition <P>      sharded-engine satellite partition: 'blocks'
                         (contiguous id ranges — whole orbital planes per
                         shard; default) or 'roundrobin' (sat % K); only
                         relabels shard ownership, the report is
                         bit-identical either way (use with --threads)

COMMON OPTIONS:
    --config <FILE>      TOML config (defaults: paper Table I values)
    --n <N>              network scale override (5, 7, 9, ...)
    --grid <N>           alias for --n (wins when both are given)
    --scenario <S>       wo-cr | srs-priority | slcr | sccr-init | sccr
    --backend <B>        pjrt (default when artifacts exist) | native
    --artifacts <DIR>    artifacts directory (default: artifacts)
    --seed <SEED>        workload seed override
    --tasks <T>          total task count override
    --loss <P>           per-chunk ISL loss probability in [0,1) (default 0)
    --corrupt <P>        per-chunk corruption probability in [0,1) (default 0)
    --link-bandwidth <B> per-link bandwidth cap in bits/s (default uncapped)
    --chunk-bytes <C>    transfer chunk size in bytes (default whole-record)
    --max-retries <R>    retransmission attempts per chunk (default 3)
    --topology <SPEC>    contact-plan topology: 'static' (default) or
                         'walker[:k=v,...]' with keys kind=delta|star,
                         period=<S>, duty=<F>, phasing=<K>, scale=<F>,
                         extra=<S>, gs=<K>, pass-period=<S>, pass-duty=<F>
    --outages <LIST>     scripted link outages 'a-b@start..end[,...]'
                         (satellite ids, seconds; composes with --topology)
    --node-outages <L>   scripted satellite crashes 'sat@start..end[,...]'
                         (crash at start, reboot at end; seconds)
    --mtbf <S>           mean time between random crashes per satellite in
                         seconds (default inf: no random crashes)
    --downtime <S>       reboot delay after a random crash (default 60)
    --scrt-persist       SCRT survives crashes (non-volatile storage);
                         default: wiped — reboots are cold starts
    --collab-timeout <S> response timeout before a requester declares its
                         collaboration source dead (default 5)
    --failover-retries <R>  source reselections before a requester degrades
                         to local compute (default 2, max 16)
    --failover-backoff <X>  multiplicative response-timeout backoff per
                         failover attempt (default 2.0, min 1.0)
    --json               emit machine-readable JSON instead of text
    --csv                emit CSV (reproduce/sweep)
    --help               this help
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Parsed flags: `--key value` pairs plus boolean flags.
struct Flags {
    values: HashMap<String, String>,
    bools: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut values = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::config(format!("unexpected argument '{a}'")))?;
            match key {
                "json" | "csv" | "help" | "quiet" | "scale" | "check"
                | "validate" | "streaming" | "aggregate-only"
                | "scrt-persist" => bools.push(key.to_string()),
                _ => {
                    let v = args.get(i + 1).ok_or_else(|| {
                        Error::config(format!("--{key} needs a value"))
                    })?;
                    values.insert(key.to_string(), v.clone());
                    i += 1;
                }
            }
            i += 1;
        }
        Ok(Flags { values, bools })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    fn parse_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| Error::config(format!("--{key} wants an integer, got '{v}'")))
            })
            .transpose()
    }

    fn parse_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| Error::config(format!("--{key} wants a number, got '{v}'")))
            })
            .transpose()
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    if cmd == "--help" || cmd == "help" {
        print!("{USAGE}");
        return Ok(());
    }
    if cmd == "--version" {
        println!("ccrsat {}", ccrsat::VERSION);
        return Ok(());
    }
    let flags = Flags::parse(&args[1..])?;
    if flags.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "reproduce" => cmd_reproduce(&flags),
        "sweep" => cmd_sweep(&flags),
        "bench" => cmd_bench(&flags),
        "bench-report" => cmd_bench_report(&flags),
        "inspect" => cmd_inspect(&flags),
        "selftest" => cmd_selftest(&flags),
        other => Err(Error::config(format!(
            "unknown command '{other}' (see --help)"
        ))),
    }
}

/// Build the SimConfig from --config/--n/--seed/--tasks.
fn load_config(flags: &Flags) -> Result<SimConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SimConfig::from_file(path)?,
        None => SimConfig::paper_default(5),
    };
    if let Some(n) = flags.parse_usize("n")? {
        cfg.network.n = n;
    }
    // `--grid` is the constellation-scale alias for `--n`; it wins when
    // both are given.
    if let Some(n) = flags.parse_usize("grid")? {
        cfg.network.n = n;
    }
    if let Some(seed) = flags.get("seed") {
        cfg.workload.seed = seed
            .parse()
            .map_err(|_| Error::config("--seed wants an integer".to_string()))?;
    }
    if let Some(tasks) = flags.parse_usize("tasks")? {
        cfg.workload.total_tasks = tasks;
    }
    // ISL fault-model overrides (see `CommConfig`): these switch the
    // simulation onto the lossy chunked-transfer path when any of them
    // makes `faults_active()` true.
    if let Some(loss) = flags.parse_f64("loss")? {
        cfg.comm.loss_prob = loss;
    }
    if let Some(corrupt) = flags.parse_f64("corrupt")? {
        cfg.comm.corrupt_prob = corrupt;
    }
    if let Some(bw) = flags.parse_f64("link-bandwidth")? {
        cfg.comm.link_bandwidth_bps = bw;
    }
    if let Some(chunk) = flags.parse_f64("chunk-bytes")? {
        cfg.comm.chunk_bytes = chunk;
    }
    if let Some(retries) = flags.parse_usize("max-retries")? {
        cfg.comm.max_retries = retries;
    }
    // Contact-plan overrides (see `TopologyConfig`): `--topology
    // walker:duty=0.6,period=5400` puts the inter-plane ISLs on a
    // Walker-shell duty cycle; `--outages "a-b@t0..t1,..."` scripts
    // absolute link outages on top of whichever mode is active.
    if let Some(spec) = flags.get("topology") {
        apply_topology_flag(&mut cfg, spec)?;
    }
    if let Some(list) = flags.get("outages") {
        cfg.topology.outages =
            OutageSpec::parse_list(list).map_err(Error::config)?;
    }
    // Node-fault overrides (see `FaultConfig`): any of these switches the
    // engines onto the crash/reboot/failover path when it makes
    // `node_faults_active()` true. Structural validation (ranges, ids)
    // stays in `FaultConfig::node_fault_check`, which both engines run.
    if let Some(mtbf) = flags.parse_f64("mtbf")? {
        cfg.faults.mtbf_s = mtbf;
    }
    if let Some(downtime) = flags.parse_f64("downtime")? {
        cfg.faults.downtime_s = downtime;
    }
    if flags.has("scrt-persist") {
        cfg.faults.scrt_persist = true;
    }
    if let Some(timeout) = flags.parse_f64("collab-timeout")? {
        cfg.faults.collab_timeout_s = timeout;
    }
    if let Some(retries) = flags.parse_usize("failover-retries")? {
        cfg.faults.max_failover_retries = retries;
    }
    if let Some(backoff) = flags.parse_f64("failover-backoff")? {
        cfg.faults.failover_backoff = backoff;
    }
    if let Some(list) = flags.get("node-outages") {
        cfg.faults.node_outages =
            NodeOutageSpec::parse_list(list).map_err(Error::config)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Apply a `--topology` spec: a mode name (`static` | `walker`) optionally
/// followed by `:key=value,...` refinements. Structural validation (duty
/// ranges, grid adjacency of outages, ...) stays in
/// [`ccrsat::config::TopologyConfig::check`], which both engines run — this
/// only translates the flag syntax onto the config fields.
fn apply_topology_flag(cfg: &mut SimConfig, spec: &str) -> Result<()> {
    let (mode, rest) = match spec.split_once(':') {
        Some((m, r)) => (m, Some(r)),
        None => (spec, None),
    };
    cfg.topology.mode = match mode {
        "static" => TopologyMode::Static,
        "walker" => TopologyMode::Walker,
        other => {
            return Err(Error::config(format!(
                "--topology mode '{other}' is not 'static' or 'walker'"
            )))
        }
    };
    let Some(rest) = rest else { return Ok(()) };
    for kv in rest.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv.split_once('=').ok_or_else(|| {
            Error::config(format!("--topology option '{kv}' is not 'key=value'"))
        })?;
        let num = |v: &str| {
            v.parse::<f64>().map_err(|_| {
                Error::config(format!("--topology {k} wants a number, got '{v}'"))
            })
        };
        let int = |v: &str| {
            v.parse::<usize>().map_err(|_| {
                Error::config(format!("--topology {k} wants an integer, got '{v}'"))
            })
        };
        match k {
            "kind" => {
                cfg.topology.kind = match v {
                    "delta" => WalkerKind::Delta,
                    "star" => WalkerKind::Star,
                    other => {
                        return Err(Error::config(format!(
                            "--topology kind '{other}' is not 'delta' or 'star'"
                        )))
                    }
                }
            }
            "period" => cfg.topology.period_s = num(v)?,
            "duty" => cfg.topology.duty = num(v)?,
            "phasing" => cfg.topology.phasing = int(v)?,
            "scale" => cfg.topology.inter_rate_scale = num(v)?,
            "extra" => cfg.topology.inter_extra_latency_s = num(v)?,
            "gs" => cfg.topology.ground_stations = int(v)?,
            "pass-period" => cfg.topology.pass_period_s = num(v)?,
            "pass-duty" => cfg.topology.pass_duty = num(v)?,
            other => {
                return Err(Error::config(format!(
                    "unknown --topology option '{other}' (kind, period, duty, \
                     phasing, scale, extra, gs, pass-period, pass-duty)"
                )))
            }
        }
    }
    Ok(())
}

/// The explicit scale override for commands that select their own scale
/// list (`reproduce`, `sweep`): `--grid` wins over `--n`, mirroring
/// [`load_config`].
fn scale_override(flags: &Flags) -> Result<Option<usize>> {
    Ok(match flags.parse_usize("grid")? {
        Some(g) => Some(g),
        None => flags.parse_usize("n")?,
    })
}

/// Build the compute backend from --backend/--artifacts.
fn load_backend(flags: &Flags, cfg: &SimConfig) -> Result<Box<dyn ComputeBackend>> {
    let dir = flags.get("artifacts").unwrap_or("artifacts");
    match flags.get("backend") {
        Some("native") => Ok(Box::new(NativeBackend::new(cfg))),
        Some("pjrt") => Ok(Box::new(PjrtBackend::from_dir(dir)?)),
        Some(other) => Err(Error::config(format!("unknown backend '{other}'"))),
        // default: pjrt when artifacts are usable, else native. Only an
        // explicit `--backend pjrt` hard-errors on unusable artifacts.
        None => exp::default_backend_at(dir, cfg),
    }
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let backend = load_backend(flags, &cfg)?;
    let scenario = match flags.get("scenario") {
        Some(s) => Scenario::parse(s)
            .ok_or_else(|| Error::config(format!("unknown scenario '{s}'")))?,
        None => Scenario::Sccr,
    };
    let mut sim = Simulation::new(&cfg, backend.as_ref(), scenario);
    if flags.has("aggregate-only") {
        sim = sim.aggregate_only();
    }
    let threads = flags.parse_usize("threads")?;
    if let Some(threads) = threads {
        if threads == 0 {
            return Err(Error::config("--threads wants at least 1".to_string()));
        }
        sim = sim.threads(threads);
    }
    if let Some(spec) = flags.get("partition") {
        let part = ShardPartition::parse(spec).ok_or_else(|| {
            Error::config(format!(
                "--partition must be 'roundrobin' or 'blocks', got '{spec}'"
            ))
        })?;
        if threads.is_none() {
            eprintln!(
                "warning: --partition {} only affects the sharded engine; \
                 pass --threads K to use it",
                part.name()
            );
        }
        sim = sim.partition(part);
    }
    let report = if flags.has("streaming") {
        let stream = StreamConfig::with_window_tasks(
            flags.parse_usize("stream-window")?.unwrap_or(256),
        );
        // A streaming window narrower than the shard count thrashes: the
        // shards' interleaved fetches evict each other's chunks and every
        // recompute runs under the shared source lock, stalling all
        // shards. Warn rather than silently widening the user's
        // residency budget. The suggested budget accounts for
        // `with_window_tasks`'s shape (chunks of up to 256 tasks): below
        // the 256-task chunk cap the window always holds ~4 chunks, so
        // more than 4 shards need `256 × threads` tasks of window.
        if let Some(threads) = threads {
            if threads > 1 && stream.window_chunks < threads {
                let needed = if threads <= 4 {
                    4 * threads
                } else {
                    256 * threads
                };
                eprintln!(
                    "warning: streaming window holds {} chunks for {threads} shards; \
                     concurrent shards may thrash the window and recompute chunks — \
                     consider --stream-window {needed} or more, or fewer shards",
                    stream.window_chunks,
                );
            }
        }
        let wl = build_workload(&cfg);
        let mut source = StreamingSource::new(backend.as_ref(), &wl, stream)?;
        let report = sim.with_workload(&wl).run_with_source(&mut source)?;
        eprintln!(
            "streaming: peak resident {} of {} prepared tasks (window {}, {} chunk preparations, {} recomputed); raw workload {:.1} MB stays resident",
            source.peak_resident(),
            wl.tasks.len(),
            stream.window_tasks(),
            source.prepared_chunks(),
            source.recomputed_chunks(),
            wl.raw_bytes() as f64 / 1e6,
        );
        report
    } else {
        sim.run()?
    };
    if flags.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("backend: {}", backend.name());
        println!("{}", report.summary());
        println!(
            "  mean latency {:.3}s  p95 {:.3}s  wallclock {:.2}s",
            report.mean_latency, report.p95_latency, report.wallclock_s
        );
    }
    Ok(())
}

fn cmd_reproduce(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let backend = load_backend(flags, &cfg)?;
    let experiment = flags.get("experiment").unwrap_or("all");
    let scales: Vec<usize> = match scale_override(flags)? {
        Some(n) => vec![n],
        None => exp::PAPER_SCALES.to_vec(),
    };

    let needs_suite = matches!(experiment, "table2" | "table3" | "fig3" | "all");
    let suite = if needs_suite {
        eprintln!(
            "running {} scenarios × {:?} scales on backend '{}' ({} threads per scale)...",
            Scenario::ALL.len(),
            scales,
            backend.name(),
            Scenario::ALL.len(),
        );
        let (reports, timing) = exp::run_scale_suite_timed(
            &cfg,
            backend.as_ref(),
            &scales,
            &Scenario::ALL,
        )?;
        eprintln!("{}", timing.summary());
        Some(reports)
    } else {
        None
    };

    // The suite is only built for the experiments that need it; reaching
    // for it when the run above was skipped is a bug worth a named error,
    // not a panic.
    fn suite_for<'s, T>(suite: &'s Option<T>, what: &str) -> Result<&'s T> {
        suite.as_ref().ok_or_else(|| {
            Error::simulation(format!(
                "reproduce '{what}' needs the scenario×scale suite, \
                 but no suite run was scheduled for it"
            ))
        })
    }

    match experiment {
        "table2" => println!("{}", exp::table2_markdown(suite_for(&suite, "table2")?)),
        "table3" => println!("{}", exp::table3_markdown(suite_for(&suite, "table3")?)),
        "fig3" => println!("{}", exp::fig3_markdown(suite_for(&suite, "fig3")?)),
        "fig4" => {
            let rows =
                exp::tau_sweep(&cfg, backend.as_ref(), scales[0], &exp::TAU_SWEEP)?;
            println!("{}", exp::fig4_markdown(&rows));
        }
        "fig5" => {
            let rows =
                exp::thco_sweep(&cfg, backend.as_ref(), scales[0], &exp::THCO_SWEEP)?;
            println!("{}", exp::fig5_markdown(&rows));
        }
        "all" => {
            let suite = suite_for(&suite, "all")?;
            println!("{}", exp::table2_markdown(suite));
            println!("{}", exp::table3_markdown(suite));
            println!("{}", exp::fig3_markdown(suite));
            let rows =
                exp::tau_sweep(&cfg, backend.as_ref(), scales[0], &exp::TAU_SWEEP)?;
            println!("{}", exp::fig4_markdown(&rows));
            let rows =
                exp::thco_sweep(&cfg, backend.as_ref(), scales[0], &exp::THCO_SWEEP)?;
            println!("{}", exp::fig5_markdown(&rows));
        }
        other => {
            return Err(Error::config(format!(
                "unknown experiment '{other}' (table2|table3|fig3|fig4|fig5|all)"
            )))
        }
    }
    if flags.has("csv") {
        if let Some(suite) = &suite {
            println!("{}", reports_to_csv(suite));
        }
    }
    Ok(())
}

fn cmd_sweep(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let backend = load_backend(flags, &cfg)?;
    let n = scale_override(flags)?.unwrap_or(5);
    match flags.get("param") {
        Some("tau") => {
            let rows = exp::tau_sweep(&cfg, backend.as_ref(), n, &exp::TAU_SWEEP)?;
            println!("{}", exp::fig4_markdown(&rows));
        }
        Some("thco") => {
            let rows = exp::thco_sweep(&cfg, backend.as_ref(), n, &exp::THCO_SWEEP)?;
            println!("{}", exp::fig5_markdown(&rows));
        }
        other => {
            return Err(Error::config(format!(
                "--param must be tau or thco, got {other:?}"
            )))
        }
    }
    Ok(())
}

/// `ccrsat bench`: run the hot-path suite, write the `BENCH_hotpath.json`
/// artifact and — with `--check` — enforce the committed perf baseline.
fn cmd_bench(flags: &Flags) -> Result<()> {
    let ms = std::time::Duration::from_millis;
    let opts = hotpath::HotpathOpts {
        warmup: ms(flags.parse_usize("warmup-ms")?.unwrap_or(150) as u64),
        budget: ms(flags.parse_usize("budget-ms")?.unwrap_or(700) as u64),
        scale: flags.has("scale"),
    };
    let b = hotpath::run_suite(&opts)?;
    if !flags.has("quiet") {
        b.report();
    }
    let out = flags.get("out").unwrap_or(hotpath::DEFAULT_OUT);
    b.write_json(out)?;
    eprintln!("wrote {out} ({} measurements)", b.results().len());

    if flags.has("check") {
        let baseline_path = flags.get("baseline").unwrap_or(hotpath::BASELINE_PATH);
        let factor = flags
            .parse_f64("factor")?
            .unwrap_or(hotpath::DEFAULT_FACTOR);
        let baseline = hotpath::load_bench_json(baseline_path)?;
        let regressions =
            hotpath::check_against_baseline(b.results(), &baseline, factor)?;
        if regressions.is_empty() {
            println!(
                "perf check OK: no tracked bench regressed > {factor:.1}x vs {baseline_path}"
            );
            return Ok(());
        }
        for r in &regressions {
            eprintln!(
                "REGRESSION {:<28} {:>12.1} ns/iter vs baseline {:>12.1} ns/iter ({:.2}x)",
                r.name,
                r.measured_ns,
                r.baseline_ns,
                r.ratio()
            );
        }
        return Err(Error::simulation(format!(
            "{} tracked bench(es) regressed > {factor:.1}x vs {baseline_path}",
            regressions.len()
        )));
    }
    Ok(())
}

/// `ccrsat bench-report`: render the measured-vs-baseline markdown table
/// from existing artifacts (the CI bench job pipes this into the workflow
/// summary; no benches are run).
fn cmd_bench_report(flags: &Flags) -> Result<()> {
    let measured_path = flags.get("measured").unwrap_or(hotpath::DEFAULT_OUT);
    let baseline_path = flags.get("baseline").unwrap_or(hotpath::BASELINE_PATH);
    let measured = hotpath::load_bench_json(measured_path)?;
    let baseline = hotpath::load_bench_json(baseline_path)?;
    // `--snapshot F` adds the per-case Δ column CI shows in its workflow
    // summary — typically F is the committed repo-root BENCH_hotpath.json
    // and `--measured` a fresh local run.
    let snapshot = flags
        .get("snapshot")
        .map(hotpath::load_bench_json)
        .transpose()?;
    print!(
        "{}",
        hotpath::comparison_markdown_with_snapshot(
            &measured,
            &baseline,
            snapshot.as_ref()
        )?
    );
    // `--validate` turns the report into a lint: the measured artifact
    // (in CI, the committed repo-root snapshot) must carry the expected
    // schema and every case the baseline tracks, so a malformed or stale
    // snapshot fails the job instead of rendering `—` cells.
    if flags.has("validate") {
        hotpath::validate_snapshot(&measured, &baseline)?;
        eprintln!(
            "snapshot OK: {measured_path} covers every case in {baseline_path}"
        );
    }
    Ok(())
}

fn cmd_inspect(flags: &Flags) -> Result<()> {
    let dir = flags.get("artifacts").unwrap_or("artifacts");
    let manifest = ccrsat::runtime::Manifest::load(dir)?;
    if flags.has("json") {
        let mut entries = Vec::new();
        for (name, e) in &manifest.entries {
            entries.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("file", Json::str(e.file.display().to_string())),
                ("inputs", Json::num(e.inputs.len() as f64)),
                ("outputs", Json::num(e.outputs.len() as f64)),
            ]));
        }
        println!("{}", Json::Arr(entries).to_string_pretty());
        return Ok(());
    }
    println!("artifacts dir: {dir}");
    let c = &manifest.constants;
    println!(
        "model: {}x{}→{}x{}, {} classes, p_k={}, {} buckets, {} FLOPs/inference",
        c.raw_h, c.raw_w, c.pre_h, c.pre_w, c.num_classes, c.p_k, c.num_buckets,
        c.classifier_flops
    );
    for (name, e) in &manifest.entries {
        let size = std::fs::metadata(&e.file).map(|m| m.len()).unwrap_or(0);
        println!(
            "  {:<18} {:>8.2} KB  {} inputs → {} outputs",
            name,
            size as f64 / 1e3,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    Ok(())
}

fn cmd_selftest(flags: &Flags) -> Result<()> {
    use ccrsat::util::rng::Rng;
    use ccrsat::workload::texture::{SceneSpec, TextureSynth};

    let cfg = load_config(flags)?;
    let dir = flags.get("artifacts").unwrap_or("artifacts");
    let pjrt = PjrtBackend::from_dir(dir)?;
    let native = NativeBackend::new(&cfg);
    println!("selftest: pjrt vs native backends");

    let synth = TextureSynth::new(cfg.workload.raw_h, cfg.workload.raw_w, 0.05);
    let mut max_pd_err = 0f32;
    let mut max_ssim_err = 0f32;
    let mut checks = 0usize;
    for seed in 0..6u64 {
        let scene = SceneSpec::sample(seed as u32, (seed % 21) as u16, &mut Rng::new(seed));
        let img_a = synth.render(&scene, &mut Rng::new(100 + seed));
        let img_b = synth.render(&scene, &mut Rng::new(200 + seed));
        let (pa, na) = (pjrt.preprocess(&img_a)?, native.preprocess(&img_a)?);
        let (pb, nb) = (pjrt.preprocess(&img_b)?, native.preprocess(&img_b)?);
        for (x, y) in pa.pd.iter().zip(&na.pd) {
            max_pd_err = max_pd_err.max((x - y).abs());
        }
        let s_p = pjrt.ssim(&pa, &pb)?;
        let s_n = native.ssim(&na, &nb)?;
        max_ssim_err = max_ssim_err.max((s_p - s_n).abs());
        checks += 1;
    }
    println!("  preprocess max |Δ| = {max_pd_err:.2e}  ({checks} images)");
    println!("  ssim       max |Δ| = {max_ssim_err:.2e}");
    let ok = max_pd_err < 1e-4 && max_ssim_err < 1e-3;
    println!("selftest: {}", if ok { "OK" } else { "MISMATCH" });
    if ok {
        Ok(())
    } else {
        Err(Error::simulation("backend cross-check failed"))
    }
}
