"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; fixed seeds keep runs deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lsh import hyperplane_hash, make_hyperplanes
from compile.kernels.matmul import matmul, mxu_utilization_estimate, vmem_footprint_bytes
from compile.kernels.ssim import ssim
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape,
                              minval=lo, maxval=hi, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (1, 1, 1),
            (2, 3072, 1),        # the LSH projection shape
            (8, 128, 128),
            (32, 2048, 64),      # classifier fc1 shape
            (32, 64, 21),        # classifier fc2 shape
            (128, 128, 128),     # exactly one tile
            (129, 257, 130),     # off-tile sizes exercise padding
            (300, 100, 200),
        ],
    )
    def test_matches_ref(self, m, k, n):
        x = _rand(m * 1000 + k, (m, k))
        w = _rand(n * 1000 + k + 1, (k, n))
        got = matmul(x, w)
        want = ref.matmul_ref(x, w)
        # tolerance scales with K: tiled accumulation reassociates the sum
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4 * max(k, 16) ** 0.5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 160),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_hypothesis(self, m, k, n, seed):
        x = _rand(seed, (m, k))
        w = _rand(seed + 1, (k, n))
        np.testing.assert_allclose(
            np.asarray(matmul(x, w)), np.asarray(ref.matmul_ref(x, w)),
            rtol=1e-4, atol=1e-4,
        )

    def test_zero_operand(self):
        x = jnp.zeros((16, 32), jnp.float32)
        w = _rand(3, (32, 8))
        np.testing.assert_array_equal(np.asarray(matmul(x, w)), 0.0)

    def test_identity(self):
        x = _rand(9, (24, 24))
        eye = jnp.eye(24, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, eye)),
                                   np.asarray(x), rtol=1e-6, atol=1e-6)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2,)), jnp.zeros((2, 2)))

    def test_mxu_utilization_estimate(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert 0.0 < mxu_utilization_estimate(129, 129, 129) < 1.0
        assert vmem_footprint_bytes() == 4 * 3 * 128 * 128


# ---------------------------------------------------------------------------
# ssim
# ---------------------------------------------------------------------------
class TestSsim:
    def test_identical_images_is_one(self):
        x = _rand(1, (32, 32), 0.0, 1.0)
        assert float(ssim(x, x)) == pytest.approx(1.0, abs=1e-5)

    def test_matches_ref_random_pairs(self):
        for seed in range(8):
            x = _rand(seed, (32, 32), 0.0, 1.0)
            y = _rand(seed + 100, (32, 32), 0.0, 1.0)
            assert float(ssim(x, y)) == pytest.approx(
                float(ref.ssim_ref(x, y)), abs=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), rows=st.integers(4, 64),
           cols=st.integers(4, 64))
    def test_matches_ref_hypothesis(self, seed, rows, cols):
        x = _rand(seed, (rows, cols), 0.0, 1.0)
        y = _rand(seed + 1, (rows, cols), 0.0, 1.0)
        assert float(ssim(x, y)) == pytest.approx(
            float(ref.ssim_ref(x, y)), abs=1e-4)

    def test_symmetry(self):
        x = _rand(5, (32, 32), 0.0, 1.0)
        y = _rand(6, (32, 32), 0.0, 1.0)
        assert float(ssim(x, y)) == pytest.approx(float(ssim(y, x)), abs=1e-6)

    def test_range(self):
        for seed in range(6):
            x = _rand(seed, (32, 32), 0.0, 1.0)
            y = _rand(seed + 50, (32, 32), 0.0, 1.0)
            v = float(ssim(x, y))
            assert -1.0 - 1e-6 <= v <= 1.0 + 1e-6

    def test_inverse_correlation_is_negative(self):
        x = _rand(7, (32, 32), 0.0, 1.0)
        y = jnp.mean(x) * 2.0 - x  # mirror around the mean -> cov < 0
        assert float(ssim(x, y)) < 0.0

    def test_constant_images(self):
        x = jnp.full((32, 32), 0.5, jnp.float32)
        y = jnp.full((32, 32), 0.5, jnp.float32)
        assert float(ssim(x, y)) == pytest.approx(1.0, abs=1e-5)

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            ssim(jnp.zeros((4, 4)), jnp.zeros((4, 5)))


# ---------------------------------------------------------------------------
# hyperplane LSH
# ---------------------------------------------------------------------------
class TestHyperplaneHash:
    @pytest.mark.parametrize("p_k,dim", [(1, 16), (2, 3072), (4, 100), (8, 64)])
    def test_matches_ref(self, p_k, dim):
        planes = make_hyperplanes(jax.random.PRNGKey(0), p_k, dim)
        for seed in range(4):
            x = _rand(seed, (dim,))
            got_b, got_p = hyperplane_hash(planes, x)
            want_b, want_p = ref.hyperplane_hash_ref(planes, x)
            assert int(got_b) == int(want_b)
            np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                                       rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(p_k=st.integers(1, 6), dim=st.integers(2, 256),
           seed=st.integers(0, 2**16))
    def test_bucket_in_range_hypothesis(self, p_k, dim, seed):
        planes = make_hyperplanes(jax.random.PRNGKey(seed), p_k, dim)
        x = _rand(seed + 1, (dim,))
        bucket, proj = hyperplane_hash(planes, x)
        assert 0 <= int(bucket) < 2**p_k
        assert proj.shape == (p_k,)

    def test_locality(self):
        """Near-identical inputs hash to the same bucket (the LSH property)."""
        planes = make_hyperplanes(jax.random.PRNGKey(1), 2, 512)
        x = _rand(11, (512,))
        y = x + 1e-5
        assert int(hyperplane_hash(planes, x)[0]) == int(
            hyperplane_hash(planes, y)[0])

    def test_negation_flips_all_bits(self):
        planes = make_hyperplanes(jax.random.PRNGKey(2), 3, 128)
        x = _rand(12, (128,))
        b1, p1 = hyperplane_hash(planes, x)
        b2, p2 = hyperplane_hash(planes, -x)
        # projections negate; bits flip wherever proj != 0
        np.testing.assert_allclose(np.asarray(p2), -np.asarray(p1),
                                   rtol=1e-4, atol=1e-5)
        assert int(b1) ^ int(b2) == (1 << 3) - 1

    def test_shape_validation(self):
        planes = make_hyperplanes(jax.random.PRNGKey(0), 2, 8)
        with pytest.raises(ValueError):
            hyperplane_hash(planes, jnp.zeros((9,)))
