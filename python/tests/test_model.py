"""L2 model-graph tests: shapes, determinism, and semantic sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _raw(seed: int) -> jax.Array:
    return jax.random.uniform(
        jax.random.PRNGKey(seed),
        (model.RAW_H, model.RAW_W, model.CHANNELS),
        minval=0.0, maxval=255.0, dtype=jnp.float32,
    )


class TestPreprocess:
    def test_shapes(self):
        pd, gray = model.preprocess(_raw(0))
        assert pd.shape == (model.PRE_H, model.PRE_W, model.CHANNELS)
        assert gray.shape == (model.PRE_H, model.PRE_W)

    def test_range(self):
        pd, gray = model.preprocess(_raw(1))
        assert float(pd.min()) >= 0.0 and float(pd.max()) <= 1.0
        assert float(gray.min()) >= 0.0 and float(gray.max()) <= 1.0

    def test_mean_pool_exact(self):
        raw = jnp.arange(
            model.RAW_H * model.RAW_W * model.CHANNELS, dtype=jnp.float32
        ).reshape(model.RAW_H, model.RAW_W, model.CHANNELS) % 256
        pd, _ = model.preprocess(raw)
        # manual 2x2 mean of the normalized image, top-left block
        block = raw[:2, :2, 0] / 255.0
        assert float(pd[0, 0, 0]) == pytest.approx(float(block.mean()), abs=1e-6)

    def test_grayscale_coefficients(self):
        # pure red / green / blue raw tiles map to the BT.601 luma weights
        for c, coeff in enumerate([0.299, 0.587, 0.114]):
            raw = jnp.zeros((model.RAW_H, model.RAW_W, 3)).at[:, :, c].set(255.0)
            _, gray = model.preprocess(raw)
            np.testing.assert_allclose(np.asarray(gray), coeff, rtol=1e-5)

    def test_constant_image(self):
        raw = jnp.full((model.RAW_H, model.RAW_W, 3), 128.0)
        pd, gray = model.preprocess(raw)
        np.testing.assert_allclose(np.asarray(pd), 128.0 / 255.0, rtol=1e-6)


class TestLshHash:
    def test_deterministic(self):
        pd, _ = model.preprocess(_raw(2))
        b1, p1 = model.lsh_hash(pd)
        b2, p2 = model.lsh_hash(pd)
        assert int(b1) == int(b2)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_bucket_range(self):
        for seed in range(8):
            pd, _ = model.preprocess(_raw(seed))
            bucket, proj = model.lsh_hash(pd)
            assert 0 <= int(bucket) < 2**model.P_K
            assert proj.shape == (model.P_K,)

    def test_similar_inputs_collide(self):
        raw = _raw(3)
        pd1, _ = model.preprocess(raw)
        pd2, _ = model.preprocess(raw + 0.5)  # sub-quantum perturbation
        assert int(model.lsh_hash(pd1)[0]) == int(model.lsh_hash(pd2)[0])

    def test_buckets_are_used(self):
        """Across many random inputs, more than one bucket must appear."""
        seen = {
            int(model.lsh_hash(model.preprocess(_raw(s))[0])[0])
            for s in range(24)
        }
        assert len(seen) >= 2


class TestSsimPair:
    def test_identical(self):
        _, gray = model.preprocess(_raw(4))
        (v,) = model.ssim_pair(gray, gray)
        assert float(v) == pytest.approx(1.0, abs=1e-5)

    def test_distinct_scenes_below_one(self):
        _, g1 = model.preprocess(_raw(5))
        _, g2 = model.preprocess(_raw(6))
        (v,) = model.ssim_pair(g1, g2)
        assert float(v) < 0.999


class TestClassifier:
    def test_shapes(self):
        pd, _ = model.preprocess(_raw(7))
        logits, label = model.classifier_one(pd)
        assert logits.shape == (model.NUM_CLASSES,)
        assert label.shape == ()
        assert label.dtype == jnp.uint32

    def test_label_is_argmax(self):
        pd, _ = model.preprocess(_raw(8))
        logits, label = model.classifier_one(pd)
        assert int(label) == int(jnp.argmax(logits))

    def test_deterministic(self):
        pd, _ = model.preprocess(_raw(9))
        l1, _ = model.classifier_one(pd)
        l2, _ = model.classifier_one(pd)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_batch_matches_single(self):
        pds = jnp.stack([model.preprocess(_raw(s))[0] for s in range(4)])
        logits_b, labels_b = model.classifier_batch(pds)
        assert logits_b.shape == (4, model.NUM_CLASSES)
        for i in range(4):
            logits_1, label_1 = model.classifier_one(pds[i])
            np.testing.assert_allclose(np.asarray(logits_b[i]),
                                       np.asarray(logits_1),
                                       rtol=1e-4, atol=1e-5)
            assert int(labels_b[i]) == int(label_1)

    def test_labels_vary_across_inputs(self):
        labels = {
            int(model.classifier_one(model.preprocess(_raw(s))[0])[1])
            for s in range(24)
        }
        assert len(labels) >= 2, "degenerate classifier: one label for all inputs"

    def test_flops_positive_and_stable(self):
        f = model.classifier_flops()
        assert f > 1e6
        assert f == model.classifier_flops()


class TestParams:
    def test_cached_identity(self):
        assert model.model_params() is model.model_params()
        assert model.lsh_planes(model.P_K) is model.lsh_planes(model.P_K)

    def test_weight_shapes(self):
        p = model.model_params()
        assert p.stem.shape == (3, 3, 3, 16)
        assert p.fc1.shape == ((model.PRE_H // 4) * (model.PRE_W // 4) * 32, 64)
        assert p.fc2.shape == (64, model.NUM_CLASSES)
