"""Tiled matmul Pallas kernel (MXU-shaped).

Used by the MicroGoogLeNet dense layers and the LSH hyperplane projection.
The kernel tiles ``(M, K) @ (K, N)`` into ``(bm, bk) x (bk, bn)`` VMEM blocks
and accumulates over the K grid axis into the output block, which stays
resident across the K sweep (revisiting schedule) — the canonical TPU
schedule: one MXU-sized block pair in VMEM per grid step, HBM traffic
expressed through the BlockSpec index maps.

On this image we lower with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the *structure* is what a real TPU build would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes.  The MXU is a 128x128 systolic array;
# float32 VMEM tiling is (8, 128), so every default is a multiple of both.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One grid step: accumulate x_block @ w_block into the output block.

    Grid is (M/bm, N/bn, K/bk) with K innermost, so the (i, j) output block
    is revisited across the whole K sweep and written back to HBM once.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU op: block matmul with f32 accumulation.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    """Zero-pad a 2D array so both dims are multiples of the tile sizes."""
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def _shrink(tile: int, dim: int, floor: int) -> int:
    """Shrink a tile for small operands while keeping power-of-2 alignment."""
    return min(tile, max(floor, 1 << (max(dim - 1, 1)).bit_length()))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """``x @ w`` via the tiled Pallas kernel.

    Operands of any 2D shape are zero-padded up to the tile grid and the
    result is sliced back, so callers never see the padding.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape

    # Shrink tiles for small operands (keeps padding waste bounded while
    # still exercising the same kernel).  Sublane floor 8, lane floor 128.
    bm = _shrink(bm, m, 8)
    bn = _shrink(bn, n, 128)
    bk = _shrink(bk, k, 128)

    xp = _pad_to(x.astype(jnp.float32), bm, bk)
    wp = _pad_to(w.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                         bk: int = DEFAULT_BK) -> int:
    """Estimated VMEM bytes live per grid step (x, w and output blocks)."""
    f32 = 4
    return f32 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int,
                             bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                             bk: int = DEFAULT_BK) -> float:
    """Fraction of issued MXU work that is real (non-padding) FLOPs."""
    mp = ((m + bm - 1) // bm) * bm
    kp = ((k + bk - 1) // bk) * bk
    np_ = ((n + bn - 1) // bn) * bn
    return (m * k * n) / float(mp * kp * np_)
