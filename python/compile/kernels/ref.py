"""Pure-jnp oracles for every L1 kernel.

These are the ground truth the Pallas kernels are tested against
(``python/tests/test_kernel.py``); they are also what the L2 model would use
if the Pallas layer were disabled, so they double as an ablation path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.ssim import C1, C2, C3


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for kernels.matmul: plain f32 dot."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def ssim_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference for kernels.ssim: eq. (12), global window, same constants."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    n = x.size
    mu_x = jnp.mean(x)
    mu_y = jnp.mean(y)
    var_x = jnp.maximum(jnp.mean(x * x) - mu_x**2, 0.0)
    var_y = jnp.maximum(jnp.mean(y * y) - mu_y**2, 0.0)
    cov = jnp.mean(x * y) - mu_x * mu_y
    sig_x = jnp.sqrt(var_x)
    sig_y = jnp.sqrt(var_y)
    lum = (2 * mu_x * mu_y + C1) / (mu_x**2 + mu_y**2 + C1)
    con = (2 * sig_x * sig_y + C2) / (var_x + var_y + C2)
    struct = (cov + C3) / (sig_x * sig_y + C3)
    return lum * con * struct


def hyperplane_hash_ref(
    planes: jax.Array, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Reference for kernels.hyperplane_hash."""
    proj = planes.astype(jnp.float32) @ x.astype(jnp.float32)
    bits = (proj >= 0).astype(jnp.uint32)
    weights = (2 ** jnp.arange(planes.shape[0], dtype=jnp.uint32))[::-1]
    return jnp.sum(bits * weights).astype(jnp.uint32), proj
