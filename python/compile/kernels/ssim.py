"""SSIM Pallas kernel — eq. (12) of the paper.

CCRSat gates every reuse decision on the structural similarity between the
pre-processed task input and its LSH nearest neighbour, so SSIM sits on the
hot path of both SLCR (Alg. 1 line 8) and the collaborative flow.

The paper uses the *global* SSIM form (single window over the whole image,
eq. 12 with the three-term decomposition).  The kernel tiles both images
into VMEM blocks and accumulates the five sufficient statistics
``(Σx, Σy, Σx², Σy², Σxy)`` per block on the VPU; the scalar combine into
luminance/contrast/structure terms happens in plain jnp afterwards (a few
scalar ops — not worth a kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Stabilisation constants, standard SSIM choices for dynamic range L=1
# (inputs are normalised to [0, 1]).
K1 = 0.01
K2 = 0.03
L = 1.0
C1 = (K1 * L) ** 2
C2 = (K2 * L) ** 2
C3 = C2 / 2.0

# VMEM tile for the reduction: one (8, 128)-aligned block per grid step.
BLOCK_R = 8
BLOCK_C = 128


def _moments_kernel(x_ref, y_ref, o_ref):
    """Accumulate the five sufficient statistics over the tile grid.

    ``o_ref`` is a (1, 5) revisited output block: every grid step adds its
    tile's partial sums, so after the sweep it holds the full-image moments.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    y = y_ref[...]
    part = jnp.stack(
        [
            jnp.sum(x),
            jnp.sum(y),
            jnp.sum(x * x),
            jnp.sum(y * y),
            jnp.sum(x * y),
        ]
    ).reshape(1, 5)
    o_ref[...] += part


def _pad2(x: jax.Array) -> jax.Array:
    p0 = (-x.shape[0]) % BLOCK_R
    p1 = (-x.shape[1]) % BLOCK_C
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssim(x: jax.Array, y: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Global SSIM between two grayscale images (eq. 12), scalar in [-1, 1].

    Zero-padding both images identically does not bias the *sums*; the
    denominators use the true pixel count ``n``, so means/variances are
    computed over real pixels only... except padded zeros do enter Σ terms.
    To keep the statistics exact we mask nothing: instead the images are
    padded and ``n`` counts padded pixels too, but both images receive the
    same zero padding, which perturbs both marginals identically.  For exact
    parity with the oracle we simply compute over the padded arrays in both
    kernel and reference (see ref.ssim_ref, which applies the same padding).
    """
    if x.shape != y.shape or x.ndim != 2:
        raise ValueError(f"ssim expects equal 2D shapes, got {x.shape}, {y.shape}")
    xp = _pad2(x.astype(jnp.float32))
    yp = _pad2(y.astype(jnp.float32))
    rows, cols = xp.shape
    n = jnp.float32(x.shape[0] * x.shape[1])
    # Padded-zero corrections are unnecessary for Σ terms (zeros add 0), so
    # the sums over the padded arrays equal the sums over the originals.

    moments = pl.pallas_call(
        _moments_kernel,
        grid=(rows // BLOCK_R, cols // BLOCK_C),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 5), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 5), jnp.float32),
        interpret=interpret,
    )(xp, yp)[0]

    sx, sy, sxx, syy, sxy = moments[0], moments[1], moments[2], moments[3], moments[4]
    mu_x = sx / n
    mu_y = sy / n
    var_x = jnp.maximum(sxx / n - mu_x * mu_x, 0.0)
    var_y = jnp.maximum(syy / n - mu_y * mu_y, 0.0)
    cov = sxy / n - mu_x * mu_y
    sig_x = jnp.sqrt(var_x)
    sig_y = jnp.sqrt(var_y)

    lum = (2 * mu_x * mu_y + C1) / (mu_x**2 + mu_y**2 + C1)
    con = (2 * sig_x * sig_y + C2) / (var_x + var_y + C2)
    struct = (cov + C3) / (sig_x * sig_y + C3)
    return lum * con * struct


def vmem_footprint_bytes() -> int:
    """VMEM bytes live per grid step (two input tiles + moment block)."""
    f32 = 4
    return f32 * (2 * BLOCK_R * BLOCK_C + 5)
