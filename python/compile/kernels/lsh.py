"""Hyperplane LSH Pallas kernel.

The paper uses FALCONN's hyperplane hashing with ``p_l = 1`` table and
``p_k = 2`` hash functions (Table I).  Hyperplane LSH is
``bit_i = sign(h_i · x)`` for random Gaussian hyperplanes ``h_i``; the
``p_k`` bits concatenate into a bucket id in ``[0, 2**p_k)``.

The projection is an ``(p_k, D) @ (D, 1)`` matvec — we express it through
the same tiled Pallas matmul schedule as the classifier (one kernel, two
call sites), then take signs in jnp.  Supporting arbitrary ``p_k`` keeps
the sensitivity-analysis sweeps honest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul


def make_hyperplanes(key: jax.Array, p_k: int, dim: int) -> jax.Array:
    """Random Gaussian hyperplanes, the FALCONN hyperplane family."""
    return jax.random.normal(key, (p_k, dim), dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hyperplane_hash(
    planes: jax.Array, x: jax.Array, *, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Hash a flattened input vector.

    Args:
      planes: ``(p_k, D)`` Gaussian hyperplanes.
      x: ``(D,)`` flattened pre-processed input.

    Returns:
      ``(bucket, projections)`` — ``bucket`` is a uint32 scalar in
      ``[0, 2**p_k)``; ``projections`` the raw ``(p_k,)`` dot products
      (useful for multiprobe extensions and for tests).
    """
    if planes.ndim != 2 or x.ndim != 1 or planes.shape[1] != x.shape[0]:
        raise ValueError(f"shape mismatch: planes {planes.shape}, x {x.shape}")
    p_k = planes.shape[0]
    # (p_k, D) @ (D, 1) through the tiled MXU kernel.
    proj = matmul(planes, x[:, None], interpret=interpret)[:, 0]
    bits = (proj >= 0).astype(jnp.uint32)
    weights = (2 ** jnp.arange(p_k, dtype=jnp.uint32))[::-1]
    bucket = jnp.sum(bits * weights).astype(jnp.uint32)
    return bucket, proj
