"""Layer-1 Pallas kernels for CCRSat.

Every kernel here is authored for TPU (VMEM tiling, MXU-shaped matmuls) but
lowered with ``interpret=True`` so the resulting HLO runs on the CPU PJRT
client that the Rust coordinator embeds.  Correctness oracles live in
:mod:`compile.kernels.ref` and are enforced by ``python/tests``.
"""

from compile.kernels.matmul import matmul
from compile.kernels.ssim import ssim
from compile.kernels.lsh import hyperplane_hash

__all__ = ["matmul", "ssim", "hyperplane_hash"]
