"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla_extension 0.5.1 the Rust ``xla`` crate
links against rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Run from ``python/``::

    python -m compile.aot --out-dir ../artifacts

Also writes ``manifest.json`` describing every artifact (entry name, file,
input/output shapes + dtypes) plus the model constants the Rust simulator
needs (FLOPs per inference, LSH geometry, class count).  ``make artifacts``
is a no-op when sources are unchanged (Makefile dependency tracking).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.matmul import vmem_footprint_bytes

BATCH = 32  # oracle-pass batch size


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big weight
    # tensors as ``constant({...})``, which the Rust-side text parser would
    # mis-read; the artifacts must be numerically self-contained.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


ENTRIES = {
    # name -> (fn, example args)
    "preprocess": (
        lambda raw: model.preprocess(raw),
        [_spec((model.RAW_H, model.RAW_W, model.CHANNELS))],
    ),
    "lsh_hash": (
        lambda pd: model.lsh_hash(pd),
        [_spec((model.PRE_H, model.PRE_W, model.CHANNELS))],
    ),
    "ssim_pair": (
        lambda a, b: model.ssim_pair(a, b),
        [_spec((model.PRE_H, model.PRE_W)), _spec((model.PRE_H, model.PRE_W))],
    ),
    "classifier": (
        lambda pd: model.classifier_one(pd),
        [_spec((model.PRE_H, model.PRE_W, model.CHANNELS))],
    ),
    "classifier_batch": (
        lambda pd: model.classifier_batch(pd),
        [_spec((BATCH, model.PRE_H, model.PRE_W, model.CHANNELS))],
    ),
}


def lower_entry(name: str):
    # Materialise the weights/hyperplanes EAGERLY before tracing: under jit
    # omnistaging, calling model_params() inside the trace would stage the
    # whole threefry RNG into the artifact instead of baking concrete
    # constants (and re-generate weights on every inference call).
    jax.block_until_ready(model.model_params())
    jax.block_until_ready(model.lsh_planes(model.P_K))
    fn, args = ENTRIES[name]
    lowered = jax.jit(fn).lower(*args)
    outs = jax.eval_shape(fn, *args)
    out_leaves = jax.tree_util.tree_leaves(outs)
    return to_hlo_text(lowered), args, out_leaves


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None,
        help="subset of entries to lower (default: all)",
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "return_tuple": True,
        "entries": {},
        "constants": {
            "raw_h": model.RAW_H,
            "raw_w": model.RAW_W,
            "pre_h": model.PRE_H,
            "pre_w": model.PRE_W,
            "channels": model.CHANNELS,
            "num_classes": model.NUM_CLASSES,
            "p_l": model.P_L,
            "p_k": model.P_K,
            "num_buckets": 2 ** model.P_K,
            "feature_dim": model.FEATURE_DIM,
            "batch": BATCH,
            "classifier_flops": model.classifier_flops(),
            "matmul_vmem_bytes": vmem_footprint_bytes(),
        },
    }

    names = ns.only or list(ENTRIES)
    for name in names:
        text, args, outs = lower_entry(name)
        fname = f"{name}.hlo.txt"
        path = os.path.join(ns.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [_shape_entry(a) for a in args],
            "outputs": [_shape_entry(o) for o in outs],
        }
        print(f"lowered {name:18s} -> {path} ({len(text) / 1e6:.2f} MB)")

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
