"""Layer-2 JAX compute graphs for CCRSat.

Four entry points, each AOT-lowered by :mod:`compile.aot` into an HLO-text
artifact the Rust coordinator executes via PJRT:

* ``preprocess``       — Alg. 1 line 1: resize (2x2 mean pool), normalise,
                         grayscale for SSIM.
* ``lsh_hash``         — Alg. 1 line 2: FALCONN-style hyperplane hashing of
                         the flattened pre-processed input (Pallas kernel).
* ``ssim_pair``        — Alg. 1 line 8: eq. (12) similarity gate
                         (Pallas kernel).
* ``classifier_batch`` — Alg. 1 lines 4/13: the "pre-trained model"
                         (MicroGoogLeNet, the GoogLeNet-22 stand-in; dense
                         layers run through the Pallas matmul kernel).

The classifier weights are seeded (PRNGKey(42)) and baked into the artifact
as constants: the Rust side ships no Python and loads no weight files.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels.lsh import hyperplane_hash, make_hyperplanes
from compile.kernels.matmul import matmul
from compile.kernels.ssim import ssim

# ---------------------------------------------------------------------------
# Geometry / hyper-parameters (Table I of the paper where applicable).
# ---------------------------------------------------------------------------
RAW_H = 64          # raw sensor tile (stand-in for UC Merced 256x256)
RAW_W = 64
PRE_H = 32          # pre-processed model input (stand-in for 224x224)
PRE_W = 32
CHANNELS = 3
NUM_CLASSES = 21    # UC Merced has 21 land-use classes
P_L = 1             # number of LSH tables   (Table I)
P_K = 2             # number of hash functions (Table I)
FEATURE_DIM = PRE_H * PRE_W * CHANNELS
WEIGHT_SEED = 42
LSH_SEED = 7

GRAY_COEFFS = jnp.array([0.299, 0.587, 0.114], dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Entry point 1: preprocess.
# ---------------------------------------------------------------------------
def preprocess(raw: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Resize + normalise + grayscale.

    Args:
      raw: ``f32[RAW_H, RAW_W, 3]`` pixel values in [0, 255].

    Returns:
      ``(pd, gray)`` — ``pd`` is ``f32[PRE_H, PRE_W, 3]`` in [0, 1] (model
      input), ``gray`` is ``f32[PRE_H, PRE_W]`` (SSIM input).
    """
    x = raw.astype(jnp.float32) / 255.0
    # 2x2 mean pool == bilinear-free resize from 64 -> 32.
    fh = RAW_H // PRE_H
    fw = RAW_W // PRE_W
    x = x.reshape(PRE_H, fh, PRE_W, fw, CHANNELS).mean(axis=(1, 3))
    gray = jnp.einsum("hwc,c->hw", x, GRAY_COEFFS)
    return x, gray


# ---------------------------------------------------------------------------
# Entry point 2: LSH hash.
# ---------------------------------------------------------------------------
@functools.cache
def lsh_planes(p_k: int = P_K) -> jax.Array:
    return make_hyperplanes(jax.random.PRNGKey(LSH_SEED), p_k, FEATURE_DIM)


def lsh_hash(pd: jax.Array, *, p_k: int = P_K) -> tuple[jax.Array, jax.Array]:
    """Bucket id + raw projections for a pre-processed input."""
    planes = lsh_planes(p_k)
    return hyperplane_hash(planes, pd.reshape(-1))


# ---------------------------------------------------------------------------
# Entry point 3: SSIM pair.
# ---------------------------------------------------------------------------
def ssim_pair(gray_a: jax.Array, gray_b: jax.Array) -> tuple[jax.Array]:
    """Eq. (12) similarity between two grayscale pre-processed inputs."""
    return (ssim(gray_a, gray_b),)


# ---------------------------------------------------------------------------
# Entry point 4: the pre-trained model (MicroGoogLeNet).
# ---------------------------------------------------------------------------
class InceptionParams(NamedTuple):
    """One inception block: 1x1 / 1x1->3x3 / 1x1->5x5 / pool->1x1 branches."""

    b1: jax.Array            # (1,1,c,b1)
    r2: jax.Array            # (1,1,c,r2)
    b2: jax.Array            # (3,3,r2,b2)
    r3: jax.Array            # (1,1,c,r3)
    b3: jax.Array            # (5,5,r3,b3)
    b4: jax.Array            # (1,1,c,b4)


class ModelParams(NamedTuple):
    stem: jax.Array          # (3,3,3,16)
    inc1: InceptionParams    # 16 -> 24
    inc2: InceptionParams    # 24 -> 32
    fc1: jax.Array           # (8*8*32, 64)
    fc2: jax.Array           # (64, NUM_CLASSES)


def _conv_init(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, dtype=jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _inception_init(key: jax.Array, c: int, spec) -> InceptionParams:
    b1, r2, b2, r3, b3, b4 = spec
    ks = jax.random.split(key, 6)
    return InceptionParams(
        b1=_conv_init(ks[0], (1, 1, c, b1)),
        r2=_conv_init(ks[1], (1, 1, c, r2)),
        b2=_conv_init(ks[2], (3, 3, r2, b2)),
        r3=_conv_init(ks[3], (1, 1, c, r3)),
        b3=_conv_init(ks[4], (5, 5, r3, b3)),
        b4=_conv_init(ks[5], (1, 1, c, b4)),
    )


@functools.cache
def model_params() -> ModelParams:
    """Deterministic 'pre-trained' weights baked into the artifact."""
    ks = jax.random.split(jax.random.PRNGKey(WEIGHT_SEED), 5)
    fc_in = (PRE_H // 4) * (PRE_W // 4) * 32
    return ModelParams(
        stem=_conv_init(ks[0], (3, 3, CHANNELS, 16)),
        inc1=_inception_init(ks[1], 16, (8, 8, 8, 4, 4, 4)),     # out 24
        inc2=_inception_init(ks[2], 24, (12, 12, 12, 4, 4, 4)),  # out 32
        fc1=jax.random.normal(ks[3], (fc_in, 64), dtype=jnp.float32)
        * jnp.sqrt(2.0 / fc_in),
        fc2=jax.random.normal(ks[4], (64, NUM_CLASSES), dtype=jnp.float32)
        * jnp.sqrt(2.0 / 64),
    )


def _conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """NHWC same-padding conv (XLA fuses these; the MXU work is in fc)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _maxpool3_same(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 1, 1, 1), "SAME"
    )


def _inception(x: jax.Array, p: InceptionParams) -> jax.Array:
    br1 = _conv(x, p.b1)
    br2 = _conv(jax.nn.relu(_conv(x, p.r2)), p.b2)
    br3 = _conv(jax.nn.relu(_conv(x, p.r3)), p.b3)
    br4 = _conv(_maxpool3_same(x), p.b4)
    return jax.nn.relu(jnp.concatenate([br1, br2, br3, br4], axis=-1))


def classifier_batch(pd: jax.Array) -> tuple[jax.Array, jax.Array]:
    """MicroGoogLeNet forward over a batch.

    Args:
      pd: ``f32[B, PRE_H, PRE_W, 3]`` pre-processed inputs.

    Returns:
      ``(logits f32[B, 21], labels u32[B])``.
    """
    p = model_params()
    x = jax.nn.relu(_conv(pd, p.stem))
    x = _maxpool2(x)                      # 16x16x16
    x = _inception(x, p.inc1)             # 16x16x24
    x = _maxpool2(x)                      # 8x8x24
    x = _inception(x, p.inc2)             # 8x8x32
    x = x.reshape(x.shape[0], -1)         # (B, 2048)
    # Dense layers through the Pallas MXU kernel.
    x = jax.nn.relu(matmul(x, p.fc1))
    logits = matmul(x, p.fc2)
    labels = jnp.argmax(logits, axis=-1).astype(jnp.uint32)
    return logits, labels


def classifier_one(pd: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-image classifier: ``f32[PRE_H, PRE_W, 3] -> (f32[21], u32[])``."""
    logits, labels = classifier_batch(pd[None])
    return logits[0], labels[0]


# ---------------------------------------------------------------------------
# Analytic cost of one classifier call — feeds the paper's F_t (eq. 6).
# ---------------------------------------------------------------------------
def classifier_flops() -> int:
    """MACs*2 of one forward pass; the simulator scales this to GoogLeNet-22."""

    def conv_flops(h, w, kh, kw, cin, cout):
        return 2 * h * w * kh * kw * cin * cout

    f = 0
    f += conv_flops(32, 32, 3, 3, 3, 16)                      # stem
    # inception 1 at 16x16, cin 16, spec (8,8,8,4,4,4)
    f += conv_flops(16, 16, 1, 1, 16, 8)
    f += conv_flops(16, 16, 1, 1, 16, 8) + conv_flops(16, 16, 3, 3, 8, 8)
    f += conv_flops(16, 16, 1, 1, 16, 4) + conv_flops(16, 16, 5, 5, 4, 4)
    f += conv_flops(16, 16, 1, 1, 16, 4)
    # inception 2 at 8x8, cin 24, spec (12,12,12,4,4,4)
    f += conv_flops(8, 8, 1, 1, 24, 12)
    f += conv_flops(8, 8, 1, 1, 24, 12) + conv_flops(8, 8, 3, 3, 12, 12)
    f += conv_flops(8, 8, 1, 1, 24, 4) + conv_flops(8, 8, 5, 5, 4, 4)
    f += conv_flops(8, 8, 1, 1, 24, 4)
    f += 2 * 2048 * 64 + 2 * 64 * NUM_CLASSES                 # dense head
    return f
